"""Quickstart: TT-HF (Algorithm 1) on the federated image-classification
task of the paper, next to its two FL baselines — in ~2 minutes on CPU.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

from repro.configs import TopologyConfig, TTHFConfig
from repro.core import TTHFTrainer, make_baseline_config
from repro.data import fashion_synth, partition_noniid_labels
from repro.models import make_sim_model

# 1. A federated world: 25 devices in 5 D2D clusters, non-iid shards
#    (3 labels per device), geometric graphs tuned to rho ~ 0.7.
x, y = fashion_synth(num_points=6_000, seed=0)
data = partition_noniid_labels(x, y, num_devices=25, labels_per_device=3)
topo = TopologyConfig(num_devices=25, num_clusters=5, graph="geometric",
                      target_spectral_radius=0.7, seed=0)
model = make_sim_model("svm", data.feature_dim, data.num_classes)

# 2. TT-HF: tau=20 local SGD steps per global aggregation, D2D consensus
#    every 5 steps with Gamma=2 rounds, cluster-sampled uplinks.
STEPS, LR = 120, 0.002
tthf = TTHFConfig(tau=20, consensus_every=5, gamma_d2d=2, constant_lr=LR)

print(f"{'method':16s} {'loss':>8s} {'acc':>7s} {'uplinks':>8s} {'d2d':>7s}")
for name, algo in [
    ("tthf", tthf),
    ("fl_tau20", dataclasses.replace(make_baseline_config("fedavg", 20),
                                     constant_lr=LR)),
    ("fl_tau1", dataclasses.replace(make_baseline_config("centralized", 1),
                                    constant_lr=LR)),
]:
    tr = TTHFTrainer(model, data, topo, algo, batch_size=16)
    _, hist = tr.run(steps=STEPS, eval_every=STEPS)
    print(f"{name:16s} {hist.global_loss[-1]:8.4f} "
          f"{hist.global_acc[-1]:7.3f} {tr.ledger.uplinks:8d} "
          f"{tr.ledger.d2d_msgs:7d}")

print("\nTT-HF matches/beats FL tau=20 with 5x fewer uplink transmissions;"
      "\nincrease gamma_d2d to approach the tau=1 upper bound (Fig. 4).")
