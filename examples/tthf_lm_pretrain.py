"""End-to-end driver: pretrain a ~110M-parameter decoder LM with TT-HF
as the distributed sync strategy (scale mode, DESIGN.md §3-4).

4 model replicas in 2 clusters; each TT-HF interval = tau local SGD
steps + aperiodic D2D consensus (fused V^Gamma mixing) + a
cluster-sampled global aggregation. Replicas consume disjoint Zipf
shards (the non-iid delta>0 regime).

CPU note: the full run (--intervals 25 --tau 8, ~200 local steps x 4
replicas of a 110M model) takes hours on 1 core; defaults are sized for
a smoke run. On accelerators the same script scales via the mesh in
launch/mesh.py.

Run:  PYTHONPATH=src python examples/tthf_lm_pretrain.py \
          [--intervals 2] [--dim 768] [--layers 12]
"""
import argparse
import dataclasses
import time

import numpy as np

ap = argparse.ArgumentParser()
ap.add_argument("--intervals", type=int, default=2)
ap.add_argument("--tau", type=int, default=4)
ap.add_argument("--dim", type=int, default=256)
ap.add_argument("--layers", type=int, default=4)
ap.add_argument("--d-ff", type=int, default=1024)
ap.add_argument("--vocab", type=int, default=32_000)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--lr", type=float, default=0.01)
ap.add_argument("--sync", choices=["tthf", "star", "local"],
                default="tthf")
args = ap.parse_args()

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.distributed import (
    TTHFScaleConfig, make_tthf_train_step, stack_replicas)
from repro.data.tokens import synthetic_token_batches
from repro.models import build_model

cfg = dataclasses.replace(
    get_arch("qwen1.5-0.5b"),
    num_layers=args.layers, d_model=args.dim, d_ff=args.d_ff,
    num_heads=max(4, args.dim // 64), num_kv_heads=max(4, args.dim // 64),
    head_dim=64, vocab_size=args.vocab, max_seq_len=4096)
model = build_model(cfg)
print(f"model: {cfg.param_count()/1e6:.0f}M params "
      f"(L={cfg.num_layers}, d={cfg.d_model}, vocab={cfg.vocab_size})")

scale = TTHFScaleConfig(replicas=4, cluster_size=2, tau=args.tau,
                        consensus_every=max(args.tau // 2, 1),
                        gamma_d2d=2, lr=args.lr, consensus_mode="fused")
step, net = make_tthf_train_step(model, scale, dtype=jnp.float32,
                                 sync=args.sync)
step = jax.jit(step)
params = stack_replicas(model.init(jax.random.PRNGKey(0)), scale.replicas)
gens = [synthetic_token_batches(args.batch, args.seq, cfg.vocab_size,
                                seed=0, shard_id=r)
        for r in range(scale.replicas)]
key = jax.random.PRNGKey(1)

for it in range(args.intervals):
    mbs = [[next(g) for _ in range(scale.tau)] for g in gens]
    batch = {k: jnp.asarray(np.stack(
        [[mbs[r][t][k] for r in range(scale.replicas)]
         for t in range(scale.tau)]))
        for k in ("tokens", "labels")}
    key, kp = jax.random.split(key)
    picks = jax.random.randint(kp, (net.num_clusters,), 0,
                               scale.cluster_size)
    t0 = time.time()
    params, loss = step(params, batch, picks, jnp.asarray(it))
    tok_s = scale.tau * scale.replicas * args.batch * args.seq \
        / (time.time() - t0)
    print(f"interval {it:3d}: loss={float(loss):.4f} "
          f"({scale.tau} local steps/replica, {tok_s:,.0f} tok/s, "
          f"sync={args.sync})")

print("\nuplink traffic per interval: N_clusters models "
      f"({net.num_clusters}) vs full participation "
      f"({scale.replicas}) — the paper's cluster-sampling saving.")
