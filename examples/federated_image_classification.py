"""Paper-faithful Sec.-IV experiment: I=125 devices, N=25 clusters of
s_c=5, geometric D2D graphs (avg spectral radius 0.7), non-iid 3-label
shards, SVM + adaptive Remark-1 consensus and the decaying step size
eta_t = gamma/(t+alpha) of Theorem 2.

Plots-as-text: loss/accuracy trajectories + uplink/D2D accounting +
the analytic nu/(t+alpha) envelope.

Run:  PYTHONPATH=src python examples/federated_image_classification.py
      (add --fast for a 25-device version)
"""
import argparse

import numpy as np

from repro.configs import TopologyConfig, TTHFConfig
from repro.core import TTHFTrainer, bound_curve
from repro.data import fashion_synth, partition_noniid_labels
from repro.models import make_sim_model

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true")
ap.add_argument("--steps", type=int, default=400)
args = ap.parse_args()

devices, clusters, points = (25, 5, 6000) if args.fast else (125, 25, 31250)

x, y = fashion_synth(num_points=points, seed=0, unit_norm=True)
data = partition_noniid_labels(x, y, num_devices=devices,
                               labels_per_device=3)
topo = TopologyConfig(num_devices=devices, num_clusters=clusters,
                      graph="geometric", target_spectral_radius=0.7,
                      seed=0)
model = make_sim_model("svm", data.feature_dim, data.num_classes)

# Theorem-2 compliant schedules: eta_t = gamma/(t+alpha) with
# gamma > 1/mu (mu = 0.1), eps^(t) = eta_t * phi via adaptive Gamma.
algo = TTHFConfig(tau=10, consensus_every=5, gamma_d2d=-1, phi=0.05,
                  gamma=20.0, alpha=1000.0)
tr = TTHFTrainer(model, data, topo, algo, batch_size=16)
print(f"network: {devices} devices, {clusters} clusters, "
      f"avg lambda={tr.net.lambdas.mean():.3f}")
_, hist = tr.run(steps=args.steps, eval_every=max(args.steps // 10, 1))

ts = np.asarray(hist.ts, float)
loss = np.asarray(hist.global_loss)
gap = loss - (loss.min() - 1e-3)
nu = gap[0] * (ts[0] + algo.alpha)
env = bound_curve(1.5 * nu, algo.alpha, ts)

print(f"\n{'t':>6s} {'loss':>9s} {'acc':>7s} {'gap':>9s} "
      f"{'nu/(t+a)':>9s} {'Gamma_c (mean)':>14s}")
for i, t in enumerate(ts):
    g = np.mean(hist.gamma_used[i])
    print(f"{int(t):6d} {loss[i]:9.4f} {hist.global_acc[i]:7.3f} "
          f"{gap[i]:9.4f} {env[i]:9.4f} {g:14.1f}")

print(f"\nuplinks={tr.ledger.uplinks} (cluster-sampled; full participation "
      f"would be {tr.ledger.uplinks * topo.cluster_size})")
print(f"d2d messages={tr.ledger.d2d_msgs}, d2d rounds={tr.ledger.d2d_rounds}")
print(f"energy @ E_D2D/E_Glob=0.1: {tr.ledger.energy(0.1):.2f} J; "
      f"delay @ 0.1: {tr.ledger.delay(0.1):.1f} s")
print("O(1/t) envelope holds:", bool((gap[1:] <= env[1:]).all()))
