"""jit'd public wrappers for the Pallas kernels.

Off-TPU the kernels run in ``interpret=True`` mode (the kernel body
executes as traced jnp ops); on a real TPU backend they compile via
Mosaic. ``INTERPRET`` is auto-detected once per process by
``repro.kernels.runtime.default_interpret`` — a kernel module imported
directly (bypassing these wrappers) auto-detects the same way, so a TPU
caller can no longer silently run interpreted. Wrappers handle padding
and expose oracle-identical signatures so call-sites can swap
kernel <-> ref freely.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import fused_consensus_sgd as _fcs
from repro.kernels import fused_sgd as _fs
from repro.kernels import ssd_scan as _ss
from repro.kernels import ref
from repro.kernels.runtime import default_interpret

# Auto-detected: True off-TPU (interpret mode), False on real TPUs.
# Still assignable for tests/benches that force one mode.
INTERPRET = default_interpret()


def consensus_mix(z: jax.Array, V: jax.Array, gamma: jax.Array,
                  blk_m: int = 512) -> jax.Array:
    """D2D mixing via the unified engine's Pallas backend
    (``repro.core.mixing``; honors this module's INTERPRET flag)."""
    from repro.core import mixing
    return mixing.mix(z, V, gamma, backend="pallas", blk_m=blk_m)


def ssd_scan(x: jax.Array, dt: jax.Array, loga: jax.Array, B: jax.Array,
             C: jax.Array, chunk: int = 256):
    """Pads T to a chunk multiple, calls the kernel, trims."""
    T = x.shape[1]
    pad = (-T) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        loga = jnp.pad(loga, ((0, 0), (0, pad)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y, h = _ss.ssd_scan(x, dt, loga, B, C, chunk=chunk, interpret=INTERPRET)
    return (y[:, :T], h) if pad else (y, h)


def fused_sgd(w: jax.Array, g: jax.Array, eta, weight_decay: float = 0.0
              ) -> jax.Array:
    return _fs.fused_sgd(w, g, eta, weight_decay=weight_decay,
                         interpret=INTERPRET)


def fused_consensus_sgd(w: jax.Array, g: jax.Array, W: jax.Array, eta,
                        weight_decay: float = 0.0) -> jax.Array:
    """Fused last-microstep SGD + W-mixing; w, g: (N, s, M), W: (N, s, s)."""
    return _fcs.fused_consensus_sgd(w, g, W, eta,
                                    weight_decay=weight_decay,
                                    interpret=INTERPRET)


__all__ = ["consensus_mix", "ssd_scan", "fused_sgd",
           "fused_consensus_sgd", "ref", "INTERPRET"]
