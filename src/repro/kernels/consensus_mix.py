"""Pallas TPU kernel: fused Gamma-round D2D consensus mixing.

Computes ``z_c <- V_c^{gamma_c} z_c`` for N stacked clusters without
round-tripping intermediates through HBM: the (s, s) mixing matrix and an
(s, blk_m) parameter tile are pinned in VMEM and the Gamma-round loop of
small MXU matmuls runs in registers/VMEM. HBM traffic drops from
``2 * Gamma * s * M`` words (the naive per-round einsum) to ``2 * s * M``
— a Gamma-fold cut, and Remark 1 routinely asks for Gamma in the tens.

Grid: (N, M / blk_m); gamma is a scalar-prefetch operand so each cluster
can run a *different* (aperiodic, Remark-1) round count.

TPU notes: blk_m defaults to 512 lanes (4 x 128); s is the cluster size
(tiny, e.g. 5) — Mosaic pads the sublane dim to 8. The matmul chain
accumulates in fp32 via preferred_element_type regardless of z dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret


def _kernel(gamma_ref, z_ref, v_ref, o_ref):
    n = pl.program_id(0)
    gamma_n = gamma_ref[n]
    v = v_ref[0].astype(jnp.float32)          # (s, s)
    z0 = z_ref[0].astype(jnp.float32)         # (s, blk_m)

    def body(_, z):
        return jnp.dot(v, z, preferred_element_type=jnp.float32)

    z = jax.lax.fori_loop(0, gamma_n, body, z0)
    o_ref[0] = z.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk_m", "interpret"))
def consensus_mix(z: jax.Array, V: jax.Array, gamma: jax.Array,
                  blk_m: int = 512,
                  interpret: Optional[bool] = None) -> jax.Array:
    """z: (N, s, M), V: (N, s, s), gamma: (N,) int32.

    ``interpret=None`` auto-detects (interpret only off-TPU)."""
    interpret = resolve_interpret(interpret)
    N, s, M = z.shape
    gamma = jnp.asarray(gamma, jnp.int32)
    if gamma.ndim == 0:
        gamma = jnp.full((N,), gamma)

    blk = min(blk_m, max(M, 1))
    pad = (-M) % blk
    zp = jnp.pad(z, ((0, 0), (0, 0), (0, pad))) if pad else z
    Mp = M + pad

    grid = (N, Mp // blk)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, s, blk), lambda n, m, g: (n, 0, m)),
                pl.BlockSpec((1, s, s), lambda n, m, g: (n, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, s, blk), lambda n, m, g: (n, 0, m)),
        ),
        out_shape=jax.ShapeDtypeStruct((N, s, Mp), z.dtype),
        interpret=interpret,
        name="consensus_mix",
    )(gamma, zp, V)
    return out[:, :, :M] if pad else out
