"""Pure-jnp oracles for every Pallas kernel (the correctness ground
truth; tests sweep shapes/dtypes against these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def consensus_mix_ref(z: jax.Array, V: jax.Array,
                      gamma: jax.Array) -> jax.Array:
    """z: (N, s, M); V: (N, s, s); gamma: (N,) int32 -> V_c^{gamma_c} z_c.

    Reference: explicit per-round einsum with per-cluster masking.
    gamma must be CONCRETE (the loop unrolls in Python) — it is read
    through numpy so the oracle also works on constants inside a jit
    trace; traced gamma raises TracerArrayConversionError.
    """
    import numpy as np
    gamma = np.asarray(gamma, np.int32)
    max_gamma = int(gamma.max()) if gamma.size else 0

    out = z.astype(jnp.float32)
    Vf = V.astype(jnp.float32)
    for r in range(max_gamma):
        mixed = jnp.einsum("nij,njm->nim", Vf, out)
        keep = jnp.asarray((r < gamma)[:, None, None])
        out = jnp.where(keep, mixed, out)
    return out.astype(z.dtype)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, loga: jax.Array,
                 B: jax.Array, C: jax.Array,
                 h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Mamba-2 SSD recurrence, sequential reference.

    x:    (BH, T, P)   per-head inputs
    dt:   (BH, T)      input gates (discretization steps, > 0)
    loga: (BH, T)      log decay per step (= dt * A_head, < 0)
    B:    (BH, T, S)   input projections onto the state
    C:    (BH, T, S)   output projections
    h0:   (BH, S, P)   initial state (zeros if None)

    returns y: (BH, T, P), h_final: (BH, S, P)

      h_t = exp(loga_t) * h_{t-1} + dt_t * B_t (x) x_t
      y_t = C_t @ h_t
    """
    BH, T, P = x.shape
    S = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((BH, S, P), jnp.float32)

    def step(h, inp):
        xt, dtt, lat, bt, ct = inp
        h = jnp.exp(lat)[:, None, None] * h + \
            dtt[:, None, None] * bt[:, :, None] * xt[:, None, :]
        y = jnp.einsum("bs,bsp->bp", ct, h)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(loga, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32))
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    return y, h_final


def fused_sgd_ref(w: jax.Array, g: jax.Array, eta: jax.Array,
                  weight_decay: float = 0.0) -> jax.Array:
    """w <- w - eta * (g + wd * w)."""
    gg = g.astype(jnp.float32) + weight_decay * w.astype(jnp.float32)
    return (w.astype(jnp.float32) - eta * gg).astype(w.dtype)


def fused_consensus_sgd_ref(w: jax.Array, g: jax.Array, W: jax.Array,
                            eta: jax.Array,
                            weight_decay: float = 0.0) -> jax.Array:
    """W_c @ (w_c - eta * (g_c + wd * w_c)); w, g: (N, s, M), W: (N, s, s)."""
    wp = fused_sgd_ref(w, g, eta, weight_decay=weight_decay)
    return jnp.einsum("nij,njm->nim", W.astype(jnp.float32),
                      wp.astype(jnp.float32),
                      preferred_element_type=jnp.float32).astype(w.dtype)
