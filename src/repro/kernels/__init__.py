"""Pallas TPU kernels for the compute hot-spots:

* ``consensus_mix`` — fused Gamma-round D2D mixing (the paper's hot loop)
* ``ssd_scan``      — Mamba-2 SSD chunked scan (mamba2/long-context)
* ``fused_sgd``     — fused parameter update for the tau-step local scan
* ``paged_attn``    — paged decode attention over a scalar-prefetched
  page map (the serving engine's block cache, DESIGN.md §15)

Each kernel ships with a pure-jnp oracle in ``ref.py`` and a jit wrapper
in ``ops.py``; tests assert allclose across shape/dtype sweeps in
interpret mode.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
