"""Pallas TPU kernel: paged decode attention (one query token per slot).

The jnp reference path in ``models.attention.paged_decode_attention``
gathers every slot's pages into a contiguous (B, S, K, hd) buffer and
runs a masked softmax — an HBM round-trip of the whole working set per
step. This kernel instead walks the page list with a scalar-prefetched
page map: grid = (slot, page_index), the BlockSpec index_map reads
``page_map[b, j]`` to DMA exactly one (page_size, K, hd) page per step,
and an online-softmax accumulator in VMEM scratch carries the partial
attention across a slot's pages (same flash-decode recurrence as
``models.attention.flash_attention``).

Masking is positional: page ``j`` holds absolute positions
``[j*page_size, (j+1)*page_size)``; entries beyond ``pos[b]`` (or
outside the sliding band) are NEG_INF'd, so dummy-page garbage never
contributes. Runs in ``interpret=True`` off-TPU via
``runtime.resolve_interpret`` like every kernel in this package.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _paged_decode_kernel(pm_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, page_size: int,
                         pages_per_slot: int, window: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                 # (K, G, hd)
    k = k_ref[0].astype(jnp.float32)                 # (ps, K, hd)
    v = v_ref[0].astype(jnp.float32)
    hd = q.shape[-1]

    s = jnp.einsum("kgh,skh->kgs", q * hd ** -0.5, k,
                   preferred_element_type=jnp.float32)   # (K, G, ps)
    pos = pos_ref[b]
    k_pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (page_size,), 0)
    valid = k_pos <= pos
    if window:
        valid = valid & (k_pos > pos - window)
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc_new = acc_ref[...] * alpha[..., None] + jnp.einsum(
        "kgs,skh->kgh", p, v, preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(j == pages_per_slot - 1)
    def _finish():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l_safe[..., None]).astype(o_ref.dtype)


def paged_decode(q, k_pages, v_pages, page_map, pos, *, window: int = 0,
                 interpret: Optional[bool] = None):
    """Paged single-token attention.

    q: (B, K, G, hd); k_pages/v_pages: (num_pages, page_size, K, hd);
    page_map: (B, pages_per_slot) int32; pos: (B,) int32. Returns the
    softmax-weighted values (B, K, G, hd) in fp32 (caller projects).
    """
    interpret = resolve_interpret(interpret)
    B, K, G, hd = q.shape
    _, ps = k_pages.shape[:2]
    P = page_map.shape[1]
    kern = functools.partial(_paged_decode_kernel, page_size=ps,
                             pages_per_slot=P, window=int(window))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # page_map, pos
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, K, G, hd), lambda b, j, pm, pos: (b, 0, 0, 0)),
            pl.BlockSpec((1, ps, K, hd),
                         lambda b, j, pm, pos: (pm[b, j], 0, 0, 0)),
            pl.BlockSpec((1, ps, K, hd),
                         lambda b, j, pm, pos: (pm[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, K, G, hd),
                               lambda b, j, pm, pos: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((K, G, hd), jnp.float32),
            pltpu.VMEM((K, G), jnp.float32),
            pltpu.VMEM((K, G), jnp.float32),
        ],
    )
    fn = pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), jnp.float32),
        interpret=interpret)
    return fn(page_map.astype(jnp.int32), pos.astype(jnp.int32),
              q.astype(jnp.float32), k_pages, v_pages)


__all__ = ["paged_decode"]
