"""Pallas TPU kernel: fused SGD update  w <- w - eta * (g + wd * w).

Trivial arithmetic, but fusing the schedule multiply + weight decay +
subtract into one pass halves parameter-stream HBM traffic inside the
tau-step TT-HF local scan (read w, read g, write w — vs an extra
round-trip for the scaled gradient).

Grid: 1-D over flattened, lane-padded parameter tiles. The flat size is
padded up to a lane multiple (128) ONCE so every block is lane-aligned —
a small leaf (n < 128) used to produce a non-lane-multiple block that
Mosaic would have to re-tile.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

LANE = 128


def _kernel(w_ref, g_ref, eta_ref, o_ref, *, weight_decay: float):
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    if weight_decay:
        g = g + weight_decay * w
    o_ref[...] = (w - eta_ref[0] * g).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("weight_decay", "blk", "interpret"))
def fused_sgd(w: jax.Array, g: jax.Array, eta: jax.Array,
              weight_decay: float = 0.0, blk: int = 65_536,
              interpret: Optional[bool] = None) -> jax.Array:
    """Flat or shaped arrays; returns updated w with the same shape.

    ``interpret=None`` auto-detects (interpret only off-TPU)."""
    interpret = resolve_interpret(interpret)
    shape, dtype = w.shape, w.dtype
    wf, gf = w.reshape(-1), g.reshape(-1)
    n = wf.size
    # lane-align once: blk is always a multiple of LANE, and the single
    # pad (on both streamed operands) rounds n up to a blk multiple
    blk = max(LANE, min(blk, -(-n // LANE) * LANE))
    assert blk % LANE == 0
    pad = (-n) % blk
    if pad:
        wf = jnp.pad(wf, (0, pad))
        gf = jnp.pad(gf, (0, pad))
    eta_arr = jnp.asarray(eta, jnp.float32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_kernel, weight_decay=weight_decay),
        grid=(wf.size // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((wf.size,), dtype),
        interpret=interpret,
        name="fused_sgd",
    )(wf, gf, eta_arr)
    return out[:n].reshape(shape)
