"""Pallas TPU kernel: Mamba-2 SSD chunked scan (state-space duality).

The SSD recurrence  h_t = a_t h_{t-1} + dt_t B_t (x) x_t ;  y_t = C_t h_t
is evaluated in chunks of Q tokens (arXiv:2405.21060):

  intra-chunk:  Y += (L o (C B^T) o dt) X        -- quadratic in Q, MXU
  inter-chunk:  Y += (C o exp(l)) H_prev         -- state broadcast
  state carry:  H  = exp(l_Q) H_prev + (B o exp(l_Q - l) o dt)^T X

where l is the in-chunk cumulative log decay. The running state H lives
in a VMEM scratch buffer that persists across the chunk axis of the grid
(minor-most => sequential), so HBM sees each token exactly once in and
once out — the memory-optimal schedule for a recurrent scan on TPU.

Grid: (BH, T/Q). Block shapes: X (Q, P), B/C (Q, S), decay rows (1, Q);
defaults Q=256, S=128, P=64 keep the working set ~0.6 MB << 16 MB VMEM
and all matmul dims MXU-aligned (Q, S multiples of 128; P=64 packs the
lane dim at half utilization, the native Mamba-2 head size).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, loga_ref, b_ref, c_ref, y_ref, hfin_ref, h_scr):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    la = loga_ref[0].astype(jnp.float32)      # (Q,)
    b = b_ref[0].astype(jnp.float32)          # (Q, S)
    c = c_ref[0].astype(jnp.float32)          # (Q, S)

    l = jnp.cumsum(la)                        # inclusive cumulative log decay
    q = x.shape[0]

    # intra-chunk: M[t,u] = exp(l_t - l_u) * dt_u  for u <= t
    g = jnp.dot(c, b.T, preferred_element_type=jnp.float32)   # (Q, Q)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    causal = t_idx >= u_idx
    # clamp to <= 0: exact on causal entries (l is non-increasing) and
    # keeps the masked half from overflowing exp (inf * 0 = nan in the
    # backward pass)
    decay = jnp.exp(jnp.minimum(l[:, None] - l[None, :], 0.0))
    m = jnp.where(causal, g * decay * dt[None, :], 0.0)
    y = jnp.dot(m, x, preferred_element_type=jnp.float32)     # (Q, P)

    # inter-chunk: contribution of the carried state
    h = h_scr[...]                                            # (S, P)
    c_decayed = c * jnp.exp(l)[:, None]
    y = y + jnp.dot(c_decayed, h, preferred_element_type=jnp.float32)

    # state update
    total = l[q - 1]
    b_decayed = b * (jnp.exp(total - l) * dt)[:, None]        # (Q, S)
    h_new = jnp.exp(total) * h + jnp.dot(
        b_decayed.T, x, preferred_element_type=jnp.float32)
    h_scr[...] = h_new

    y_ref[0] = y.astype(y_ref.dtype)
    hfin_ref[0] = h_new.astype(hfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, loga: jax.Array, B: jax.Array,
             C: jax.Array, chunk: int = 256,
             interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """x: (BH, T, P), dt/loga: (BH, T), B/C: (BH, T, S).

    Returns (y: (BH, T, P), h_final: (BH, S, P)). T must be a multiple
    of ``chunk`` (ops.py pads).
    """
    from repro.kernels.runtime import resolve_interpret
    interpret = resolve_interpret(interpret)
    BH, T, P = x.shape
    S = B.shape[-1]
    assert T % chunk == 0, f"T={T} not a multiple of chunk={chunk}"
    nc = T // chunk

    y, hfin = pl.pallas_call(
        _kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk), lambda bh, c: (bh, c)),
            pl.BlockSpec((1, chunk), lambda bh, c: (bh, c)),
            pl.BlockSpec((1, chunk, S), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, S), lambda bh, c: (bh, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, S, P), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, P), x.dtype),
            jax.ShapeDtypeStruct((BH, S, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((S, P), jnp.float32)],
        interpret=interpret,
        name="ssd_scan",
    )(x, dt, loga, B, C)
    return y, hfin
