"""Shared execution-mode detection for every Pallas kernel.

The kernels run in ``interpret=True`` mode off-TPU (the kernel body
executes as traced jnp ops) and compiled via Mosaic on real TPUs.
Historically each kernel module hard-coded ``interpret: bool = True``
as its own default, independent of ``ops.INTERPRET`` — a TPU caller
importing a kernel directly would silently run interpreted. Every
kernel now defaults to :func:`default_interpret` through one helper.

``ops.INTERPRET`` remains the session-wide switch (tests monkeypatch
it); kernel entry points take ``interpret=None`` -> auto-detect.
"""
from __future__ import annotations

import functools
from typing import Optional


@functools.lru_cache(maxsize=1)
def default_interpret() -> bool:
    """True off-TPU (interpret mode), False on a real TPU backend.

    Cached: ``jax.default_backend()`` initializes the backend, and the
    answer cannot change within a process.
    """
    import jax
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` -> auto-detect; a concrete bool wins (tests/benches)."""
    return default_interpret() if interpret is None else bool(interpret)


__all__ = ["default_interpret", "resolve_interpret"]
