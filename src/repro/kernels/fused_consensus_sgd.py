"""Pallas TPU kernel: fused last-microstep SGD + D2D consensus mixing.

One consensus block of the TT-HF interval ends with an SGD update
followed by the block-diagonal mixing einsum ``z_c <- W_c z_c`` (the
``fused_power`` backend's precomputed ``W = V^Gamma``). Run separately
those are two full parameter-stream HBM passes: read w / read g /
write w, then read w / write w. This kernel fuses them into ONE pass —
read w, read g, write mixed w — over the lane-padded flat ``(R, P)``
replica buffer of the fused-interval step
(:func:`repro.core.distributed.make_tthf_train_step` with
``fused_interval=True``).

Math (bitwise-matching the reference path, asserted in
``tests/test_fused_interval.py``):

    w' = w - eta * (g + wd * w)          (per replica, f32 accumulate)
    z_c <- W_c @ w'_c                    (per cluster, s x s MXU matmul)

Grid: (N, M / blk_m). The (s, s) mixing block and an (s, blk_m) tile
of w and g are pinned in VMEM; each column of the tile mixes
independently, so lane-padding between pytree leaves is harmless
(zeros map to zeros).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

LANE = 128


def _kernel(w_ref, g_ref, mix_ref, eta_ref, o_ref, *,
            weight_decay: float):
    w = w_ref[0].astype(jnp.float32)          # (s, blk)
    g = g_ref[0].astype(jnp.float32)
    if weight_decay:
        g = g + weight_decay * w
    wp = w - eta_ref[0] * g
    mixed = jnp.dot(mix_ref[0].astype(jnp.float32), wp,
                    preferred_element_type=jnp.float32)
    o_ref[0] = mixed.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("weight_decay", "blk_m", "interpret"))
def fused_consensus_sgd(w: jax.Array, g: jax.Array, W: jax.Array,
                        eta: jax.Array, weight_decay: float = 0.0,
                        blk_m: Optional[int] = None,
                        interpret: Optional[bool] = None) -> jax.Array:
    """w, g: (N, s, M); W: (N, s, s); returns ``W @ (w - eta*g)``.

    ``interpret=None`` auto-detects (interpret only off-TPU).
    ``blk_m=None`` picks 4096 lanes compiled (VMEM-sized for small s)
    and 65536 interpreted (fewer unrolled grid cells).
    """
    interpret = resolve_interpret(interpret)
    if blk_m is None:
        blk_m = 65_536 if interpret else 4_096
    N, s, M = w.shape
    assert g.shape == (N, s, M) and W.shape == (N, s, s)

    # lane-align once: blk is a LANE multiple, M padded to a blk multiple
    blk = max(LANE, min(blk_m, -(-M // LANE) * LANE))
    pad = (-M) % blk
    if pad:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, pad)))
        g = jnp.pad(g, ((0, 0), (0, 0), (0, pad)))
    Mp = M + pad
    eta_arr = jnp.asarray(eta, jnp.float32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_kernel, weight_decay=weight_decay),
        grid=(N, Mp // blk),
        in_specs=[
            pl.BlockSpec((1, s, blk), lambda n, m: (n, 0, m)),
            pl.BlockSpec((1, s, blk), lambda n, m: (n, 0, m)),
            pl.BlockSpec((1, s, s), lambda n, m: (n, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, s, blk), lambda n, m: (n, 0, m)),
        out_shape=jax.ShapeDtypeStruct((N, s, Mp), w.dtype),
        interpret=interpret,
        name="fused_consensus_sgd",
    )(w, g, W, eta_arr)
    return out[:, :, :M] if pad else out
