"""Shared model-zoo building blocks: params-with-logical-axes, norms,
embeddings, initializers.

Parameters are plain pytrees of arrays. Sharding is expressed by a
*parallel* pytree of logical-axis tuples produced at init time: every
init function returns ``Px(array, logical_axes)`` leaves; ``split_tree``
separates them into (params, axes). ``dist.sharding`` maps logical axes
to mesh axes.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class Px(NamedTuple):
    """A parameter leaf bundled with its logical sharding axes."""
    value: Any
    axes: tuple


def split_tree(tree):
    """Pytree of Px -> (params, logical_axes) with identical structure."""
    is_px = lambda x: isinstance(x, Px)
    params = jax.tree.map(lambda p: p.value, tree, is_leaf=is_px)
    axes = jax.tree.map(lambda p: tuple(p.axes), tree, is_leaf=is_px)
    return params, axes


# ---------------------------------------------------------------------------
# initializers (operate on key, produce Px)
# ---------------------------------------------------------------------------

def dense_init(key, shape, axes, dtype=jnp.float32, scale: float = 1.0,
               fan_in: int | None = None) -> Px:
    fan = fan_in if fan_in is not None else shape[0]
    std = scale / np.sqrt(max(fan, 1))
    return Px(jax.random.normal(key, shape, dtype) * jnp.asarray(std, dtype),
              axes)


def embed_init(key, vocab, dim, axes, dtype=jnp.float32) -> Px:
    return Px(jax.random.normal(key, (vocab, dim), dtype) * 0.02, axes)


def zeros_init(shape, axes, dtype=jnp.float32) -> Px:
    return Px(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype=jnp.float32) -> Px:
    return Px(jnp.ones(shape, dtype), axes)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    # gemma convention: multiply by (1 + scale)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


def norm_init(key, cfg, dim: int) -> dict:
    if cfg.norm == "rmsnorm":
        return {"scale": zeros_init((dim,), ("embed_nomodel",))}
    return {"scale": ones_init((dim,), ("embed_nomodel",)),
            "bias": zeros_init((dim,), ("embed_nomodel",))}


def apply_norm(cfg, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array,
         theta: float = 10_000.0) -> jax.Array:
    """Rotary embeddings. x: (..., T, n, hd); positions: (..., T)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq   # (..., T, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_positions(T: int, dim: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (T, dim)."""
    half = dim // 2
    freq = jnp.exp(-np.log(10_000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = jnp.arange(T)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL; logits (..., V) possibly vocab-sharded (XLA inserts
    the collectives), labels int (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)
