"""Simulation-mode models (paper Sec. IV-A).

* ``svm``: regularized (squared-hinge) multiclass SVM — mu-strongly
  convex + beta-smooth, the regime of Assumption 1 / Theorem 2.
* ``nn``: one-hidden-layer fully-connected network (paper: 7840 neurons;
  configurable — benches default to a smaller width on CPU, noted in
  EXPERIMENTS.md).

Interface: ``init(key) -> params``, ``loss(params, x, y) -> scalar``,
``accuracy(params, x, y)``. Params are pytrees; devices stack them on a
leading axis and the TT-HF engine vmaps.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SimModel:
    init: Callable
    loss: Callable          # (params, x, y) -> scalar
    predict: Callable       # (params, x) -> (B, C) scores
    reg: float
    name: str

    def accuracy(self, params, x, y) -> jax.Array:
        pred = jnp.argmax(self.predict(params, x), axis=-1)
        return jnp.mean((pred == y).astype(jnp.float32))


def svm(dim: int, num_classes: int, reg: float = 0.1) -> SimModel:
    """Multiclass squared-hinge SVM with L2 regularization.

    loss = (1/B) sum_b sum_{c != y_b} max(0, 1 + s_c - s_y)^2 / C
           + (reg/2) ||W||^2
    Strongly convex with mu = reg; smooth (squared hinge is C^1 with
    Lipschitz gradient).
    """
    def init(key):
        kw, _ = jax.random.split(key)
        w = jax.random.normal(kw, (dim, num_classes)) * 0.01
        b = jnp.zeros((num_classes,))
        return {"w": w, "b": b}

    def predict(params, x):
        return x @ params["w"] + params["b"]

    def loss(params, x, y):
        s = predict(params, x)                      # (B, C)
        sy = jnp.take_along_axis(s, y[:, None], axis=1)  # (B, 1)
        margins = jnp.maximum(0.0, 1.0 + s - sy)
        margins = margins * (1 - jax.nn.one_hot(y, s.shape[-1]))
        data = jnp.mean(jnp.sum(margins ** 2, axis=-1)) / s.shape[-1]
        l2 = 0.5 * reg * (jnp.sum(params["w"] ** 2)
                          + jnp.sum(params["b"] ** 2))
        return data + l2

    return SimModel(init, loss, predict, reg, "svm")


def nn(dim: int, num_classes: int, hidden: int = 7840,
       reg: float = 1e-4) -> SimModel:
    """One-hidden-layer fully-connected net (paper: 7840 neurons)."""
    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "w1": jax.random.normal(k1, (dim, hidden)) * jnp.sqrt(2.0 / dim),
            "b1": jnp.zeros((hidden,)),
            "w2": jax.random.normal(k2, (hidden, num_classes))
                  * jnp.sqrt(1.0 / hidden),
            "b2": jnp.zeros((num_classes,)),
        }

    def predict(params, x):
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]

    def loss(params, x, y):
        logits = predict(params, x)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
        l2 = 0.5 * reg * sum(jnp.sum(p ** 2) for p in
                             (params["w1"], params["w2"]))
        return nll + l2

    return SimModel(init, predict=predict, loss=loss, reg=reg, name="nn")


def make_sim_model(name: str, dim: int, num_classes: int,
                   hidden: int = 7840) -> SimModel:
    if name == "svm":
        return svm(dim, num_classes)
    if name == "nn":
        return nn(dim, num_classes, hidden)
    raise ValueError(f"unknown sim model {name!r}")
