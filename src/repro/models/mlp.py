"""Feed-forward blocks: SwiGLU / GeGLU / plain GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Px, dense_init, zeros_init


def init_mlp(key, cfg, d_model: int | None = None) -> dict:
    d = d_model or cfg.d_model
    f = cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_variant in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d, f), ("embed", "ffn")),
            "w_up": dense_init(ks[1], (d, f), ("embed", "ffn")),
            "w_down": dense_init(ks[2], (f, d), ("ffn", "embed"), fan_in=f),
        }
    return {
        "w_up": dense_init(ks[0], (d, f), ("embed", "ffn")),
        "b_up": zeros_init((f,), ("ffn",)),
        "w_down": dense_init(ks[1], (f, d), ("ffn", "embed"), fan_in=f),
        "b_down": zeros_init((d,), ("embed_nomodel",)),
    }


def apply_mlp(p, cfg, x: jax.Array) -> jax.Array:
    dt = x.dtype
    if cfg.mlp_variant in ("swiglu", "geglu"):
        g = x @ p["w_gate"].astype(dt)
        u = x @ p["w_up"].astype(dt)
        act = jax.nn.silu(g) if cfg.mlp_variant == "swiglu" \
            else jax.nn.gelu(g, approximate=True)
        return (act * u) @ p["w_down"].astype(dt)
    h = jax.nn.gelu(x @ p["w_up"].astype(dt) + p["b_up"].astype(dt),
                    approximate=True)
    return h @ p["w_down"].astype(dt) + p["b_down"].astype(dt)
