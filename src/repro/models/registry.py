"""Model registry: the public API surface of the model zoo.

``build_model(cfg)`` returns a :class:`ModelApi` whose functions are
pure (params explicit) and jit-friendly. ``abstract_params`` captures
both parameter ShapeDtypeStructs and the logical-axes tree WITHOUT
allocating (the Px axes are Python constants, collected during an
``eval_shape`` trace via a side channel).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer as tfm
from repro.models.common import split_tree
from repro.serving import engine as serve


@dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig

    # -- params ---------------------------------------------------------
    def init(self, key, dtype=jnp.float32):
        tree = tfm.init_model(key, self.cfg, dtype)
        params, _ = split_tree(tree)
        if dtype != jnp.float32:
            params = jax.tree.map(lambda x: x.astype(dtype), params)
        return params

    def abstract_params(self, dtype=jnp.float32):
        """(ShapeDtypeStruct tree, logical-axes tree) — no allocation."""
        captured = {}

        def wrapper(key):
            tree = tfm.init_model(key, self.cfg, jnp.float32)
            params, axes = split_tree(tree)
            captured["axes"] = axes
            if dtype != jnp.float32:
                params = jax.tree.map(lambda x: x.astype(dtype), params)
            return params

        shapes = jax.eval_shape(wrapper, jax.random.PRNGKey(0))
        return shapes, captured["axes"]

    # -- training -------------------------------------------------------
    def loss(self, params, batch, *, dtype=jnp.bfloat16, remat=True,
             use_pallas=False):
        return tfm.loss_fn(params, self.cfg, batch, dtype=dtype,
                           remat=remat, use_pallas=use_pallas)

    def forward(self, params, batch, *, dtype=jnp.bfloat16, remat=True,
                use_pallas=False):
        return tfm.forward(params, self.cfg, batch, dtype=dtype,
                           remat=remat, use_pallas=use_pallas)

    # -- serving --------------------------------------------------------
    def prefill(self, params, batch, *, dtype=jnp.bfloat16,
                cache_dtype=jnp.bfloat16, serve_window=0, remat=True,
                cache_len=None, lengths=None):
        return serve.prefill(params, self.cfg, batch, dtype=dtype,
                             cache_dtype=cache_dtype,
                             serve_window=serve_window, remat=remat,
                             cache_len=cache_len, lengths=lengths)

    def write_cache_slot(self, cache, one_cache, slot, *, pos=None,
                         one_pos=None, cache_rules=None):
        return serve.write_cache_slot(self.cfg, cache, one_cache, slot,
                                      pos=pos, one_pos=one_pos,
                                      cache_rules=cache_rules)

    def decode_step(self, params, token, cache, pos, *, dtype=jnp.bfloat16,
                    serve_window=0):
        return serve.decode_step(params, self.cfg, token, cache, pos,
                                 dtype=dtype, serve_window=serve_window)

    def init_cache(self, batch, seq_len, dtype=jnp.bfloat16,
                   serve_window=0, mesh=None, cache_rules=None):
        return serve.init_cache_tree(self.cfg, batch, seq_len, dtype,
                                     serve_window=serve_window,
                                     mesh=mesh, cache_rules=cache_rules)

    def abstract_cache(self, batch, seq_len, dtype=jnp.bfloat16,
                       serve_window=0):
        return jax.eval_shape(
            lambda: serve.init_cache_tree(self.cfg, batch, seq_len, dtype,
                                          serve_window=serve_window))

    def cache_axes(self, long_context: bool = False):
        return serve.cache_logical_axes_tree(self.cfg, long_context)

    # -- paged serving (DESIGN.md §15) ----------------------------------
    def prefill_chunk(self, params, cache, tokens, start, valid, page_row,
                      slot, *, dtype=jnp.float32, serve_window=0):
        return serve.prefill_chunk(params, self.cfg, cache, tokens, start,
                                   valid, page_row, slot, dtype=dtype,
                                   serve_window=serve_window)

    def decode_step_paged(self, params, token, cache, pos, page_map, live,
                          *, dtype=jnp.bfloat16, serve_window=0,
                          use_kernel=False):
        return serve.decode_step_paged(params, self.cfg, token, cache, pos,
                                       page_map, live, dtype=dtype,
                                       serve_window=serve_window,
                                       use_kernel=use_kernel)

    def init_paged_cache(self, slots, num_pages, page_size,
                         dtype=jnp.bfloat16, mesh=None, cache_rules=None):
        return serve.init_paged_cache_tree(self.cfg, slots, num_pages,
                                           page_size, dtype, mesh=mesh,
                                           cache_rules=cache_rules)

    def abstract_paged_cache(self, slots, num_pages, page_size,
                             dtype=jnp.bfloat16):
        return jax.eval_shape(
            lambda: serve.init_paged_cache_tree(self.cfg, slots, num_pages,
                                                page_size, dtype))

    def paged_cache_axes(self):
        return serve.paged_cache_logical_axes_tree(self.cfg)

    # -- abstract inputs (dry-run) ---------------------------------------
    def input_specs(self, shape: InputShape, *, serve_window: int = 0,
                    cache_dtype=jnp.bfloat16) -> dict:
        """ShapeDtypeStruct stand-ins for every input of the step that
        ``shape`` exercises (train/prefill: token batch [+ stub frontend
        embeddings]; decode: one token + the full cache + pos)."""
        cfg = self.cfg
        B, T = shape.global_batch, shape.seq_len
        i32 = jnp.int32

        def frontend(specs, batch_sz, txt_len):
            if cfg.kind == "vlm":
                specs["patches"] = jax.ShapeDtypeStruct(
                    (batch_sz, cfg.enc_seq_len, cfg.d_model), jnp.bfloat16)
            if cfg.kind in ("encdec", "audio"):
                specs["frames"] = jax.ShapeDtypeStruct(
                    (batch_sz, cfg.enc_seq_len, cfg.d_model), jnp.bfloat16)
            return specs

        if shape.phase == "train":
            t_text = T - (cfg.enc_seq_len if cfg.kind == "vlm" else 0)
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, t_text), i32),
                "labels": jax.ShapeDtypeStruct((B, t_text), i32),
            }
            return {"batch": frontend(specs, B, t_text)}

        if shape.phase == "prefill":
            t_text = T - (cfg.enc_seq_len if cfg.kind == "vlm" else 0)
            specs = {"tokens": jax.ShapeDtypeStruct((B, t_text), i32)}
            return {"batch": frontend(specs, B, t_text)}

        # decode: one token against a cache of length T
        cache = self.abstract_cache(B, T, cache_dtype,
                                    serve_window=serve_window)
        return {
            "token": jax.ShapeDtypeStruct((B, 1), i32),
            "cache": cache,
            "pos": jax.ShapeDtypeStruct((), i32),
        }


def build_model(cfg: ModelConfig) -> ModelApi:
    return ModelApi(cfg)
