"""Transformer stacks for every assigned architecture family.

Design notes
------------
* **Scan over layers.** Per-layer parameters are stacked on a leading
  ``layers`` axis and the stack runs under ``jax.lax.scan`` — compact
  HLO (one layer body) so the 48-layer/512-device dry-runs compile
  quickly, and the standard structure for activation rematerialization.
* **Heterogeneous stacks** (llama4's interleaved MoE, RecurrentGemma's
  2-recurrent:1-attention pattern) scan over *groups* — the smallest
  repeating unit — so no parameter space is wasted on union layouts.
* **Caches** are pytrees with the same leading ``layers``/``groups``
  axis, threaded through the scan during decode.

Every init function returns `Px(value, logical_axes)` leaves; the
registry splits them (`split_tree`) and captures the axes tree during an
`eval_shape` trace, so abstract init never allocates.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mlp as mlpm
from repro.models import moe as moem
from repro.models import rglru as rgm
from repro.models import ssm as ssmm
from repro.models.common import (
    Px, apply_norm, embed_init, norm_init, softmax_cross_entropy,
    sinusoidal_positions, split_tree,
)


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def init_dense_layer(key, cfg, *, use_moe: bool = False,
                     cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "ln_attn": norm_init(ks[0], cfg, cfg.d_model),
        "attn": attn.init_attention(ks[0], cfg),
        "ln_mlp": norm_init(ks[1], cfg, cfg.d_model),
    }
    p["moe" if use_moe else "mlp"] = (
        moem.init_moe(ks[1], cfg) if use_moe else mlpm.init_mlp(ks[1], cfg))
    if cross:
        p["ln_cross"] = norm_init(ks[2], cfg, cfg.d_model)
        p["cross"] = attn.init_attention(ks[3], cfg, cross=True)
    return p


def apply_dense_layer(p, cfg, x, *, mode="causal", window=0,
                      prefix_len=None, enc_out=None, positions=None):
    from repro.dist.sharding import hint
    x = hint(x, ("pod", "data"), None, None)   # batch stays data-sharded
    h = apply_norm(cfg, p["ln_attn"], x)
    h = attn.attention_block(p["attn"], cfg, h, mode=mode, window=window,
                             prefix_len=prefix_len, positions=positions)
    x = x + h
    aux = None
    if "cross" in p:
        h = apply_norm(cfg, p["ln_cross"], x)
        h = attn.attention_block(p["cross"], cfg, h, mode="full",
                                 kv_source=enc_out)
        x = x + h
    h = apply_norm(cfg, p["ln_mlp"], x)
    if "moe" in p:
        h, aux = moem.apply_moe(p["moe"], cfg, h)
    else:
        h = mlpm.apply_mlp(p["mlp"], cfg, h)
    return x + h, aux


def init_ssm_layer(key, cfg) -> dict:
    return {"ln": norm_init(key, cfg, cfg.d_model),
            "ssm": ssmm.init_ssm(key, cfg)}


def apply_ssm_layer(p, cfg, x, use_pallas=False):
    from repro.dist.sharding import hint
    x = hint(x, ("pod", "data"), None, None)
    return x + ssmm.apply_ssm(p["ssm"], cfg,
                              apply_norm(cfg, p["ln"], x),
                              use_pallas=use_pallas)


def init_rec_layer(key, cfg) -> dict:
    ks = jax.random.split(key, 2)
    return {"ln_rec": norm_init(ks[0], cfg, cfg.d_model),
            "rec": rgm.init_rglru(ks[0], cfg),
            "ln_mlp": norm_init(ks[1], cfg, cfg.d_model),
            "mlp": mlpm.init_mlp(ks[1], cfg)}


def apply_rec_layer(p, cfg, x):
    from repro.dist.sharding import hint
    x = hint(x, ("pod", "data"), None, None)
    x = x + rgm.apply_rglru(p["rec"], cfg, apply_norm(cfg, p["ln_rec"], x))
    return x + mlpm.apply_mlp(p["mlp"], cfg, apply_norm(cfg, p["ln_mlp"], x))


# ---------------------------------------------------------------------------
# stack init
# ---------------------------------------------------------------------------

def _stack(init_one: Callable, key, n: int):
    """vmap-stack n layer inits; Px axes handled by a capture trick:
    we init one layer for the axes structure (under eval_shape upstream
    this never materializes), and vmap the value-only init for params."""
    keys = jax.random.split(key, n)
    template = init_one(keys[0])
    _, axes = split_tree(template)

    def values_only(k):
        params, _ = split_tree(init_one(k))
        return params

    stacked = jax.vmap(values_only)(keys)
    axes = jax.tree.map(lambda a: ("layers",) + tuple(a), axes,
                        is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(lambda v, a: Px(v, a), stacked, axes,
                        is_leaf=lambda x: not isinstance(x, (dict,)))


def _scan_layers(body: Callable, x, stacked_params, remat: bool,
                 with_aux: bool = False):
    """Run ``body(layer_params, x) -> (x, aux)`` over the layer stack."""
    fn = jax.checkpoint(body) if remat else body

    def step(carry, lp):
        y, aux = fn(lp, carry)
        return y, aux

    x, auxs = jax.lax.scan(step, x, stacked_params)
    return (x, auxs) if with_aux else (x, None)


# ---------------------------------------------------------------------------
# the model: init
# ---------------------------------------------------------------------------

def init_model(key, cfg, dtype=jnp.float32) -> dict:
    """Full parameter tree (Px leaves) for any arch kind."""
    ks = jax.random.split(key, 8)
    V = cfg.padded_vocab
    # embedding d_model dim deliberately NOT fsdp-sharded: vocab/model
    # sharding already divides it 16x, and a data-sharded d dim makes
    # GSPMD all-gather activations instead of weights.
    p: dict[str, Any] = {
        "embed": embed_init(ks[0], V, cfg.d_model, ("vocab", "embed_nomodel")),
        "ln_final": norm_init(ks[1], cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = embed_init(ks[2], V, cfg.d_model,
                                  ("vocab", "embed_nomodel"))

    kind = cfg.kind
    if kind in ("dense", "vlm"):
        p["layers"] = _stack(lambda k: init_dense_layer(k, cfg),
                             ks[3], cfg.num_layers)
    elif kind == "moe":
        if cfg.moe_every == 1:
            p["layers"] = _stack(
                lambda k: init_dense_layer(k, cfg, use_moe=True),
                ks[3], cfg.num_layers)
        else:
            n_groups = cfg.num_layers // cfg.moe_every
            def group(k):
                kk = jax.random.split(k, cfg.moe_every)
                g = {f"dense_{i}": init_dense_layer(kk[i], cfg)
                     for i in range(cfg.moe_every - 1)}
                g["moe"] = init_dense_layer(kk[-1], cfg, use_moe=True)
                return g
            p["groups"] = _stack(group, ks[3], n_groups)
    elif kind == "ssm":
        p["layers"] = _stack(lambda k: init_ssm_layer(k, cfg),
                             ks[3], cfg.num_layers)
    elif kind == "hybrid":
        period = cfg.local_attn_every or 3
        n_groups = cfg.num_layers // period
        rem = cfg.num_layers - n_groups * period

        def group(k):
            kk = jax.random.split(k, period)
            g = {f"rec_{i}": init_rec_layer(kk[i], cfg)
                 for i in range(period - 1)}
            g["attn"] = init_dense_layer(kk[-1], cfg)
            return g
        if n_groups:
            p["groups"] = _stack(group, ks[3], n_groups)
        if rem:
            p["tail"] = _stack(lambda k: init_rec_layer(k, cfg), ks[4], rem)
    elif kind in ("encdec", "audio"):
        p["enc_layers"] = _stack(lambda k: init_dense_layer(k, cfg),
                                 ks[3], cfg.enc_num_layers)
        p["enc_ln_final"] = norm_init(ks[5], cfg, cfg.d_model)
        p["layers"] = _stack(
            lambda k: init_dense_layer(k, cfg, cross=True),
            ks[4], cfg.num_layers)
    else:
        raise ValueError(kind)
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_tokens(p, cfg, tokens, dtype):
    from repro.dist.sharding import hint
    x = jnp.take(p["embed"].astype(dtype), tokens, axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    return hint(x, ("pod", "data"), None, None)


def _unembed(p, cfg, x):
    from repro.dist.sharding import hint
    w = p["unembed"] if "unembed" in p else p["embed"]
    logits = jnp.einsum("btd,vd->btv", x, w.astype(x.dtype))
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    # keep the vocab dim model-sharded through the loss — materializing
    # replicated (B, T, V) logits is a multi-GB/device temp
    return hint(logits, ("pod", "data"), None, "model")


def forward(p, cfg, batch, *, dtype=jnp.bfloat16, remat: bool = True,
            use_pallas: bool = False):
    """Full-sequence forward -> (logits, aux_losses).

    batch: {"tokens": (B, T) int32, and per-frontend extras:
            "patches": (B, enc_seq, d) for vlm (stub vision output)
            "frames":  (B, enc_seq, d) for audio (stub codec output)}
    """
    kind = cfg.kind
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = _embed_tokens(p, cfg, tokens, dtype)
    mode, window, prefix_len = "causal", 0, None
    if cfg.sliding_window:
        mode, window = "sliding", cfg.sliding_window

    if kind == "vlm":
        # prefix-LM over [patch embeds | text]
        patches = batch["patches"].astype(dtype)
        x = jnp.concatenate([patches, x], axis=1)
        mode, prefix_len = "prefix", cfg.enc_seq_len

    enc_out = None
    if kind in ("encdec", "audio"):
        frames = batch["frames"].astype(dtype)
        pos = sinusoidal_positions(frames.shape[1], cfg.d_model).astype(dtype)
        h = frames + pos[None]
        def enc_body(lp, hh):
            y, _ = apply_dense_layer(lp, cfg, hh, mode="full")
            return y, None
        h, _ = _scan_layers(enc_body, h, p["enc_layers"], remat)
        enc_out = apply_norm(cfg, p["enc_ln_final"], h)
        if not cfg.rope:
            dpos = sinusoidal_positions(T, cfg.d_model).astype(dtype)
            x = x + dpos[None]

    aux = None
    if kind in ("dense", "vlm") or (kind == "moe" and cfg.moe_every == 1):
        def body(lp, xx):
            return apply_dense_layer(lp, cfg, xx, mode=mode, window=window,
                                     prefix_len=prefix_len)
        x, aux = _scan_layers(body, x, p["layers"], remat, with_aux=True)
    elif kind == "moe":
        def body(lp, xx):
            for i in range(cfg.moe_every - 1):
                xx, _ = apply_dense_layer(lp[f"dense_{i}"], cfg, xx,
                                          mode=mode, window=window)
            xx, a = apply_dense_layer(lp["moe"], cfg, xx, mode=mode,
                                      window=window)
            return xx, a
        x, aux = _scan_layers(body, x, p["groups"], remat, with_aux=True)
    elif kind == "ssm":
        def body(lp, xx):
            return apply_ssm_layer(lp, cfg, xx, use_pallas=use_pallas), None
        x, _ = _scan_layers(body, x, p["layers"], remat)
    elif kind == "hybrid":
        period = cfg.local_attn_every or 3
        def body(lp, xx):
            for i in range(period - 1):
                xx = apply_rec_layer(lp[f"rec_{i}"], cfg, xx)
            xx, _ = apply_dense_layer(lp["attn"], cfg, xx, mode="sliding",
                                      window=cfg.attention_window)
            return xx, None
        if "groups" in p:
            x, _ = _scan_layers(body, x, p["groups"], remat)
        if "tail" in p:
            def tail_body(lp, xx):
                return apply_rec_layer(lp, cfg, xx), None
            x, _ = _scan_layers(tail_body, x, p["tail"], remat)
    elif kind in ("encdec", "audio"):
        def body(lp, xx):
            return apply_dense_layer(lp, cfg, xx, mode="causal",
                                     enc_out=enc_out)
        x, _ = _scan_layers(body, x, p["layers"], remat)
    else:
        raise ValueError(kind)

    x = apply_norm(cfg, p["ln_final"], x)
    if kind == "vlm":
        x = x[:, cfg.enc_seq_len:]          # predict text positions only
    logits = _unembed(p, cfg, x)
    aux_losses = {}
    if aux is not None and isinstance(aux, dict) and "load_balance" in aux:
        aux_losses["load_balance"] = jnp.mean(aux["load_balance"])
        aux_losses["router_z"] = jnp.mean(aux["router_z"])
    return logits, aux_losses


def loss_fn(p, cfg, batch, *, dtype=jnp.bfloat16, remat=True,
            use_pallas=False):
    logits, aux = forward(p, cfg, batch, dtype=dtype, remat=remat,
                          use_pallas=use_pallas)
    loss = softmax_cross_entropy(logits, batch["labels"])
    if "load_balance" in aux:
        loss = loss + cfg.moe_aux_loss_weight * aux["load_balance"] \
            + 1e-3 * aux["router_z"]
    return loss
