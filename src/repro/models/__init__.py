from repro.models.registry import ModelApi, build_model
from repro.models.simple import make_sim_model, SimModel

__all__ = ["ModelApi", "build_model", "make_sim_model", "SimModel"]
