"""Attention for the model zoo: GQA/MQA/MHA with RoPE, QKV bias,
causal / sliding-window / prefix-LM masks, cross-attention, KV caches.

Two execution paths, chosen by sequence length:

* ``simple``: materialize (B, H, Tq, Tk) scores — tests & short seqs.
* ``flash``: scan over query/key chunks with online softmax — compiles
  to compact HLO (scan) and keeps live memory at (B, H, qc, kc) per
  step, which is what lets 4k-32k contexts lower on the 256-chip mesh
  without a T^2 buffer. This is the jnp reference of a TPU flash
  kernel; FLOPs are identical.

Masks are expressed by (mode, window, prefix_len) so the flash path can
apply them per chunk without building a (Tq, Tk) bool tensor.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import Px, dense_init, zeros_init, rope

NEG_INF = -1e30


class AttnParams(NamedTuple):
    pass  # params are plain dicts; kept for documentation


def init_attention(key, cfg, d_model: int | None = None,
                   cross: bool = False) -> dict:
    d = d_model or cfg.d_model
    hd, H, K = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), ("embed", "q_proj")),
        "wk": dense_init(ks[1], (d, K * hd), ("embed", "kv_proj")),
        "wv": dense_init(ks[2], (d, K * hd), ("embed", "kv_proj")),
        "wo": dense_init(ks[3], (H * hd, d), ("q_proj", "embed"),
                         scale=1.0, fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init((H * hd,), ("q_proj",))
        p["bk"] = zeros_init((K * hd,), ("kv_proj",))
        p["bv"] = zeros_init((K * hd,), ("kv_proj",))
    return p


# ---------------------------------------------------------------------------
# mask logic (chunk-local evaluation)
# ---------------------------------------------------------------------------

def _mask_block(q_pos, k_pos, mode: str, window: int, prefix_len):
    """Boolean keep-mask for a (qc, kc) tile given absolute positions.

    mode: 'causal' | 'sliding' | 'prefix' | 'full'
    """
    q = q_pos[:, None]
    k = k_pos[None, :]
    if mode == "full":
        return jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    causal = k <= q
    if mode == "causal":
        return causal
    if mode == "sliding":
        return causal & (k > q - window)
    if mode == "prefix":
        # bidirectional inside the prefix, causal after
        both_prefix = (q < prefix_len) & (k < prefix_len)
        return causal | both_prefix
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# core attention computations
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: (B, Tq, K, G, hd), k: (B, Tk, K, hd) -> (B, K, G, Tq, Tk)."""
    return jnp.einsum("btkgh,bskh->bkgts", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(w, v):
    """w: (B, K, G, Tq, Tk), v: (B, Tk, K, hd) -> (B, Tq, K, G, hd)."""
    return jnp.einsum("bkgts,bskh->btkgh", w, v,
                      preferred_element_type=jnp.float32)


def simple_attention(q, k, v, *, mode="causal", window=0, prefix_len=None,
                     q_offset=0, k_len: jax.Array | None = None):
    """Materialized attention. q: (B,Tq,K,G,hd), k/v: (B,Tk,K,hd)."""
    B, Tq = q.shape[0], q.shape[1]
    Tk = k.shape[1]
    scale = q.shape[-1] ** -0.5
    scores = _gqa_scores(q * scale, k)              # (B,K,G,Tq,Tk) f32
    q_pos = q_offset + jnp.arange(Tq)
    k_pos = jnp.arange(Tk)
    keep = _mask_block(q_pos, k_pos, mode, window,
                       prefix_len if prefix_len is not None else 0)
    if k_len is not None:                            # cache validity limit
        keep = keep & (k_pos[None, :] < k_len)
    scores = jnp.where(keep[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(w, v)
    return out.astype(q.dtype)


def flash_attention(q, k, v, *, mode="causal", window=0, prefix_len=None,
                    q_offset=0, q_chunk=512, k_chunk=1024, k_len=None):
    """Chunked online-softmax attention with a flash-style custom VJP.

    q: (B, Tq, K, G, hd); k, v: (B, Tk, K, hd). Tq % q_chunk == 0 and
    Tk % k_chunk == 0 (caller pads; ``k_len`` masks the key padding).

    The backward pass recomputes score blocks (never materializing more
    than a (q_chunk, k_chunk) tile per step) — residuals are O(T), which
    is what lets 4k-32k training contexts fit the dry-run memory budget.
    """
    return _flash(q, k, v, mode, window, prefix_len, q_offset, q_chunk,
                  k_chunk, k_len)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, mode, window, prefix_len, q_offset, q_chunk, k_chunk,
           k_len):
    out, _ = _flash_fwd(q, k, v, mode, window, prefix_len, q_offset,
                        q_chunk, k_chunk, k_len)
    return out


def _flash_fwd(q, k, v, mode, window, prefix_len, q_offset, q_chunk,
               k_chunk, k_len):
    B, Tq, K, G, hd = q.shape
    Tk = k.shape[1]
    assert Tq % q_chunk == 0 and Tk % k_chunk == 0, (Tq, Tk)
    nq, nk = Tq // q_chunk, Tk // k_chunk
    scale = hd ** -0.5
    pl_ = prefix_len if prefix_len is not None else 0

    qc = q.reshape(B, nq, q_chunk, K, G, hd)
    kc = k.reshape(B, nk, k_chunk, K, hd)
    vc = v.reshape(B, nk, k_chunk, K, hd)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        q_pos = q_offset + iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kv_and_idx):
            acc, m, l = carry
            (ki, vi), ik = kv_and_idx
            k_pos = ik * k_chunk + jnp.arange(k_chunk)
            s = _gqa_scores(qi * scale, ki)          # (B,K,G,qc,kc) f32
            keep = _mask_block(q_pos, k_pos, mode, window, pl_)
            if k_len is not None:
                keep = keep & (k_pos[None, :] < k_len)
            s = jnp.where(keep[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgts,bskh->bkgth", p, vi,
                preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, K, G, q_chunk, hd), jnp.float32)
        m0 = jnp.full((B, K, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            ((jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
             jnp.arange(nk)))
        l_safe = jnp.maximum(l, 1e-30)
        out = acc / l_safe[..., None]
        lse = m + jnp.log(l_safe)                    # (B,K,G,qc)
        return None, (out.astype(q.dtype), lse)

    _, (outs, lses) = jax.lax.scan(q_step, None,
                                   (jnp.moveaxis(qc, 1, 0), jnp.arange(nq)))
    # outs: (nq, B, K, G, qc, hd) -> (B, Tq, K, G, hd)
    out = jnp.transpose(outs, (1, 0, 4, 2, 3, 5)).reshape(B, Tq, K, G, hd)
    lse = jnp.transpose(lses, (1, 0, 4, 2, 3)).reshape(B, Tq, K, G)
    return out, lse


def _flash_fwd_vjp(q, k, v, mode, window, prefix_len, q_offset, q_chunk,
                   k_chunk, k_len):
    out, lse = _flash_fwd(q, k, v, mode, window, prefix_len, q_offset,
                          q_chunk, k_chunk, k_len)
    return out, (q, k, v, out, lse)


def _flash_bwd(mode, window, prefix_len, q_offset, q_chunk, k_chunk, k_len,
               res, dout):
    q, k, v, out, lse = res
    B, Tq, K, G, hd = q.shape
    Tk = k.shape[1]
    nq, nk = Tq // q_chunk, Tk // k_chunk
    scale = hd ** -0.5
    pl_ = prefix_len if prefix_len is not None else 0

    # delta = rowsum(dout * out)  (B, Tq, K, G)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)

    qc = jnp.moveaxis(q.reshape(B, nq, q_chunk, K, G, hd), 1, 0)
    doc = jnp.moveaxis(dout.reshape(B, nq, q_chunk, K, G, hd), 1, 0)
    lsec = jnp.moveaxis(lse.reshape(B, nq, q_chunk, K, G), 1, 0)
    deltac = jnp.moveaxis(delta.reshape(B, nq, q_chunk, K, G), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nk, k_chunk, K, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, k_chunk, K, hd), 1, 0)

    def kv_step(dq_acc, kv_and_idx):
        (ki, vi), ik = kv_and_idx
        k_pos = ik * k_chunk + jnp.arange(k_chunk)

        def q_step(carry_q, q_and_idx):
            dki, dvi = carry_q
            (qi, doi, lsei, deli), iq = q_and_idx
            # qi/doi: (B, qc, K, G, hd); lsei/deli: (B, qc, K, G)
            q_pos = q_offset + iq * q_chunk + jnp.arange(q_chunk)
            s = _gqa_scores(qi * scale, ki)            # (B,K,G,qc,kc)
            keep = _mask_block(q_pos, k_pos, mode, window, pl_)
            if k_len is not None:
                keep = keep & (k_pos[None, :] < k_len)
            s = jnp.where(keep[None, None, None], s, NEG_INF)
            lse_a = jnp.transpose(lsei, (0, 2, 3, 1))   # (B,K,G,qc)
            del_a = jnp.transpose(deli, (0, 2, 3, 1))
            p = jnp.exp(s - lse_a[..., None])
            do_b = jnp.transpose(doi, (0, 2, 3, 1, 4)
                                 ).astype(jnp.float32)  # (B,K,G,qc,hd)
            dv_blk = jnp.einsum("bkgts,bkgth->bskh", p, do_b)
            dp = jnp.einsum("bkgth,bskh->bkgts", do_b,
                            vi.astype(jnp.float32))
            ds = p * (dp - del_a[..., None]) * scale
            dq_blk = jnp.einsum("bkgts,bskh->bkgth", ds,
                                ki.astype(jnp.float32))
            q_b = jnp.transpose(qi, (0, 2, 3, 1, 4)).astype(jnp.float32)
            dk_blk = jnp.einsum("bkgts,bkgth->bskh", ds, q_b)
            # -> dq tile back to (B, qc, K, G, hd)
            dq_tile = jnp.transpose(dq_blk, (0, 3, 1, 2, 4))
            return (dki + dk_blk, dvi + dv_blk), dq_tile

        (dk_i, dv_i), dq_tiles = jax.lax.scan(
            q_step,
            (jnp.zeros((B, k_chunk, K, hd), jnp.float32),
             jnp.zeros((B, k_chunk, K, hd), jnp.float32)),
            ((qc, doc, lsec, deltac), jnp.arange(nq)))
        # dq_tiles: (nq, B, qc, K, G, hd) -> (B, Tq, K, G, hd)
        dq_full = jnp.moveaxis(dq_tiles, 0, 1).reshape(B, Tq, K, G, hd)
        return dq_acc + dq_full, (dk_i, dv_i)

    dq0 = jnp.zeros((B, Tq, K, G, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_step, dq0, ((kc, vc), jnp.arange(nk)))
    # dks: (nk, B, kc, K, hd) -> (B, Tk, K, hd)
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Tk, K, hd).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Tk, K, hd).astype(v.dtype)
    return dq.astype(q.dtype), dk, dv


_flash.defvjp(_flash_fwd_vjp, _flash_bwd)


# ---------------------------------------------------------------------------
# pair-scheduled flash attention (beyond-paper §Perf optimization):
# only the (q-chunk, k-chunk) pairs that can contain unmasked entries are
# computed — ~2x fewer FLOPs for causal, window/T for sliding windows —
# instead of masking a full rectangular sweep.
# ---------------------------------------------------------------------------

def _block_pairs(nq, nk, q_chunk, k_chunk, mode, window, prefix_len,
                 q_offset):
    """Static list of (iq, ik) chunk pairs with any visible entries."""
    pairs = []
    for iq in range(nq):
        q_lo = q_offset + iq * q_chunk
        q_hi = q_lo + q_chunk - 1
        for ik in range(nk):
            k_lo = ik * k_chunk
            k_hi = k_lo + k_chunk - 1
            if mode == "full":
                vis = True
            elif mode == "causal":
                vis = k_lo <= q_hi
            elif mode == "sliding":
                vis = (k_lo <= q_hi) and (k_hi > q_lo - window)
            elif mode == "prefix":
                vis = (k_lo <= q_hi) or (k_lo < (prefix_len or 0))
            else:
                raise ValueError(mode)
            if vis:
                pairs.append((iq, ik))
    return pairs


def flash_attention_pairs(q, k, v, *, mode="causal", window=0,
                          prefix_len=None, q_offset=0, q_chunk=512,
                          k_chunk=512, k_len=None):
    """Same math as :func:`flash_attention`, triangular/banded schedule.

    Scans over the static visible-pair list; accumulators for ALL query
    chunks are carried (O(Tq) memory, fp32) and renormalized once at the
    end. Custom VJP with the same pair schedule backward.
    """
    return _flash_pairs(q, k, v, mode, window, prefix_len, q_offset,
                        q_chunk, k_chunk, k_len)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash_pairs(q, k, v, mode, window, prefix_len, q_offset, q_chunk,
                 k_chunk, k_len):
    out, _ = _flash_pairs_fwd(q, k, v, mode, window, prefix_len, q_offset,
                              q_chunk, k_chunk, k_len)
    return out


def _pairs_arrays(nq, nk, q_chunk, k_chunk, mode, window, prefix_len,
                  q_offset):
    import numpy as _np
    pairs = _block_pairs(nq, nk, q_chunk, k_chunk, mode, window,
                         prefix_len, q_offset)
    arr = _np.asarray(pairs, _np.int32)
    return jnp.asarray(arr[:, 0]), jnp.asarray(arr[:, 1])


def _flash_pairs_fwd(q, k, v, mode, window, prefix_len, q_offset, q_chunk,
                     k_chunk, k_len):
    B, Tq, K, G, hd = q.shape
    Tk = k.shape[1]
    assert Tq % q_chunk == 0 and Tk % k_chunk == 0, (Tq, Tk)
    nq, nk = Tq // q_chunk, Tk // k_chunk
    scale = hd ** -0.5
    pl_ = prefix_len if prefix_len is not None else 0
    iqs, iks = _pairs_arrays(nq, nk, q_chunk, k_chunk, mode, window,
                             prefix_len, q_offset)

    qb = q.reshape(B, nq, q_chunk, K, G, hd)
    kb = k.reshape(B, nk, k_chunk, K, hd)
    vb = v.reshape(B, nk, k_chunk, K, hd)

    def step(carry, pair):
        acc, m, l = carry                     # acc (B,nq,qc,K,G,hd) f32
        iq, ik = pair
        qi = jax.lax.dynamic_index_in_dim(qb, iq, 1, keepdims=False)
        ki = jax.lax.dynamic_index_in_dim(kb, ik, 1, keepdims=False)
        vi = jax.lax.dynamic_index_in_dim(vb, ik, 1, keepdims=False)
        q_pos = q_offset + iq * q_chunk + jnp.arange(q_chunk)
        k_pos = ik * k_chunk + jnp.arange(k_chunk)
        s = _gqa_scores(qi * scale, ki)       # (B,K,G,qc,kc)
        keep = _mask_block(q_pos, k_pos, mode, window, pl_)
        if k_len is not None:
            keep = keep & (k_pos[None, :] < k_len)
        s = jnp.where(keep[None, None, None], s, NEG_INF)
        m_i = jax.lax.dynamic_index_in_dim(m, iq, 1, keepdims=False)
        l_i = jax.lax.dynamic_index_in_dim(l, iq, 1, keepdims=False)
        a_i = jax.lax.dynamic_index_in_dim(acc, iq, 1, keepdims=False)
        s_t = jnp.transpose(s, (0, 3, 1, 2, 4))   # (B,qc,K,G,kc)
        m_new = jnp.maximum(m_i, s_t.max(axis=-1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s_t - m_new[..., None])
        l_new = l_i * alpha + p.sum(axis=-1)
        upd = jnp.einsum("btkgs,bskh->btkgh", p,
                         vi.astype(jnp.float32))
        a_new = a_i * alpha[..., None] + upd
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, iq, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, iq, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, iq, 1)
        return (acc, m, l), None

    acc0 = jnp.zeros((B, nq, q_chunk, K, G, hd), jnp.float32)
    m0 = jnp.full((B, nq, q_chunk, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, q_chunk, K, G), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (iqs, iks))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).reshape(B, Tq, K, G, hd).astype(q.dtype)
    lse = (m + jnp.log(l_safe)).reshape(B, Tq, K, G)
    return out, lse


def _flash_pairs_fwd_vjp(q, k, v, mode, window, prefix_len, q_offset,
                         q_chunk, k_chunk, k_len):
    out, lse = _flash_pairs_fwd(q, k, v, mode, window, prefix_len,
                                q_offset, q_chunk, k_chunk, k_len)
    return out, (q, k, v, out, lse)


def _flash_pairs_bwd(mode, window, prefix_len, q_offset, q_chunk, k_chunk,
                     k_len, res, dout):
    q, k, v, out, lse = res
    B, Tq, K, G, hd = q.shape
    Tk = k.shape[1]
    nq, nk = Tq // q_chunk, Tk // k_chunk
    scale = hd ** -0.5
    pl_ = prefix_len if prefix_len is not None else 0
    iqs, iks = _pairs_arrays(nq, nk, q_chunk, k_chunk, mode, window,
                             prefix_len, q_offset)

    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                         # (B,Tq,K,G)
    qb = q.reshape(B, nq, q_chunk, K, G, hd)
    kb = k.reshape(B, nk, k_chunk, K, hd)
    vb = v.reshape(B, nk, k_chunk, K, hd)
    dob = dout.reshape(B, nq, q_chunk, K, G, hd)
    lseb = lse.reshape(B, nq, q_chunk, K, G)
    delb = delta.reshape(B, nq, q_chunk, K, G)

    def step(carry, pair):
        dq, dk, dv = carry
        iq, ik = pair
        qi = jax.lax.dynamic_index_in_dim(qb, iq, 1, keepdims=False)
        ki = jax.lax.dynamic_index_in_dim(kb, ik, 1, keepdims=False)
        vi = jax.lax.dynamic_index_in_dim(vb, ik, 1, keepdims=False)
        doi = jax.lax.dynamic_index_in_dim(dob, iq, 1, keepdims=False)
        lsei = jax.lax.dynamic_index_in_dim(lseb, iq, 1, keepdims=False)
        deli = jax.lax.dynamic_index_in_dim(delb, iq, 1, keepdims=False)
        q_pos = q_offset + iq * q_chunk + jnp.arange(q_chunk)
        k_pos = ik * k_chunk + jnp.arange(k_chunk)
        s = _gqa_scores(qi * scale, ki)              # (B,K,G,qc,kc)
        keep = _mask_block(q_pos, k_pos, mode, window, pl_)
        if k_len is not None:
            keep = keep & (k_pos[None, :] < k_len)
        s = jnp.where(keep[None, None, None], s, NEG_INF)
        lse_a = jnp.transpose(lsei, (0, 2, 3, 1))
        del_a = jnp.transpose(deli, (0, 2, 3, 1))
        p = jnp.exp(s - lse_a[..., None])
        do_b = jnp.transpose(doi, (0, 2, 3, 1, 4)).astype(jnp.float32)
        dv_blk = jnp.einsum("bkgts,bkgth->bskh", p, do_b)
        dp = jnp.einsum("bkgth,bskh->bkgts", do_b, vi.astype(jnp.float32))
        ds = p * (dp - del_a[..., None]) * scale
        dq_blk = jnp.einsum("bkgts,bskh->bkgth", ds, ki.astype(jnp.float32))
        q_b = jnp.transpose(qi, (0, 2, 3, 1, 4)).astype(jnp.float32)
        dk_blk = jnp.einsum("bkgts,bkgth->bskh", ds, q_b)
        dq_tile = jnp.transpose(dq_blk, (0, 3, 1, 2, 4))   # (B,qc,K,G,hd)
        dq_cur = jax.lax.dynamic_index_in_dim(dq, iq, 1, keepdims=False)
        dq = jax.lax.dynamic_update_index_in_dim(dq, dq_cur + dq_tile,
                                                 iq, 1)
        dk_cur = jax.lax.dynamic_index_in_dim(dk, ik, 1, keepdims=False)
        dk = jax.lax.dynamic_update_index_in_dim(dk, dk_cur + dk_blk,
                                                 ik, 1)
        dv_cur = jax.lax.dynamic_index_in_dim(dv, ik, 1, keepdims=False)
        dv = jax.lax.dynamic_update_index_in_dim(dv, dv_cur + dv_blk,
                                                 ik, 1)
        return (dq, dk, dv), None

    dq0 = jnp.zeros((B, nq, q_chunk, K, G, hd), jnp.float32)
    dk0 = jnp.zeros((B, nk, k_chunk, K, hd), jnp.float32)
    dv0 = jnp.zeros((B, nk, k_chunk, K, hd), jnp.float32)
    (dq, dk, dv), _ = jax.lax.scan(step, (dq0, dk0, dv0), (iqs, iks))
    return (dq.reshape(B, Tq, K, G, hd).astype(q.dtype),
            dk.reshape(B, Tk, K, hd).astype(k.dtype),
            dv.reshape(B, Tk, K, hd).astype(v.dtype))


_flash_pairs.defvjp(_flash_pairs_fwd_vjp, _flash_pairs_bwd)

# global switch for the §Perf experiment (build_program flips it)
PAIR_SCHEDULE = False

import contextlib


@contextlib.contextmanager
def pair_schedule(on: bool = True):
    global PAIR_SCHEDULE
    prev = PAIR_SCHEDULE
    PAIR_SCHEDULE = on
    try:
        yield
    finally:
        PAIR_SCHEDULE = prev


# ---------------------------------------------------------------------------
# the full attention block (projections + cache handling)
# ---------------------------------------------------------------------------

def _project_q(p, cfg, x):
    B, T, _ = x.shape
    q = x @ p["wq"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    return q.reshape(B, T, cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim)


def _project_kv(p, cfg, x):
    B, T, _ = x.shape
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bk" in p:
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    k = k.reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def attention_block(p, cfg, x, *, mode="causal", window=0, prefix_len=None,
                    positions=None, kv_source=None, flash_threshold=2048):
    """Self- (or cross-) attention over a full sequence (train/prefill).

    x: (B, T, d). kv_source: (B, S, d) for cross-attention.
    Returns (B, T, d).
    """
    from repro.dist.sharding import hint
    B, T, _ = x.shape
    q = _project_q(p, cfg, x)
    kv_in = x if kv_source is None else kv_source
    k, v = _project_kv(p, cfg, kv_in)
    # keep heads on the model axis when the head count divides it —
    # otherwise XLA splits head_dim and all-reduces every score block
    q = hint(q, ("pod", "data"), None, "model", None, None)
    k = hint(k, ("pod", "data"), None, "model", None)
    v = hint(v, ("pod", "data"), None, "model", None)
    if cfg.rope and kv_source is None:
        pos = positions if positions is not None else jnp.arange(T)
        q = rope(q.reshape(B, T, -1, cfg.head_dim), pos,
                 cfg.rope_theta).reshape(q.shape)
        k = rope(k, pos, cfg.rope_theta)

    Tk = k.shape[1]
    use_flash = max(T, Tk) > flash_threshold
    if use_flash:
        pair_mode = PAIR_SCHEDULE and mode in ("causal", "sliding",
                                               "prefix")
        qc = min(512, T)
        kc = qc if pair_mode else min(1024, Tk)
        # pad to chunk multiples
        pq, pk = (-T) % qc, (-Tk) % kc
        if pq:
            q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
        if pk:
            k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        fa = flash_attention_pairs if pair_mode else flash_attention
        out = fa(q, k, v, mode=mode, window=window,
                 prefix_len=prefix_len, q_chunk=qc, k_chunk=kc,
                 k_len=Tk if pk else None)
        out = out[:, :T]
    else:
        out = simple_attention(q, k, v, mode=mode, window=window,
                               prefix_len=prefix_len)
    out = out.reshape(B, T, cfg.num_heads * cfg.head_dim)
    return out @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# decode path: single-token step against a KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16):
    """Cache leaves for ONE layer (the layer axis is added by the stack).

    Ring buffer when cfg.sliding_window > 0 and cache_len > window.
    """
    K, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, cache_len, K, hd), dtype),
        "v": jnp.zeros((batch, cache_len, K, hd), dtype),
    }


def cache_logical_axes():
    return {"k": ("cache_batch", "cache_seq", "cache_kv_heads", "head_dim"),
            "v": ("cache_batch", "cache_seq", "cache_kv_heads", "head_dim")}


def init_paged_cache(cfg, num_pages: int, page_size: int,
                     dtype=jnp.bfloat16):
    """Paged cache leaves for ONE layer: a pool of fixed-size pages
    shared by every slot (page 0 is the reserved dummy page)."""
    K, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((num_pages, page_size, K, hd), dtype),
        "v": jnp.zeros((num_pages, page_size, K, hd), dtype),
    }


def paged_cache_logical_axes():
    ax = ("cache_pages", "page_off", "cache_kv_heads", "head_dim")
    return {"k": ax, "v": ax}


def _paged_scatter(kv, k_new, v_new, flat):
    """Write per-row K/V (B, K, hd) at flat page offsets (B,) into the
    (num_pages, page_size, K, hd) pool; returns the updated pool pair.
    Rows routed to the dummy page may collide — nobody reads page 0
    unmasked, so last-writer-wins is fine."""
    N, ps = kv["k"].shape[:2]
    kf = kv["k"].reshape((N * ps,) + kv["k"].shape[2:])
    vf = kv["v"].reshape((N * ps,) + kv["v"].shape[2:])
    kf = kf.at[flat].set(k_new.astype(kf.dtype))
    vf = vf.at[flat].set(v_new.astype(vf.dtype))
    return kf.reshape(kv["k"].shape), vf.reshape(kv["v"].shape)


def paged_decode_attention(p, cfg, x, cache, pos, page_map, *, window=0,
                           use_kernel=False, interpret=None):
    """One-token attention step against a PAGED cache.

    x: (B, 1, d); cache: {'k','v'} (num_pages, page_size, K, hd);
    pos: (B,) absolute positions; page_map: (B, pages_per_slot) int32 —
    each slot's logical pages in position order (dummy page 0 for
    unallocated entries). Unlike the ring path, the paged cache stores
    FULL positions and masks a [pos-window, pos] band, so sliding archs
    match the ring outputs without wraparound arithmetic.

    Returns (out, new_cache). With ``use_kernel`` the gather+softmax
    runs in the Pallas paged-decode kernel (interpret mode off-TPU).
    """
    B = x.shape[0]
    q = _project_q(p, cfg, x)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))

    k_new, v_new = _project_kv(p, cfg, x)
    if cfg.rope:
        q = rope(q.reshape(B, 1, -1, cfg.head_dim), pos[:, None],
                 cfg.rope_theta).reshape(q.shape)
        k_new = rope(k_new, pos[:, None], cfg.rope_theta)
    from repro.dist.sharding import hint
    q = hint(q, ("pod", "data"), None, "model", None, None)
    k_new = hint(k_new, ("pod", "data"), None, "model", None)
    v_new = hint(v_new, ("pod", "data"), None, "model", None)

    N, ps = cache["k"].shape[:2]
    P = page_map.shape[1]
    # the new token's page: slots mid-prefill / retired carry an
    # all-dummy page-map row, so their write lands in the page-0 sink
    pg = jnp.take_along_axis(page_map,
                             jnp.clip(pos // ps, 0, P - 1)[:, None],
                             axis=1)[:, 0]
    flat = pg * ps + pos % ps                        # (B,)
    k_pages, v_pages = _paged_scatter(cache, k_new[:, 0], v_new[:, 0],
                                      flat)

    if use_kernel:
        from repro.kernels.paged_attn import paged_decode
        out = paged_decode(q[:, 0], k_pages, v_pages, page_map, pos,
                           window=window, interpret=interpret)
        out = out[:, None].astype(x.dtype)           # (B, 1, K, G, hd)
    else:
        kg = k_pages[page_map].reshape(B, P * ps, *k_pages.shape[2:])
        vg = v_pages[page_map].reshape(B, P * ps, *v_pages.shape[2:])
        scale = cfg.head_dim ** -0.5
        s = _gqa_scores(q * scale, kg.astype(q.dtype))   # (B,K,G,1,S)
        k_pos = jnp.arange(P * ps)
        valid = k_pos[None, :] <= pos[:, None]
        if window:
            valid = valid & (k_pos[None, :] > pos[:, None] - window)
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        out = _gqa_out(w, vg.astype(q.dtype)).astype(x.dtype)
    out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
    return out @ p["wo"].astype(x.dtype), {"k": k_pages, "v": v_pages}


def decode_attention(p, cfg, x, cache, pos, *, window=0,
                     kv_source_cache=None):
    """One-token attention step.

    x: (B, 1, d); cache: {'k','v'} (B, S, K, hd); pos: int32 scalar or
    ``(B,)`` vector — the absolute position of each slot's new token
    (a scalar broadcasts to all slots). Returns (out, new_cache).

    Ring-buffer semantics when window > 0 and S == window: slot =
    pos % window and all cache entries are valid once pos >= window.
    Keys are stored post-RoPE (absolute rotation).
    """
    B = x.shape[0]
    q = _project_q(p, cfg, x)

    if kv_source_cache is not None:
        # cross-attention: cache holds the (pre-projected) encoder K/V
        k, v = kv_source_cache["k"], kv_source_cache["v"]
        scale = cfg.head_dim ** -0.5
        s = _gqa_scores(q * scale, k.astype(q.dtype))
        w = jax.nn.softmax(s, axis=-1)
        out = _gqa_out(w, v.astype(q.dtype)).astype(x.dtype)
        out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
        return out @ p["wo"].astype(x.dtype), cache

    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim > 0                          # (B,) vector positions
    pos = jnp.broadcast_to(pos.reshape(-1), (B,))

    k_new, v_new = _project_kv(p, cfg, x)
    if cfg.rope:
        pos_arr = pos[:1, None] if not per_slot else pos[:, None]  # bcast B
        q = rope(q.reshape(B, 1, -1, cfg.head_dim), pos_arr,
                 cfg.rope_theta).reshape(q.shape)
        k_new = rope(k_new, pos_arr, cfg.rope_theta)
    # tensor-parallel decode: per-token projections sharded over heads
    # (shape-aware — a no-op on single device / indivisible head counts)
    from repro.dist.sharding import hint
    q = hint(q, ("pod", "data"), None, "model", None, None)
    k_new = hint(k_new, ("pod", "data"), None, "model", None)
    v_new = hint(v_new, ("pod", "data"), None, "model", None)

    S = cache["k"].shape[1]
    slot = jnp.where(window > 0, pos % jnp.maximum(S, 1), pos)
    slot = jnp.minimum(slot, S - 1)                  # (B,)
    if per_slot:
        bi = jnp.arange(B)
        k = cache["k"].at[bi, slot].set(k_new[:, 0].astype(cache["k"].dtype))
        v = cache["v"].at[bi, slot].set(v_new[:, 0].astype(cache["v"].dtype))
    else:
        # aligned batch: one contiguous slice update beats a scatter
        k = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, slot[0], 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, slot[0], 0, 0))

    scale = cfg.head_dim ** -0.5
    s = _gqa_scores(q * scale, k.astype(q.dtype))    # (B,K,G,1,S)
    k_pos = jnp.arange(S)
    if window > 0:
        # ring: all valid once a slot's position wraps past the window
        valid = (k_pos[None, :] <= slot[:, None]) | (pos[:, None] >= S)
    else:
        valid = k_pos[None, :] <= pos[:, None]       # (B, S)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = _gqa_out(w, v.astype(q.dtype)).astype(x.dtype)
    out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
    return out @ p["wo"].astype(x.dtype), {"k": k, "v": v}
