"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Griffin recurrent block:
  branch A: linear -> GeLU
  branch B: linear -> short causal conv -> RG-LRU
  merge: A * B -> out-proj

RG-LRU (per channel):
  r_t = sigmoid(W_a x_t + b_a)            recurrence gate
  i_t = sigmoid(W_x x_t + b_x)            input gate
  a_t = exp(c * softplus(Lambda) * (-r_t))     in (0,1),  c = 8
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Full-sequence path uses an associative scan over (a, b) pairs —
O(log T) depth, compact HLO; decode is a single-step update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Px, dense_init, zeros_init

RG_C = 8.0


def _width(cfg):
    return cfg.rglru_width or cfg.d_model


def init_rglru(key, cfg) -> dict:
    d = cfg.d_model
    w = _width(cfg)
    ks = jax.random.split(key, 6)
    conv_k = cfg.rglru_conv_width
    return {
        "w_gelu": dense_init(ks[0], (d, w), ("embed", "rnn_width")),
        "w_rec": dense_init(ks[1], (d, w), ("embed", "rnn_width")),
        "conv": Px(jax.random.normal(ks[2], (conv_k, w)) * 0.1,
                   ("conv_k", "rnn_width")),
        "w_a": dense_init(ks[3], (w, w), ("rnn_width_in", "rnn_width")),
        "b_a": zeros_init((w,), ("rnn_width",)),
        "w_x": dense_init(ks[4], (w, w), ("rnn_width_in", "rnn_width")),
        "b_x": zeros_init((w,), ("rnn_width",)),
        # Lambda init so that a^c ~ U[0.9, 0.999] at r=1 (paper App. A)
        "lam": Px(jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, w)) / RG_C)), ("rnn_width",)),
        "w_out": dense_init(ks[5], (w, d), ("rnn_width", "embed"), fan_in=w),
    }


def _gates(p, xb):
    """xb: (..., w) -> (a, beta_x) with a the decay, beta the input scale."""
    r = jax.nn.sigmoid(xb @ p["w_a"].astype(xb.dtype)
                       + p["b_a"].astype(xb.dtype)).astype(jnp.float32)
    i = jax.nn.sigmoid(xb @ p["w_x"].astype(xb.dtype)
                       + p["b_x"].astype(xb.dtype)).astype(jnp.float32)
    log_a = -RG_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    scale = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, scale * i


def _causal_conv(x, w, state=None):
    K = w.shape[0]
    pad = jnp.zeros_like(x[:, : K - 1]) if state is None \
        else state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else jnp.zeros_like(x[:, :0])
    return y, new_state


def apply_rglru(p, cfg, x: jax.Array) -> jax.Array:
    """Full-sequence Griffin recurrent block. x: (B, T, d)."""
    dt = x.dtype
    ga = jax.nn.gelu(x @ p["w_gelu"].astype(dt), approximate=True)
    xb = x @ p["w_rec"].astype(dt)
    xb, _ = _causal_conv(xb, p["conv"])
    a, beta = _gates(p, xb)                        # (B, T, w) f32
    b = beta * xb.astype(jnp.float32)

    # h_t = a_t h_{t-1} + b_t  via associative scan
    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (ga.astype(jnp.float32) * h).astype(dt)
    return y @ p["w_out"].astype(dt)


def init_rglru_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    w = _width(cfg)
    K = cfg.rglru_conv_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, K - 1, w), dtype)}


def rglru_cache_logical_axes(cfg) -> dict:
    return {"h": ("cache_batch", "rnn_width"),
            "conv": ("cache_batch", None, "rnn_width")}


def decode_rglru(p, cfg, x, cache):
    """x: (B, 1, d) -> (y, new_cache). O(1) state update."""
    dt = x.dtype
    ga = jax.nn.gelu(x @ p["w_gelu"].astype(dt), approximate=True)
    xb = x @ p["w_rec"].astype(dt)
    # tensor-parallel decode: recurrence width sharded over model
    # (shape-aware — a no-op on single device / indivisible widths)
    from repro.dist.sharding import hint
    xb = hint(xb, ("pod", "data"), None, "model")
    xb, conv_state = _causal_conv(xb, p["conv"], cache["conv"])
    a, beta = _gates(p, xb)                        # (B, 1, w)
    h = a[:, 0] * cache["h"] + beta[:, 0] * xb[:, 0].astype(jnp.float32)
    y = (ga[:, 0].astype(jnp.float32) * h).astype(dt)[:, None]
    return y @ p["w_out"].astype(dt), \
        {"h": h, "conv": conv_state.astype(cache["conv"].dtype)}
