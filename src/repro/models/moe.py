"""Top-1 (Switch-style) Mixture-of-Experts FFN.

Dispatch/combine are one-hot EINSUMS over token groups (scatter-free —
see apply_moe's docstring), giving the *active*-FLOPs formulation
(top_k x dense, not E x) with the expert axis sharded over ``model``
(expert parallelism) and optional ``expert_ffn`` sharding for the
weights-stay-put/tokens-move layout (EXPERIMENTS.md §Perf HC4).
Overflow tokens beyond per-group capacity are dropped (residual passes
through), the standard Switch behaviour.

Aux losses: Switch load-balance loss E * sum_e f_e * p_e and router
z-loss; both returned for the trainer to weigh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Px, dense_init


def init_moe(key, cfg) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe_num_experts
    ks = jax.random.split(key, 4)
    gated = cfg.mlp_variant in ("swiglu", "geglu")
    p = {
        "router": dense_init(ks[0], (d, E), ("embed", "experts_router")),
        "w_up": dense_init(ks[1], (E, d, f),
                           ("experts", "embed_fsdp", "expert_ffn")),
        "w_down": dense_init(ks[2], (E, f, d),
                             ("experts", "expert_ffn", "embed_fsdp"),
                             fan_in=f),
    }
    if gated:
        p["w_gate"] = dense_init(ks[3], (E, d, f),
                                 ("experts", "embed_fsdp", "expert_ffn"))
    return p


def _group_size(G: int, target: int = 2048) -> int:
    """Largest divisor of G that is <= target (dispatch tile size)."""
    if G <= target:
        return G
    n = -(-G // target)           # ceil
    while G % n:
        n += 1
    return G // n


def apply_moe(p, cfg, x: jax.Array, capacity_factor: float | None = None,
              token_mask: jax.Array | None = None):
    """x: (B, T, D) -> (y, aux) with y: (B, T, D).

    Dispatch/combine are ONE-HOT EINSUMS over token groups (no scatter):
    GSPMD partitions them cleanly — groups follow the batch sharding,
    the expert axis follows the 'model' sharding — whereas a scatter
    into an expert-sharded buffer makes the partitioner replicate the
    whole token stream. Capacity is per group (Switch-style dropping);
    the dispatch one-hot costs ~(E*c/3F) of the expert FLOPs (~8%).

    ``token_mask``: optional (B, T) bool — False tokens (serving pad)
    are excluded from dispatch entirely: they consume no expert
    capacity, contribute nothing to the load-balance stats, and get
    y = 0 (residual passthrough). Masked mode also makes token groups
    PER ROW (n = B, s = T) so routing and capacity are row-independent:
    a slot in a mixed batch dispatches exactly like the same prompt in
    a batch-1 prefill of the same padded length — no cross-request
    capacity interference in serving.
    """
    from repro.dist.sharding import hint
    B, T, D = x.shape
    E = cfg.moe_num_experts
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    G = B * T
    dt = x.dtype
    if token_mask is not None:
        s, n = T, B
    else:
        s = _group_size(G)
        n = G // s
    c = int(max(1, round(s * capacity_factor / E)))
    xg = hint(x.reshape(n, s, D), ("pod", "data"), None, None)

    logits = jnp.einsum("nsd,de->nse", xg,
                        p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (n, s, E)
    eid = jnp.argmax(logits, axis=-1)                        # (n, s)
    gate = jnp.max(probs, axis=-1)                           # (n, s)

    onehot_e = jax.nn.one_hot(eid, E, dtype=jnp.float32)     # (n, s, E)
    if token_mask is not None:
        keep_tok = token_mask.reshape(n, s).astype(jnp.float32)
        onehot_e = onehot_e * keep_tok[..., None]
    pos_in_e = jnp.cumsum(onehot_e, axis=1) - onehot_e       # (n, s, E)
    pos = jnp.sum(pos_in_e * onehot_e, axis=-1)              # (n, s) f32
    keep = pos < c
    onehot_c = jax.nn.one_hot(pos.astype(jnp.int32), c,
                              dtype=jnp.float32)             # (n, s, c)
    disp = (onehot_e[..., None] * onehot_c[:, :, None, :]
            * keep[..., None, None]).astype(dt)              # (n, s, E, c)
    disp = hint(disp, ("pod", "data"), None, "model", None)

    buf = jnp.einsum("nsec,nsd->necd", disp, xg)             # (n, E, c, D)
    buf = hint(buf, ("pod", "data"), "model", None, None)
    gated = "w_gate" in p
    up = jnp.einsum("necd,edf->necf", buf, p["w_up"].astype(dt))
    up = hint(up, ("pod", "data"), "model", None, None)
    if gated:
        g = jnp.einsum("necd,edf->necf", buf, p["w_gate"].astype(dt))
        g = hint(g, ("pod", "data"), "model", None, None)
        act = jax.nn.silu(g) if cfg.mlp_variant == "swiglu" \
            else jax.nn.gelu(g, approximate=True)
        h = act * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    out = jnp.einsum("necf,efd->necd", h, p["w_down"].astype(dt))
    out = hint(out, ("pod", "data"), "model", None, None)
    y = jnp.einsum("nsec,necd->nsd", disp, out)              # (n, s, D)
    y = hint(y, ("pod", "data"), None, None)
    y = y * gate[..., None].astype(dt)

    # aux: Switch load-balance + z-loss (over real tokens only when a
    # token_mask is given — pads must not bias the router losses)
    lse2 = jax.scipy.special.logsumexp(logits, axis=-1) ** 2
    if token_mask is None:
        frac_tokens = jnp.mean(onehot_e, axis=(0, 1))        # f_e
        frac_probs = jnp.mean(probs, axis=(0, 1))            # p_e
        z_loss = jnp.mean(lse2)
        drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    else:
        n_real = jnp.maximum(jnp.sum(keep_tok), 1.0)
        frac_tokens = jnp.sum(onehot_e, axis=(0, 1)) / n_real
        frac_probs = jnp.sum(probs * keep_tok[..., None],
                             axis=(0, 1)) / n_real
        z_loss = jnp.sum(lse2 * keep_tok) / n_real
        drop_frac = 1.0 - jnp.sum(keep.astype(jnp.float32)
                                  * keep_tok) / n_real
    lb_loss = E * jnp.sum(frac_tokens * frac_probs)
    aux = {"load_balance": lb_loss, "router_z": z_loss,
           "drop_frac": drop_frac}
    return y.reshape(B, T, D), aux
