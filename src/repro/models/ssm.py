"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Layer: in_proj -> [z | x | B | C | dt] ; short causal conv on (x,B,C);
SSD scan  h_t = exp(dt*A) h_{t-1} + dt * B_t (x) x_t,  y_t = C_t h_t
+ D*x_t ; gate by silu(z); out_proj.

Two SSD execution paths:
* ``chunked jnp`` (default in models): lax.scan over chunks carrying the
  (H, S, P) state — compact HLO for the multi-pod dry-run, identical
  math to the Pallas kernel.
* ``pallas`` (TPU target): `repro.kernels.ssd_scan`.

Decode: O(1) single-step state update (the whole point of SSMs for the
``long_500k`` shape).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Px, dense_init, ones_init, zeros_init


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_num_heads
    P = cfg.ssm_head_dim
    S = cfg.ssm_state_dim
    assert H * P == d_in, (H, P, d_in)
    return d_in, H, P, S


def init_ssm(key, cfg) -> dict:
    d = cfg.d_model
    d_in, H, P, S = _dims(cfg)
    ks = jax.random.split(key, 8)
    conv_k = cfg.ssm_conv_width
    return {
        "w_in": dense_init(ks[0], (d, 2 * d_in + 2 * S + H),
                           ("embed", "ssm_in")),
        "conv_x": Px(jax.random.normal(ks[1], (conv_k, d_in)) * 0.1,
                     ("conv_k", "ssm_in")),
        "conv_B": Px(jax.random.normal(ks[2], (conv_k, S)) * 0.1,
                     ("conv_k", "ssm_state")),
        "conv_C": Px(jax.random.normal(ks[3], (conv_k, S)) * 0.1,
                     ("conv_k", "ssm_state")),
        "A_log": Px(jnp.log(jnp.linspace(1.0, 16.0, H)), ("ssm_heads",)),
        "D": ones_init((H,), ("ssm_heads",)),
        "dt_bias": Px(jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, H))), ("ssm_heads",)),
        "w_out": dense_init(ks[4], (d_in, d), ("ssm_in", "embed"),
                            fan_in=d_in),
    }


def _split_proj(cfg, proj):
    d_in, H, P, S = _dims(cfg)
    z, xs, B, C, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + S, 2 * d_in + 2 * S], axis=-1)
    return z, xs, B, C, dt


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B, T, D); w: (K, D).

    state: (B, K-1, D) trailing context for decode; returns (y, new_state).
    """
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, : K - 1])
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, T+K-1, D)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else jnp.zeros_like(x[:, :0])
    return y, new_state


def ssd_chunked(x, dt, loga, B, C, h0=None, chunk: int = 256):
    """Chunked SSD, vectorized jnp (same math as kernels/ssd_scan).

    x: (b, T, H, P); dt/loga: (b, T, H); B/C: (b, T, S) (state shared
    across heads, per Mamba-2's single B/C group). Returns
    (y: (b,T,H,P), h: (b,H,S,P)).
    """
    b, T, H, P = x.shape
    S = B.shape[-1]
    pad = (-T) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc = Tp // chunk

    # reshape to chunks, move chunk axis to front for scan
    xs = jnp.moveaxis(x.reshape(b, nc, chunk, H, P), 1, 0)
    dts = jnp.moveaxis(dt.reshape(b, nc, chunk, H), 1, 0)
    las = jnp.moveaxis(loga.reshape(b, nc, chunk, H), 1, 0)
    Bs = jnp.moveaxis(B.reshape(b, nc, chunk, S), 1, 0)
    Cs = jnp.moveaxis(C.reshape(b, nc, chunk, S), 1, 0)

    if h0 is None:
        h0 = jnp.zeros((b, H, S, P), jnp.float32)

    def chunk_step(h, inp):
        xc, dtc, lac, bc, cc = inp
        xc = xc.astype(jnp.float32)
        dtc = dtc.astype(jnp.float32)
        lac = lac.astype(jnp.float32)
        bc = bc.astype(jnp.float32)
        cc = cc.astype(jnp.float32)
        l = jnp.cumsum(lac, axis=1)                  # (b, Q, H)
        # intra-chunk
        g = jnp.einsum("bts,bus->btu", cc, bc)       # (b, Q, Q)
        q = xc.shape[1]
        ti = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
        ui = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
        causal = (ti >= ui)[None, :, :, None]
        # l is non-increasing, so causal (t >= u) exponents are <= 0;
        # clamping is exact there and keeps the non-causal entries
        # (discarded by the where) from overflowing exp in f32 — an inf
        # behind a where still poisons the BACKWARD pass (0 * inf = nan)
        decay = jnp.exp(jnp.minimum(
            l[:, :, None, :] - l[:, None, :, :], 0.0))        # (b,Q,Q,H)
        m = jnp.where(causal, g[..., None] * decay * dtc[:, None, :, :], 0.0)
        y = jnp.einsum("btuh,buhp->bthp", m, xc)
        # inter-chunk (carried state)
        cdec = cc[:, :, None, :] * jnp.exp(l)[..., None]      # (b,Q,H,S)
        y = y + jnp.einsum("bths,bhsp->bthp", cdec, h)
        # state update
        total = l[:, -1, :]                                   # (b, H)
        bdec = bc[:, :, None, :] * (jnp.exp(total[:, None, :] - l)
                                    * dtc)[..., None]         # (b,Q,H,S)
        h_new = jnp.exp(total)[..., None, None] * h + \
            jnp.einsum("bths,bthp->bhsp", bdec, xc)
        return h_new, y

    h_fin, ys = jax.lax.scan(chunk_step, h0, (xs, dts, las, Bs, Cs))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, Tp, H, P)[:, :T]
    return y.astype(x.dtype), h_fin


def apply_ssm(p, cfg, x, *, use_pallas: bool = False):
    """Full-sequence SSD block. x: (B, T, d) -> (B, T, d)."""
    b, T, d = x.shape
    d_in, H, P, S = _dims(cfg)
    dt_model = x.dtype

    proj = x @ p["w_in"].astype(dt_model)
    z, xs, Bm, Cm, dt_raw = _split_proj(cfg, proj)
    xs, _ = _causal_conv(xs, p["conv_x"])
    Bm, _ = _causal_conv(Bm, p["conv_B"])
    Cm, _ = _causal_conv(Cm, p["conv_C"])
    xs = jax.nn.silu(xs)
    Bm = jax.nn.silu(Bm)
    Cm = jax.nn.silu(Cm)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (b,T,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,)
    loga = dt * A                                             # (b,T,H)

    xh = xs.reshape(b, T, H, P)
    if use_pallas:
        from repro.kernels import ops as kops
        xbh = xh.transpose(0, 2, 1, 3).reshape(b * H, T, P)
        dtb = dt.transpose(0, 2, 1).reshape(b * H, T)
        lab = loga.transpose(0, 2, 1).reshape(b * H, T)
        Bb = jnp.broadcast_to(Bm[:, None], (b, H, T, S)).reshape(b * H, T, S)
        Cb = jnp.broadcast_to(Cm[:, None], (b, H, T, S)).reshape(b * H, T, S)
        ybh, _ = kops.ssd_scan(xbh, dtb, lab, Bb, Cb, chunk=cfg.ssm_chunk)
        y = ybh.reshape(b, H, T, P).transpose(0, 2, 1, 3)
    else:
        y, _ = ssd_chunked(xh, dt, loga, Bm, Cm, chunk=cfg.ssm_chunk)

    y = y + xh * p["D"].astype(dt_model)[None, None, :, None]
    y = y.reshape(b, T, d_in)
    y = y * jax.nn.silu(z)
    return y @ p["w_out"].astype(dt_model)


# ---------------------------------------------------------------------------
# decode: O(1) recurrent step
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    d_in, H, P, S = _dims(cfg)
    K = cfg.ssm_conv_width
    return {
        "h": jnp.zeros((batch, H, S, P), jnp.float32),
        "conv_x": jnp.zeros((batch, K - 1, d_in), dtype),
        "conv_B": jnp.zeros((batch, K - 1, S), dtype),
        "conv_C": jnp.zeros((batch, K - 1, S), dtype),
    }


def ssm_cache_logical_axes(cfg) -> dict:
    return {
        "h": ("cache_batch", "ssm_heads", "ssm_state", None),
        "conv_x": ("cache_batch", None, "ssm_in"),
        "conv_B": ("cache_batch", None, None),
        "conv_C": ("cache_batch", None, None),
    }


def decode_ssm(p, cfg, x, cache):
    """x: (B, 1, d) -> (y, new_cache)."""
    b = x.shape[0]
    d_in, H, P, S = _dims(cfg)
    dt_model = x.dtype

    proj = x @ p["w_in"].astype(dt_model)
    z, xs, Bm, Cm, dt_raw = _split_proj(cfg, proj)
    xs, cx = _causal_conv(xs, p["conv_x"], cache["conv_x"])
    Bm, cB = _causal_conv(Bm, p["conv_B"], cache["conv_B"])
    Cm, cC = _causal_conv(Cm, p["conv_C"], cache["conv_C"])
    xs = jax.nn.silu(xs)[:, 0]                    # (b, d_in)
    Bm = jax.nn.silu(Bm)[:, 0]                    # (b, S)
    Cm = jax.nn.silu(Cm)[:, 0]

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (b, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                                        # (b, H)

    xh = xs.reshape(b, H, P).astype(jnp.float32)
    # tensor-parallel decode: recurrent state sharded over SSM heads
    # (shape-aware — a no-op on single device / indivisible head counts)
    from repro.dist.sharding import hint
    xh = hint(xh, ("pod", "data"), "model", None)
    h = cache["h"]
    h = a[..., None, None] * h + \
        dt[..., None, None] * Bm[:, None, :, None] * xh[:, :, None, :]
    h = hint(h, ("pod", "data"), "model", None, None)
    y = jnp.einsum("bs,bhsp->bhp", Cm, h)                      # (b, H, P)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, d_in).astype(dt_model)
    y = y * jax.nn.silu(z)
    new_cache = {"h": h, "conv_x": cx.astype(cache["conv_x"].dtype),
                 "conv_B": cB.astype(cache["conv_B"].dtype),
                 "conv_C": cC.astype(cache["conv_C"].dtype)}
    return y @ p["w_out"].astype(dt_model), new_cache
