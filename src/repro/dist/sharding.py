"""Logical-axis sharding layer (DESIGN.md §2).

Model code names *logical* axes ("embed", "ffn", "cache_seq", ...);
this module owns the mapping onto *physical* mesh axes so that the
§Perf hillclimb can re-shard a phase by editing one rule table instead
of touching model code.

Three pieces:

* :class:`ShardingRules` — an ordered ``logical axis -> mesh axes``
  table.  ``rules.spec(axes, mesh)`` resolves a per-dimension tuple of
  logical names into a :class:`~jax.sharding.PartitionSpec`, silently
  dropping mesh axes the target mesh does not have (the same table
  serves the 256-chip single-pod and the 512-chip multi-pod mesh) and
  resolving duplicate-mesh-axis conflicts left-to-right (a mesh axis
  may shard at most one dimension of an array; the leftmost dimension
  that claims it wins).

* :func:`hint` — a ``with_sharding_constraint`` wrapper taking one
  *physical* spec entry per array dimension.  It is a no-op when no
  mesh is active (unit tests, simulation mode, CPU), so model code can
  hint unconditionally.

* :func:`drop_hint_axes` — a context manager that masks the named mesh
  axes out of every ``hint`` issued underneath it.  TT-HF scale mode
  uses it around the vmapped replica loss: the replica axes
  ``("pod", "data")`` are carried by the vmap dimension there, so the
  in-model batch hints must not re-claim them (DESIGN.md §4).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Iterable, Optional, Union

import jax
from jax.interpreters import pxla
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# one rule value: this logical axis is unsharded (None), sharded over
# one mesh axis ("model"), or sharded over several ( ("pod", "data") ).
MeshAxes = Union[None, str, tuple]


def _as_tuple(entry: MeshAxes) -> tuple:
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def _dim_entry(axes: tuple) -> Union[None, str, tuple]:
    """Canonical PartitionSpec entry for a resolved mesh-axis tuple."""
    if not axes:
        return None
    if len(axes) == 1:
        return axes[0]
    return tuple(axes)


class ShardingRules:
    """Ordered, immutable ``logical axis -> mesh axes`` rule table."""

    def __init__(self, rules: Iterable[tuple]):
        table = []
        seen = set()
        for name, entry in rules:
            if name in seen:
                raise ValueError(f"duplicate rule for logical axis {name!r}")
            seen.add(name)
            table.append((name, _as_tuple(entry)))
        self._rules = tuple(table)

    # -- introspection ----------------------------------------------------
    @property
    def rules(self) -> tuple:
        return self._rules

    def logical_axes(self) -> tuple:
        return tuple(name for name, _ in self._rules)

    def mesh_axes(self, logical: str) -> tuple:
        for name, entry in self._rules:
            if name == logical:
                return entry
        raise KeyError(
            f"no sharding rule for logical axis {logical!r}; known axes: "
            f"{self.logical_axes()}")

    # -- derivation -------------------------------------------------------
    def with_overrides(self, **overrides: MeshAxes) -> "ShardingRules":
        """New table with the named rules remapped in place (order kept);
        logical axes not previously present are appended."""
        pending = {k: _as_tuple(v) for k, v in overrides.items()}
        out = []
        for name, entry in self._rules:
            out.append((name, pending.pop(name, entry)))
        out.extend(pending.items())
        return ShardingRules(out)

    # -- resolution -------------------------------------------------------
    def spec(self, axes: tuple, mesh: Mesh) -> P:
        """Resolve per-dimension logical names into a PartitionSpec.

        ``axes``: one entry per array dimension — a logical axis name or
        None (dimension unconstrained).  Mesh axes absent from ``mesh``
        are dropped; a mesh axis already claimed by an earlier dimension
        is dropped from later ones (leftmost dimension wins).
        """
        present = set(mesh.axis_names)
        used: set = set()
        dims = []
        for a in axes:
            if a is None:
                dims.append(None)
                continue
            take = tuple(m for m in self.mesh_axes(a)
                         if m in present and m not in used)
            used.update(take)
            dims.append(_dim_entry(take))
        return P(*dims)

    def spec_for_shape(self, axes: tuple, shape: tuple, mesh: Mesh) -> P:
        """Shape-aware :meth:`spec`: a mesh axis only shards a dimension
        it evenly divides (otherwise it is dropped for that dimension —
        an array never fails to place, it degrades toward replication).

        Contested mesh axes go to the dimension whose logical axis
        appears EARLIEST IN THE RULE TABLE (``spec`` gives them to the
        leftmost dimension instead), so a table can express fallbacks:
        list ``cache_kv_heads -> model`` before ``cache_seq -> model``
        and the sequence dimension picks up ``model`` exactly when the
        head count does not divide it (small GQA configs).
        """
        if len(axes) != len(shape):
            raise ValueError(
                f"spec_for_shape got {len(axes)} axis entries for a "
                f"{len(shape)}-d shape {shape}")
        sizes = mesh_axis_sizes(mesh)
        prio = {name: i for i, (name, _) in enumerate(self._rules)}
        order = sorted((i for i, a in enumerate(axes) if a is not None),
                       key=lambda i: (prio.get(axes[i], len(prio)), i))
        used: set = set()
        take: dict = {}
        for i in order:
            got, prod = [], 1
            for m in self.mesh_axes(axes[i]):
                if m not in sizes or m in used:
                    continue
                if shape[i] % (prod * sizes[m]) != 0:
                    continue
                got.append(m)
                used.add(m)
                prod *= sizes[m]
            take[i] = tuple(got)
        return P(*[_dim_entry(take.get(i, ())) for i in range(len(axes))])


# ---------------------------------------------------------------------------
# activation hints
# ---------------------------------------------------------------------------

_local = threading.local()


def _dropped_axes() -> frozenset:
    return getattr(_local, "dropped", frozenset())


@contextmanager
def drop_hint_axes(axes: Iterable[str]):
    """Mask ``axes`` out of every :func:`hint` in this context.

    Nestable: inner contexts add to (never replace) the outer drop set.
    """
    prev = _dropped_axes()
    _local.dropped = prev | frozenset(axes)
    try:
        yield
    finally:
        _local.dropped = prev


def _ambient_mesh() -> Optional[Mesh]:
    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def mesh_axis_sizes(mesh) -> dict:
    """``{axis name: size}`` for a concrete :class:`Mesh` or an
    :class:`~jax.sharding.AbstractMesh` (both expose ``.shape``)."""
    return dict(mesh.shape)


def resolve_hint_spec(dim_specs: tuple, mesh: Mesh,
                      shape: Optional[tuple] = None) -> Optional[P]:
    """The PartitionSpec a :func:`hint` would pin on ``mesh`` right now
    (honoring the active :func:`drop_hint_axes` set), or None when every
    entry resolves empty (the hint is a no-op).

    With ``shape``, mesh axes that do not evenly divide their dimension
    are also dropped — a hint written for the production mesh degrades
    to a partial pin (or a no-op) on meshes whose factors don't fit,
    instead of failing to lower (serving small configs on host meshes).
    """
    present = set(mesh.axis_names)
    dropped = _dropped_axes()
    sizes = mesh_axis_sizes(mesh)
    used: set = set()
    dims = []
    for i, entry in enumerate(dim_specs):
        got, prod = [], 1
        for m in _as_tuple(entry):
            if m not in present or m in dropped or m in used:
                continue
            if shape is not None and shape[i] % (prod * sizes[m]) != 0:
                continue
            got.append(m)
            used.add(m)
            prod *= sizes[m]
        dims.append(_dim_entry(tuple(got)))
    return P(*dims) if used else None


def hint(x: jax.Array, *dim_specs: MeshAxes) -> jax.Array:
    """Pin ``x``'s sharding: one mesh-axes entry per array dimension.

    No-op when no mesh is active.  Entries naming mesh axes the active
    mesh lacks, axes masked by :func:`drop_hint_axes`, axes already
    claimed by an earlier dimension, or axes whose size does not evenly
    divide the dimension are dropped (never an error), so a single call
    site serves every mesh and the vmapped replica path.
    """
    if len(dim_specs) != x.ndim:
        raise ValueError(
            f"hint got {len(dim_specs)} axis entries for a {x.ndim}-d "
            f"array of shape {x.shape}")
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    spec = resolve_hint_spec(dim_specs, mesh, tuple(x.shape))
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


__all__ = ["ShardingRules", "hint", "drop_hint_axes", "resolve_hint_spec",
           "mesh_axis_sizes"]
