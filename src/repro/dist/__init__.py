"""``repro.dist`` — the logical-axis sharding layer (DESIGN.md §2).

Owns the mapping from model-declared logical axes to physical mesh
axes.  Model code imports :func:`hint`; step builders and TT-HF scale
mode build :class:`ShardingRules` tables; vmapped replica losses mask
the replica axes with :func:`drop_hint_axes`.
"""
from repro.dist.sharding import ShardingRules, drop_hint_axes, hint

__all__ = ["ShardingRules", "drop_hint_axes", "hint"]
