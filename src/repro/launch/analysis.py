"""Roofline-term extraction from compiled dry-run artifacts.

Sources:
* ``compiled.cost_analysis()`` — per-device HLO FLOPs and bytes accessed
* post-optimization HLO text — collective operand bytes (all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute)
* ``compiled.memory_analysis()`` — per-device HBM footprint

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI. cost_analysis and the partitioned HLO are PER DEVICE, so

  compute term    = flops_dev / peak
  memory term     = bytes_dev / hbm_bw
  collective term = coll_bytes_dev / ici_bw
  (equals the brief's global/(chips x bw) forms.)
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g. "bf16[8,4096,128]{2,1,0}"
_SHAPE_RE = re.compile(r"\b([a-z]+\d+|pred)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op (per device).

    We take the *result* shape(s) on the lhs of each collective line —
    for all-gather that is the gathered (larger) buffer, for
    reduce-scatter the scattered one; a reasonable single-number proxy
    for link traffic either way.
    """
    out: dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    counts: dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        if "-done(" in line:   # avoid double counting start/done pairs
            continue
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1]
        # shapes on the line up to the opcode occurrence: take the first
        # shape group (the result type annotation right after '=')
        seg = line.split("=", 1)[1]
        shapes = _SHAPE_RE.findall(seg.split(m.group(1))[0])
        for dtype, dims in shapes:
            out[op] += _shape_bytes(dtype, dims)
        counts[op] += 1
    out["_counts"] = counts
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_dev: float
    bytes_dev: float
    coll_bytes_dev: float
    coll_breakdown: dict
    peak_memory_dev: float
    model_flops: float          # 6 * N_active * tokens (per device share)
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the roofline attained at the dominant bound:
        compute term / max(all terms). 1.0 = compute-bound (running at
        peak FLOPs if the bound is met); below 1.0 the gap is the
        memory/collective overhang."""
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        return self.compute_s / bound if bound else 0.0

    def to_dict(self):
        d = asdict(self)
        d["dominant"] = self.dominant
        d["roofline_fraction"] = self.roofline_fraction
        d["useful_flops_frac"] = (
            self.model_flops / self.flops_dev if self.flops_dev else 0.0)
        return d


def analyze(compiled, *, arch: str, shape, mesh_name: str, chips: int,
            model_flops_total: float) -> Roofline:
    """Roofline terms from the compiled artifact.

    NOTE: XLA's ``cost_analysis()`` visits loop bodies once, so for
    scan-over-layers models it undercounts by ~the layer count; we use
    the trip-count-aware HLO walker (``hlo_cost.analyze_hlo``) instead
    and keep XLA's numbers in the breakdown for reference.
    """
    from repro.launch.hlo_cost import analyze_hlo
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older jax returns [dict]
        cost = cost[0]
    mem = compiled.memory_analysis()
    peak = float(getattr(mem, "temp_size_in_bytes", 0)
                 + getattr(mem, "argument_size_in_bytes", 0)
                 + getattr(mem, "output_size_in_bytes", 0))
    hlo = compiled.as_text()
    hc = analyze_hlo(hlo)
    flops = hc.flops
    byts = hc.bytes
    coll_total = hc.coll_total
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_dev=flops, bytes_dev=byts, coll_bytes_dev=coll_total,
        coll_breakdown={
            **{k: v for k, v in hc.coll_bytes.items() if v},
            "counts": {k: v for k, v in hc.coll_counts.items() if v},
            "xla_flops_once": float(cost.get("flops", 0.0)),
            "xla_bytes_once": float(cost.get("bytes accessed", 0.0)),
        },
        peak_memory_dev=peak,
        model_flops=model_flops_total / chips,
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll_total / ICI_BW,
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D tokens for train (fwd+bwd), 2*N*D for
    inference; N = active params."""
    n = cfg.active_param_count()
    if shape.phase == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.phase == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
