"""Training driver.

Two modes:
* ``--mode sim``   — the paper's Algorithm 1 on the federated image task
                     (Sec. IV experimental setup; runs on this CPU box).
* ``--mode scale`` — TT-HF as the sync strategy for a model-zoo arch
                     (``--arch``), on whatever devices exist (use the
                     dry-run for the production mesh).

Examples:
  python -m repro.launch.train --mode sim --model svm --steps 200
  python -m repro.launch.train --mode scale --arch qwen1.5-0.5b \
      --reduced --steps 2 --sync tthf
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np


def build_program(args, tau: int):
    """The declarative round program (DESIGN.md §10): ONE object
    declares the scenario — optional netsim dynamics, optional fog
    hierarchy — and both trainers resolve it, instead of each mode
    threading per-scenario knobs through per-scenario loops."""
    from repro.rounds import RoundProgram

    dynamics = hierarchy = None
    if args.scenario:
        from repro.netsim import scenarios
        dynamics = scenarios.get(args.scenario, seed=args.seed)
    if args.hierarchy:
        from repro.hierarchy import presets
        hierarchy = presets.get(args.hierarchy, tau=tau)
    return RoundProgram(dynamics=dynamics, hierarchy=hierarchy)


def run_sim(args):
    import jax
    from repro.configs import TopologyConfig, TTHFConfig
    from repro.core import TTHFTrainer, make_baseline_config
    from repro.data import fashion_synth, partition_noniid_labels
    from repro.models import make_sim_model

    x, y = fashion_synth(num_points=args.points, seed=args.seed)
    data = partition_noniid_labels(x, y, num_devices=args.devices,
                                   labels_per_device=3, seed=args.seed)
    topo = TopologyConfig(num_devices=args.devices,
                          num_clusters=args.clusters,
                          graph="geometric", seed=args.seed)
    model = make_sim_model(args.model, data.feature_dim, data.num_classes,
                           hidden=args.hidden)
    if args.baseline:
        algo = make_baseline_config(args.baseline, args.tau)
        algo = dataclasses.replace(algo, constant_lr=args.lr)
    else:
        algo = TTHFConfig(tau=args.tau, consensus_every=args.consensus_every,
                          gamma_d2d=args.gamma, constant_lr=args.lr,
                          phi=args.phi)
    tr = TTHFTrainer(model, data, topo, algo, batch_size=args.batch,
                     program=build_program(args, algo.tau))
    # observability (repro.obs §13): --trace-dir turns on spans +
    # theory-bound telemetry + manifest; --profile adds jax.profiler
    from repro.obs.sink import make_obs
    obs = make_obs(args.trace_dir, profile=args.profile,
                   run_name="train-sim",
                   config={"args": vars(args), "algo": algo, "topo": topo},
                   extra={"mode": "sim", "model": args.model})
    t0 = time.time()
    try:
        st, hist = tr.run(steps=args.steps, seed=args.seed,
                          eval_every=args.eval_every, obs=obs)
    finally:
        obs.close()
    dt = time.time() - t0
    by_level = "".join(f" L{l}={n}" for l, n in
                       sorted(tr.ledger.uplinks_by_level.items()))
    print(f"steps={args.steps} wall={dt:.1f}s "
          f"final_loss={hist.global_loss[-1]:.4f} "
          f"final_acc={hist.global_acc[-1]:.4f} "
          f"uplinks={tr.ledger.uplinks}{by_level} "
          f"d2d_msgs={tr.ledger.d2d_msgs}")
    if args.out:
        json.dump({k: np.asarray(v).tolist()
                   for k, v in hist.as_arrays().items()},
                  open(args.out, "w"))
    return 0


def run_scale(args):
    from repro.configs import get_arch
    from repro.core.distributed import TTHFScaleConfig
    from repro.train import ScaleTrainer, TrainerConfig

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    # consensus_every must divide tau (static event calendar): snap to
    # the nearest divisor <= requested
    ce = max(1, min(args.consensus_every, args.tau))
    while args.tau % ce:
        ce -= 1
    scale = TTHFScaleConfig(replicas=args.replicas,
                            cluster_size=args.cluster_size,
                            tau=args.tau,
                            consensus_every=ce,
                            gamma_d2d=args.gamma, lr=args.lr,
                            consensus_mode=args.consensus_mode)
    # every scenario — flat, dynamic, hierarchical — is the same
    # ScaleTrainer loop over a resolved round program
    tr = ScaleTrainer(
        cfg, scale,
        TrainerConfig(batch_per_replica=args.batch, seq_len=args.seq,
                      intervals=args.steps, eval_every=0,
                      seed=args.seed, trace_dir=args.trace_dir,
                      profile=args.profile),
        sync=args.sync, program=build_program(args, args.tau))
    t0 = time.time()
    try:
        tr.init().run()
    finally:
        tr.close()
    by_level = "".join(f" L{l}={n}" for l, n in
                       sorted(tr.ledger.uplinks_by_level.items()))
    print(f"intervals={tr.interval} wall={time.time() - t0:.1f}s "
          f"uplinks={tr.ledger.uplinks}{by_level} "
          f"d2d_msgs={tr.ledger.d2d_msgs} (tau={scale.tau} local steps "
          f"per interval, sync={args.sync})")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["sim", "scale"], default="sim")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tau", type=int, default=20)
    ap.add_argument("--gamma", type=int, default=2)
    ap.add_argument("--consensus-every", type=int, default=5)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace-dir", default=None,
                    help="observability dir (repro.obs): Chrome trace, "
                         "metrics.jsonl telemetry, run manifest")
    ap.add_argument("--profile", action="store_true",
                    help="also wrap the run in jax.profiler.trace "
                         "(written under <trace-dir>/jax_profile)")
    ap.add_argument("--scenario", default=None,
                    help="netsim dynamics scenario (see repro.netsim."
                         "scenarios; e.g. markov_links, device_churn)")
    ap.add_argument("--hierarchy", default=None,
                    help="fog-hierarchy preset (see repro.hierarchy."
                         "presets; e.g. fog3, fog4, fog3_sampled)")
    # sim
    ap.add_argument("--model", choices=["svm", "nn"], default="svm")
    ap.add_argument("--devices", type=int, default=125)
    ap.add_argument("--clusters", type=int, default=25)
    ap.add_argument("--points", type=int, default=12_500)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--phi", type=float, default=1.0)
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--baseline", choices=["centralized", "fedavg"],
                    default=None)
    # scale
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--cluster-size", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--sync", choices=["tthf", "star", "local"],
                    default="tthf")
    ap.add_argument("--consensus-mode", choices=["fused", "rounds"],
                    default="fused")
    args = ap.parse_args(argv)
    return run_sim(args) if args.mode == "sim" else run_scale(args)


if __name__ == "__main__":
    import sys
    sys.exit(main())
