"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``lax.scan`` of 48 layers reports 1/48th of the real FLOPs, and
collectives inside the loop (FSDP weight gathers!) are counted once.
This module re-derives per-device costs from the post-optimization HLO
text, recursively multiplying while-loop bodies by their trip counts:

  * flops      — 2 * prod(result) * prod(contracting dims) per dot
                 (MXU work; elementwise/transcendental ops are ignored,
                 which underestimates by <5% for transformer workloads)
  * bytes      — operand + result bytes per op line (a proxy for HBM
                 traffic assuming no fusion reuse: an overestimate
                 inside fusions, an underestimate across them)
  * collective — result-shape bytes per all-gather / all-reduce /
                 reduce-scatter / all-to-all / collective-permute,
                 split by op kind

Trip counts come from the loop-condition computation's compare bound
(scan lowers to a 0-based LT-bounded while loop).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w.\-_]+)\s*\(")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-_]+)\s*=\s*(.*)$")
_SHAPE_RE = re.compile(r"^\(?\s*([a-z]+\d*|pred|token|opaque)\[([\d,]*)\]")
_TUPLE_SHAPES = re.compile(r"([a-z]+\d*|pred)\[([\d,]*)\]")
_OPCODE_RE = re.compile(r"\}?\s*([a-z][a-z0-9\-]*)\(")
_CALLS_RE = re.compile(r"calls=%?([\w.\-_]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-_]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-_]+),\s*body=%?([\w.\-_]+)")
_OPERANDS_RE = re.compile(r"%([\w.\-_]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _shape_elems(dims) * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class _Op:
    name: str
    dtype: str
    dims: str
    opcode: str
    line: str


@dataclass
class _Computation:
    name: str
    ops: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # value name -> (dtype, dims)


def _parse(text: str) -> tuple[dict, str]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        # computation headers sit at column 0 and end with "{"
        if line and not raw.startswith(" ") and line.endswith("{") \
                and "->" in line:
            m = _COMP_HDR.match(line)
            if m:
                cur = _Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rhs = dm.groups()
        sm = _SHAPE_RE.match(rhs)
        if sm:
            dtype, dims = sm.groups()
        else:
            dtype, dims = "opaque", ""
        rest = rhs[sm.end():] if sm else rhs
        om = _OPCODE_RE.search(rest)
        opcode = om.group(1) if om else "unknown"
        cur.shapes[name] = (dtype, dims)
        cur.ops.append(_Op(name, dtype, dims, opcode, rhs))
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _trip_count(cond: _Computation) -> int:
    consts = [int(m.group(1)) for op in cond.ops
              for m in [_CONST_RE.search(op.line)] if m]
    return max(consts) if consts and max(consts) > 0 else 1


def _dot_flops(op: _Op, comp: _Computation) -> float:
    res = _shape_elems(op.dims)
    lc = _LHS_CONTRACT.search(op.line)
    # first operand name after the opcode
    args = op.line.split("dot(", 1)[1]
    names = _OPERANDS_RE.findall(args)
    if not names:
        return 0.0
    lhs = comp.shapes.get(names[0])
    if lhs is None:
        return 2.0 * res   # unknown operand: assume K=1
    ldims = lhs[1].split(",") if lhs[1] else []
    k = 1
    if lc and lc.group(1):
        for i in lc.group(1).split(","):
            idx = int(i)
            if idx < len(ldims):
                k *= int(ldims[idx])
    return 2.0 * res * k


def _result_bytes(op: _Op) -> float:
    if op.dtype != "opaque":
        return float(_shape_bytes(op.dtype, op.dims))
    # tuple-typed results: sum the element shapes before the opcode
    head = op.line.split(op.opcode + "(", 1)[0]
    return float(sum(_shape_bytes(dt, dims)
                     for dt, dims in _TUPLE_SHAPES.findall(head)))


def _op_bytes(op: _Op, comp: _Computation) -> float:
    total = 0.0
    if op.dtype != "opaque" and "[" in op.line:
        if op.line.startswith("("):
            for dt, dims in _TUPLE_SHAPES.findall(op.line.split(")", 1)[0]):
                total += _shape_bytes(dt, dims)
        else:
            total += _shape_bytes(op.dtype, op.dims)
    # operand bytes (looked up)
    tail = op.line.split("(", 1)
    if len(tail) == 2:
        for nm in _OPERANDS_RE.findall(tail[1]):
            sh = comp.shapes.get(nm)
            if sh and sh[0] != "opaque":
                total += _shape_bytes(sh[0], sh[1])
    return total


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = field(default_factory=dict)
    coll_counts: dict = field(default_factory=dict)

    @property
    def coll_total(self) -> float:
        return float(sum(self.coll_bytes.values()))


_MEMORY_OPS = ("add", "multiply", "subtract", "divide", "exponential",
               "tanh", "rsqrt", "log", "maximum", "minimum", "compare",
               "select", "convert", "reduce", "broadcast", "transpose",
               "copy", "dynamic-slice", "dynamic-update-slice",
               "concatenate", "slice", "pad", "gather", "scatter")


def analyze_hlo(text: str) -> HloCost:
    comps, entry = _parse(text)
    memo: dict[tuple, HloCost] = {}

    def cost_of(name: str, count_bytes: bool = True) -> HloCost:
        key = (name, count_bytes)
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        out = HloCost(coll_bytes={k: 0.0 for k in COLLECTIVES},
                      coll_counts={k: 0 for k in COLLECTIVES})
        memo[key] = out             # break cycles defensively
        if comp is None:
            return out
        for op in comp.ops:
            if op.opcode == "dot":
                out.flops += _dot_flops(op, comp)
                if count_bytes:
                    out.bytes += _op_bytes(op, comp)
            elif op.opcode == "while":
                wm = _WHILE_RE.search(op.line)
                if wm:
                    cond, body = wm.groups()
                    trips = _trip_count(comps.get(cond, _Computation("")))
                    sub = cost_of(body, count_bytes)
                    out.flops += trips * sub.flops
                    out.bytes += trips * sub.bytes
                    for k in COLLECTIVES:
                        out.coll_bytes[k] += trips * sub.coll_bytes[k]
                        out.coll_counts[k] += trips * sub.coll_counts[k]
            elif op.opcode in ("fusion", "call", "conditional",
                               "async-start"):
                # fusion internals live in registers: count flops and
                # collectives from inside, but HBM bytes only at the
                # fusion boundary (its operands + result)
                inner_bytes = count_bytes and op.opcode != "fusion"
                for target in (_CALLS_RE.findall(op.line)
                               + _TO_APPLY_RE.findall(op.line)):
                    sub = cost_of(target, inner_bytes)
                    out.flops += sub.flops
                    out.bytes += sub.bytes
                    for k in COLLECTIVES:
                        out.coll_bytes[k] += sub.coll_bytes[k]
                        out.coll_counts[k] += sub.coll_counts[k]
                if op.opcode == "fusion" and count_bytes:
                    # boundary traffic ~ 2x result (operand shapes lie:
                    # loop fusions take whole stacked tensors but read
                    # one dynamic slice per call)
                    out.bytes += 2.0 * _result_bytes(op)
            else:
                base = op.opcode.replace("-start", "").replace("-done", "")
                if base in COLLECTIVES and not op.opcode.endswith("-done"):
                    out.coll_bytes[base] += _op_bytes(op, comp)
                    out.coll_counts[base] += 1
                elif count_bytes and op.opcode in _MEMORY_OPS:
                    if op.dtype != "opaque":
                        out.bytes += _shape_bytes(op.dtype, op.dims)
        return out

    return cost_of(entry)
