"""Serving driver: batched prefill + decode of a model-zoo arch.

Four modes:
  direct      — one fixed batch, joint prefill, lockstep decode
  wave        — BatchScheduler: admit a wave, drain, admit the next
  continuous  — ContinuousScheduler: per-slot admission/retirement
  paged       — PagedContinuousScheduler: block/page KV cache with
                prefix sharing + chunked prefill (DESIGN.md §15);
                tune with --page-size/--cache-pages/--prefill-chunk,
                exercise prefix sharing with --prefix-template

Multi-device: ``--mesh host|data|AxB`` serves sharded over this
process's devices (params tensor-parallel over ``model``, cache leaves
along heads/experts, slots over ``data`` — DESIGN.md §14).
``--host-devices N`` forces N simulated host devices (must be the
FIRST jax configuration of the process; it sets XLA_FLAGS before jax
initializes).

Example (CPU, reduced config):
  python -m repro.launch.serve --arch mamba2-370m --reduced \
      --batch 4 --prompt-len 64 --gen 16
  python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --scheduler continuous --requests 12 --gen 16 \
      --host-devices 8 --mesh host
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np


def _run_scheduler(args, cfg, model, params, mesh):
    import jax.numpy as jnp
    from repro.obs.sink import make_obs
    from repro.serving import Request, make_scheduler, run_trace

    rng = np.random.default_rng(args.seed)
    obs = make_obs(args.trace_dir, profile=args.profile,
                   run_name="serve",
                   config={"args": vars(args)},
                   extra={"arch": cfg.name, "scheduler": args.scheduler,
                          "mesh": args.mesh or "single",
                          "devices": 1 if mesh is None
                          else int(mesh.devices.size)})
    cache_dtype = {"f32": jnp.float32, "bf16": jnp.bfloat16}[
        args.cache_dtype]
    kw = dict(slots=args.batch, max_prompt=args.prompt_len,
              max_total=args.prompt_len + args.gen,
              temperature=args.temperature, seed=args.seed,
              cache_dtype=cache_dtype, obs=obs, mesh=mesh)
    if args.scheduler == "paged":
        kw["page_size"] = args.page_size
        if args.cache_pages:
            kw["cache_pages"] = args.cache_pages
        if args.prefill_chunk:
            kw["prefill_chunk"] = args.prefill_chunk
    sched = make_scheduler(args.scheduler, model, **kw)
    arrivals = []
    step = 0
    tmpl = None
    if args.prefix_template:
        # shared template prefix across every prompt — the prefix-
        # sharing trace: after the first admission the trie serves the
        # template's full pages to everyone else
        tmpl = rng.integers(1, cfg.vocab_size,
                            size=args.prefix_template).astype(np.int32)
    for rid in range(args.requests):
        plen = int(rng.integers(max(1, args.prompt_len // 4),
                                args.prompt_len + 1))
        prompt = rng.integers(1, cfg.vocab_size, size=plen).astype(np.int32)
        if tmpl is not None:
            prompt = np.concatenate(
                [tmpl, prompt])[:args.prompt_len].astype(np.int32)
        arrivals.append((step, Request(rid=rid, prompt=prompt,
                                       max_new=args.gen)))
        step += int(rng.poisson(args.arrival_gap))
    t0 = time.time()
    try:
        stats = run_trace(sched, params, arrivals)
        if obs.enabled:
            # one JSONL record per retired request — queue latency and
            # TTFT in step-clock ticks, same stream as everything else
            for r in stats.records:
                obs.emit("request", r.retire, rid=r.rid,
                         submit=r.submit, admit=r.admit,
                         first_token=r.first_token,
                         queue_latency=r.queue_latency, ttft=r.ttft,
                         decode=r.decode, budget=r.budget,
                         prefill_chunks=r.prefill_chunks,
                         prefix_pages_reused=r.prefix_pages_reused)
    finally:
        obs.close()
    dt = time.time() - t0
    ndev = 1 if mesh is None else int(mesh.devices.size)
    print(f"arch={cfg.name} scheduler={args.scheduler} slots={args.batch} "
          f"requests={args.requests} devices={ndev}")
    print(f"done={stats.requests_done} prefills={stats.prefills} "
          f"decode_steps={stats.decode_steps} "
          f"tokens={stats.tokens_generated} "
          f"util={stats.utilization:.2f} "
          f"({stats.tokens_generated / max(dt, 1e-9):.1f} tok/s)")
    if stats.records:
        ql = np.array([r.queue_latency for r in stats.records])
        tt = np.array([r.ttft for r in stats.records if r.ttft >= 0])
        if len(tt):
            print(f"queue latency (steps): p50={np.percentile(ql, 50):.0f} "
                  f"p95={np.percentile(ql, 95):.0f}  "
                  f"ttft: p50={np.percentile(tt, 50):.0f} "
                  f"p95={np.percentile(tt, 95):.0f}")
    if args.scheduler == "paged":
        reused = sum(r.prefix_pages_reused for r in stats.records)
        print(f"pages: size={sched.page_size} pool={sched.cache_pages} "
              f"free={sched.table.num_free} "
              f"prefix_hit_rate={sched.prefix_hit_rate:.2f} "
              f"pages_reused={reused} "
              f"deferrals={sched.page_deferrals}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--scheduler", default="direct",
                    choices=["direct", "wave", "continuous", "paged"],
                    help="direct: one fixed batch; wave/continuous/"
                         "paged: request schedulers over --requests "
                         "arrivals")
    ap.add_argument("--requests", type=int, default=8,
                    help="number of requests for scheduler modes")
    ap.add_argument("--cache-dtype", default="f32",
                    choices=["f32", "bf16"],
                    help="KV/state cache storage dtype (compute stays "
                         "f32)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged scheduler: tokens per cache page")
    ap.add_argument("--cache-pages", type=int, default=0,
                    help="paged scheduler: total page-pool size incl. "
                         "the dummy page (0 = ring-equivalent capacity); "
                         "smaller pools trade capacity for deferrals")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="paged scheduler: prefill chunk length in "
                         "tokens, page-size multiple (0 = one-shot)")
    ap.add_argument("--prefix-template", type=int, default=0,
                    help="share a random N-token template prefix across "
                         "all prompts (prefix-sharing trace)")
    ap.add_argument("--arrival-gap", type=float, default=2.0,
                    help="mean Poisson inter-arrival gap (decode steps)")
    ap.add_argument("--mesh", default=None,
                    help="serve sharded over this process's devices: "
                         "'host' (all tensor-parallel), 'data' (all "
                         "data-parallel), or 'AxB' (data x model)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N simulated host devices (sets XLA_FLAGS "
                         "before jax initializes)")
    ap.add_argument("--trace-dir", default=None,
                    help="observability dir (repro.obs): Chrome trace, "
                         "per-request latency JSONL, run manifest")
    ap.add_argument("--profile", action="store_true",
                    help="also wrap the run in jax.profiler.trace")
    args = ap.parse_args(argv)

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count="
              f"{args.host_devices}")

    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serving import sample_tokens, serve_shardings, shard_params

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    key = jax.random.PRNGKey(args.seed + 1)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(args.mesh)
        params = shard_params(params, model, mesh)

    if args.scheduler != "direct":
        return _run_scheduler(args, cfg, model, params, mesh)

    B, T = args.batch, args.prompt_len
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.kind == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model)) * 0.1
    if cfg.kind in ("encdec", "audio"):
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model)) * 0.1

    total = T + args.gen + (cfg.enc_seq_len if cfg.kind == "vlm" else 0)
    jit_kw_pf, jit_kw_dec = {}, {}
    from contextlib import nullcontext
    ctx = nullcontext() if mesh is None else mesh
    if mesh is not None:
        sh = serve_shardings(model, mesh, slots=B, max_total=total,
                             dtype=jnp.float32)
        jit_kw_pf = {"out_shardings": (sh.logits, sh.cache,
                                       sh.replicated)}
        jit_kw_dec = {"out_shardings": (sh.logits, sh.cache)}
    t0 = time.time()
    prefill = jax.jit(lambda p, b: model.prefill(
        p, b, dtype=jnp.float32, cache_dtype=jnp.float32,
        cache_len=total), **jit_kw_pf)
    with ctx:
        logits, cache, pos = prefill(params, batch)
    t_prefill = time.time() - t0
    decode = jax.jit(lambda p, t, c, s: model.decode_step(
        p, t, c, s, dtype=jnp.float32), **jit_kw_dec)

    out_tokens = []
    t0 = time.time()
    for i in range(args.gen):
        key, ks = jax.random.split(key)
        tok = sample_tokens(logits, temperature=args.temperature, key=ks)
        out_tokens.append(np.asarray(tok)[:, 0])
        with ctx:
            logits, cache = decode(params, tok, cache, pos)
        pos = pos + 1
    t_decode = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    ndev = 1 if mesh is None else int(mesh.devices.size)
    print(f"arch={cfg.name} B={B} prompt={T} gen={args.gen} "
          f"devices={ndev}")
    print(f"prefill: {t_prefill:.2f}s  decode: {t_decode:.2f}s "
          f"({args.gen * B / max(t_decode, 1e-9):.1f} tok/s)")
    print("sampled token ids (first row):", gen[0].tolist())
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
