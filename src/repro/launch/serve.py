"""Serving driver: batched prefill + decode of a model-zoo arch.

Three modes:
  direct      — one fixed batch, joint prefill, lockstep decode
  wave        — BatchScheduler: admit a wave, drain, admit the next
  continuous  — ContinuousScheduler: per-slot admission/retirement

Example (CPU, reduced config):
  python -m repro.launch.serve --arch mamba2-370m --reduced \
      --batch 4 --prompt-len 64 --gen 16
  python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --scheduler continuous --requests 12 --gen 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _run_scheduler(args, cfg, model, params):
    from repro.obs.sink import make_obs
    from repro.serving.scheduler import Request, make_scheduler, run_trace

    rng = np.random.default_rng(args.seed)
    obs = make_obs(args.trace_dir, profile=args.profile,
                   run_name="serve",
                   config={"args": vars(args)},
                   extra={"arch": cfg.name, "scheduler": args.scheduler})
    sched = make_scheduler(args.scheduler, model, slots=args.batch,
                           max_prompt=args.prompt_len,
                           max_total=args.prompt_len + args.gen,
                           temperature=args.temperature, seed=args.seed,
                           obs=obs)
    arrivals = []
    step = 0
    for rid in range(args.requests):
        plen = int(rng.integers(max(1, args.prompt_len // 4),
                                args.prompt_len + 1))
        prompt = rng.integers(1, cfg.vocab_size, size=plen).astype(np.int32)
        arrivals.append((step, Request(rid=rid, prompt=prompt,
                                       max_new=args.gen)))
        step += int(rng.poisson(args.arrival_gap))
    t0 = time.time()
    try:
        stats = run_trace(sched, params, arrivals)
        if obs.enabled:
            # one JSONL record per retired request — queue latency and
            # TTFT in step-clock ticks, same stream as everything else
            for r in stats.records:
                obs.emit("request", r.retire, rid=r.rid,
                         submit=r.submit, admit=r.admit,
                         first_token=r.first_token,
                         queue_latency=r.queue_latency, ttft=r.ttft,
                         decode=r.decode, budget=r.budget)
    finally:
        obs.close()
    dt = time.time() - t0
    print(f"arch={cfg.name} scheduler={args.scheduler} slots={args.batch} "
          f"requests={args.requests}")
    print(f"done={stats.requests_done} prefills={stats.prefills} "
          f"decode_steps={stats.decode_steps} "
          f"tokens={stats.tokens_generated} "
          f"util={stats.utilization:.2f} "
          f"({stats.tokens_generated / max(dt, 1e-9):.1f} tok/s)")
    if stats.records:
        ql = np.array([r.queue_latency for r in stats.records])
        tt = np.array([r.ttft for r in stats.records if r.ttft >= 0])
        if len(tt):
            print(f"queue latency (steps): p50={np.percentile(ql, 50):.0f} "
                  f"p95={np.percentile(ql, 95):.0f}  "
                  f"ttft: p50={np.percentile(tt, 50):.0f} "
                  f"p95={np.percentile(tt, 95):.0f}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--scheduler", default="direct",
                    choices=["direct", "wave", "continuous"],
                    help="direct: one fixed batch; wave/continuous: "
                         "request schedulers over --requests arrivals")
    ap.add_argument("--requests", type=int, default=8,
                    help="number of requests for scheduler modes")
    ap.add_argument("--arrival-gap", type=float, default=2.0,
                    help="mean Poisson inter-arrival gap (decode steps)")
    ap.add_argument("--trace-dir", default=None,
                    help="observability dir (repro.obs): Chrome trace, "
                         "per-request latency JSONL, run manifest")
    ap.add_argument("--profile", action="store_true",
                    help="also wrap the run in jax.profiler.trace")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serving.sampling import sample_tokens

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    key = jax.random.PRNGKey(args.seed + 1)

    if args.scheduler != "direct":
        return _run_scheduler(args, cfg, model, params)

    B, T = args.batch, args.prompt_len
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.kind == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model)) * 0.1
    if cfg.kind in ("encdec", "audio"):
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model)) * 0.1

    total = T + args.gen + (cfg.enc_seq_len if cfg.kind == "vlm" else 0)
    t0 = time.time()
    prefill = jax.jit(lambda p, b: model.prefill(
        p, b, dtype=jnp.float32, cache_dtype=jnp.float32, cache_len=total))
    logits, cache, pos = prefill(params, batch)
    t_prefill = time.time() - t0
    decode = jax.jit(lambda p, t, c, s: model.decode_step(
        p, t, c, s, dtype=jnp.float32))

    out_tokens = []
    t0 = time.time()
    for i in range(args.gen):
        key, ks = jax.random.split(key)
        tok = sample_tokens(logits, temperature=args.temperature, key=ks)
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, tok, cache, pos)
        pos = pos + 1
    t_decode = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.name} B={B} prompt={T} gen={args.gen}")
    print(f"prefill: {t_prefill:.2f}s  decode: {t_decode:.2f}s "
          f"({args.gen * B / max(t_decode, 1e-9):.1f} tok/s)")
    print("sampled token ids (first row):", gen[0].tolist())
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
