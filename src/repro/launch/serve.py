"""Serving driver: batched prefill + decode of a model-zoo arch.

Example (CPU, reduced config):
  python -m repro.launch.serve --arch mamba2-370m --reduced \
      --batch 4 --prompt-len 64 --gen 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.models import build_model

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    key = jax.random.PRNGKey(args.seed + 1)

    B, T = args.batch, args.prompt_len
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.kind == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model)) * 0.1
    if cfg.kind in ("encdec", "audio"):
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model)) * 0.1

    total = T + args.gen + (cfg.enc_seq_len if cfg.kind == "vlm" else 0)
    t0 = time.time()
    prefill = jax.jit(lambda p, b: model.prefill(
        p, b, dtype=jnp.float32, cache_dtype=jnp.float32, cache_len=total))
    logits, cache, pos = prefill(params, batch)
    t_prefill = time.time() - t0
    decode = jax.jit(lambda p, t, c, s: model.decode_step(
        p, t, c, s, dtype=jnp.float32))

    out_tokens = []
    t0 = time.time()
    for i in range(args.gen):
        key, ks = jax.random.split(key)
        if args.temperature > 0:
            tok = jax.random.categorical(
                ks, logits[:, -1] / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, tok.astype(jnp.int32), cache, pos)
        pos = pos + 1
    t_decode = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.name} B={B} prompt={T} gen={args.gen}")
    print(f"prefill: {t_prefill:.2f}s  decode: {t_decode:.2f}s "
          f"({args.gen * B / max(t_decode, 1e-9):.1f} tok/s)")
    print("sampled token ids (first row):", gen[0].tolist())
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
