"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes, print memory/cost analyses, and dump roofline terms.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh pod --out benchmarks/results
  python -m repro.launch.dryrun --all --mesh multipod   # 2x16x16
  python -m repro.launch.dryrun --serve --arch llama4_maverick_400b_a17b \
      --mesh multipod --out benchmarks/results   # sharded serving pair

Each combo can also be run in a fresh subprocess (--subprocess) so one
failure/compile-OOM cannot take down the sweep; that is how
``benchmarks/roofline.py`` drives it.

Importing this module is side-effect free. XLA is configured by
``main()`` AFTER argparse and BEFORE the first jax import — the host
placeholder device count must match the requested mesh, and flags are
frozen once jax initializes, so every jax/repro import in this file
lives inside a function.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
import traceback

# (arch, shape) combos that are intentionally skipped, with reasons
# (see DESIGN.md §6).
SKIPS: dict[tuple[str, str], str] = {
    ("whisper-small", "long_500k"):
        "encoder-decoder ASR: 524k-token decode is not meaningful for a "
        "1500-frame/448-token enc-dec model (DESIGN.md §6).",
}

# pods per multi-pod mesh variant (absent key = single pod)
MESH_PODS = {"multipod": 2, "multipod10k": 40}


def configure_xla(args) -> None:
    """Set XLA_FLAGS from the parsed args. Must run before jax init.

    Device count: 512 for pod/multipod, 10,240 for the scale-out
    lowering check (--mesh multipod10k = 40 pods x 256).

    XLA's while-loop LICM hoists dtype converts of the remat residual
    stack OUT of the backward loop, materializing a full fp32 copy of
    the per-layer activations (2-30 GB) — disable it for TRAINING
    dry-runs. For SERVING dry-runs (--serve, or a decode/prefill
    --shape) LICM must stay ON: it hoists the (loop-invariant) K/V
    gathers out of the flash kv scan; without it every block re-gathers
    the full cache.
    """
    ndev = 10_240 if args.mesh == "multipod10k" else 512
    flags = (os.environ.get("XLA_FLAGS", "")
             + f" --xla_force_host_platform_device_count={ndev}")
    is_train = (not args.serve
                and (args.all or args.shape in (None, "train_4k")
                     or args.sync != "baseline"))
    if is_train:
        flags += " --xla_disable_hlo_passes=while-loop-invariant-code-motion"
    os.environ["XLA_FLAGS"] = flags


def build_tthf_program(model, shape, mesh, sync: str, consensus_mode: str,
                       tau: int = 8, consensus_every: int = 4,
                       gamma: int = 2, fused_interval: bool = False,
                       donate: bool = True):
    """Lower one full TT-HF interval (Algorithm 1 lines 4-15) on the
    production mesh: replicas = pod*data slices, clusters = data-blocks
    (multi-pod: cluster == pod). Used by the §Perf paper-technique
    hillclimb (--sync tthf-fused / tthf-rounds / tthf-fused-interval /
    star / local). ``fused_interval`` lowers the flat (R, P) carrier
    step (DESIGN.md §12); ``donate=False`` keeps the param input buffer
    alive, for the donated-vs-undonated memory_analysis delta."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.distributed import (
        TTHFScaleConfig, make_tthf_train_step, tthf_shardings)
    from repro.launch.steps import param_dtype_for

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    # giant models: replica = one whole pod (FSDP inside), clusters of
    # pods; otherwise replica = one data rank, clusters = pods
    pod_granular = model.cfg.param_count() > 5e10 and "pod" in sizes
    if pod_granular:
        R = sizes["pod"]
        cluster = R
    else:
        R = sizes.get("pod", 1) * sizes.get("data", 1)
        cluster = sizes.get("data", R)      # multipod: cluster == pod
    scale = TTHFScaleConfig(
        replicas=R, cluster_size=cluster, tau=tau,
        consensus_every=consensus_every, gamma_d2d=gamma,
        consensus_mode=consensus_mode, lr=1e-2, graph="ring",
        granularity="pod" if pod_granular else "dp")
    step, net = make_tthf_train_step(model, scale, dtype=jnp.bfloat16,
                                     sync=sync,
                                     fused_interval=fused_interval,
                                     param_dtype=param_dtype_for(model.cfg))
    p_abs, p_sh, b_sh = tthf_shardings(
        model, scale, mesh, param_dtype=param_dtype_for(model.cfg))
    if fused_interval:
        # the flat (R, P) carrier: rows over the replica axes, columns
        # over model ranks (P is a LANE multiple, so 16 always divides)
        spec = step.spec
        p_abs = spec.abstract(R)
        rows = (("pod",) if pod_granular
                else ("pod", "data") if "pod" in sizes else ("data",))
        p_sh = NamedSharding(mesh, P(rows, "model"))
    b = max(1, shape.global_batch // R)
    if pod_granular:
        # giant-model TT-HF: per-replica microbatch reduced 4x (the
        # interval still sees tau microbatches; remat stack must fit
        # next to the FSDP'd weights)
        b = max(1, b // 4)
    tb = jax.ShapeDtypeStruct((tau, R, b, shape.seq_len), jnp.int32)
    batch = {"tokens": tb, "labels": tb}
    repl = NamedSharding(mesh, P())
    fn = jax.jit(step,
                 in_shardings=(p_sh, {"tokens": b_sh, "labels": b_sh},
                               repl, repl),
                 out_shardings=(p_sh, repl),
                 donate_argnums=(0,) if donate else ())
    picks = jax.ShapeDtypeStruct((net.num_clusters,), jnp.int32)
    return fn, (p_abs, batch, picks, jax.ShapeDtypeStruct((), jnp.int32))


def run_one(arch: str, shape_name: str, mesh_name: str,
            verbose: bool = True, sync: str = "baseline",
            tau: int = 8, consensus_every: int = 4,
            donation_check: bool = False) -> dict:
    import jax

    from repro.configs import get_arch, get_shape
    from repro.launch.analysis import analyze, model_flops_for
    from repro.launch.mesh import chips_in, make_production_mesh
    from repro.launch.steps import build_program
    from repro.models import build_model

    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    if (arch, shape_name) in SKIPS:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": SKIPS[(arch, shape_name)]}

    mesh = make_production_mesh(multi_pod=mesh_name in MESH_PODS,
                                pods=MESH_PODS.get(mesh_name, 2))
    model = build_model(cfg)
    t0 = time.time()
    rules_override = None
    if os.environ.get("RP_MOE_EP"):
        from repro.launch.steps import TRAIN_RULES
        rules_override = TRAIN_RULES.with_overrides(
            embed_fsdp=None, expert_ffn=("pod", "data"))
    with mesh:
        if sync == "baseline":
            fn, args = build_program(model, shape, mesh,
                                     rules_override=rules_override)
        else:
            mode = "fused" if "fused" in sync else "rounds"
            base = "tthf" if sync.startswith("tthf") else sync
            fn, args = build_tthf_program(
                model, shape, mesh, base, mode, tau=tau,
                consensus_every=consensus_every,
                fused_interval=(sync == "tthf-fused-interval"))
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print("  memory_analysis:", mem)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        print("  cost_analysis: flops=%.3e bytes=%.3e" %
              (cost.get("flops", 0), cost.get("bytes accessed", 0)))

    roof = analyze(compiled, arch=arch, shape=shape, mesh_name=mesh_name,
                   chips=chips_in(mesh),
                   model_flops_total=model_flops_for(cfg, shape))
    rec = roof.to_dict()
    rec.update(status="ok", lower_s=t_lower, compile_s=t_compile,
               arg_bytes=float(getattr(mem, "argument_size_in_bytes", 0)),
               out_bytes=float(getattr(mem, "output_size_in_bytes", 0)),
               temp_bytes=float(getattr(mem, "temp_size_in_bytes", 0)),
               alias_bytes=float(getattr(mem, "alias_size_in_bytes", 0)))
    if donation_check and sync != "baseline":
        # the donation contract's memory claim, measured: recompile the
        # same interval step WITHOUT donate_argnums and compare live
        # param HBM (donated aliases the output onto the input buffer,
        # so the undonated/donated ratio approaches 2x for the params)
        with mesh:
            fn2, args2 = build_tthf_program(
                model, shape, mesh,
                "tthf" if sync.startswith("tthf") else sync,
                "fused" if "fused" in sync else "rounds", tau=tau,
                consensus_every=consensus_every,
                fused_interval=(sync == "tthf-fused-interval"),
                donate=False)
            mem2 = fn2.lower(*args2).compile().memory_analysis()

        def _live(m, alias):
            return float(getattr(m, "argument_size_in_bytes", 0)
                         + getattr(m, "output_size_in_bytes", 0)) - alias
        alias = float(getattr(mem, "alias_size_in_bytes", 0))
        live_d = _live(mem, alias)
        live_u = _live(mem2, float(getattr(mem2, "alias_size_in_bytes", 0)))
        rec["donation"] = {
            "alias_bytes": alias, "live_arg_out_donated": live_d,
            "live_arg_out_undonated": live_u,
            "param_hbm_ratio": live_u / max(live_d, 1.0)}
        if verbose:
            print(f"  donation: alias {alias:.3e}B  live arg+out "
                  f"{live_u:.3e}B -> {live_d:.3e}B "
                  f"({rec['donation']['param_hbm_ratio']:.2f}x)")
    if verbose:
        print(f"  roofline: compute {roof.compute_s*1e3:.2f}ms "
              f"memory {roof.memory_s*1e3:.2f}ms "
              f"collective {roof.collective_s*1e3:.2f}ms "
              f"-> dominant: {roof.dominant} "
              f"(fraction {rec['roofline_fraction']:.3f})")
    return rec


def run_serve_one(arch: str, mesh_name: str, *, slots: int = 8,
                  max_prompt: int = 1024, max_total: int = 2048,
                  paged: bool = False, page_size: int = 64,
                  verbose: bool = True) -> dict:
    """Lower + compile the sharded continuous-batching serving pair
    (admission prefill-splice and per-slot decode, exactly what
    ``ContinuousScheduler`` runs) on a production mesh — the served-
    model analogue of the training dry-run (ISSUE 8 / DESIGN.md §14).
    With ``paged``, lowers the paged admission/decode pair instead
    (chunked prefill into pages + page-map decode, what
    ``PagedContinuousScheduler`` runs — DESIGN.md §15)."""
    from repro.configs import get_arch
    from repro.launch.mesh import chips_in, make_production_mesh
    from repro.launch.steps import build_paged_serve_program, \
        build_serve_program
    from repro.models import build_model

    cfg = get_arch(arch)
    mesh = make_production_mesh(multi_pod=mesh_name in MESH_PODS,
                                pods=MESH_PODS.get(mesh_name, 2))
    model = build_model(cfg)
    if paged:
        programs = build_paged_serve_program(
            model, mesh, slots=slots, max_prompt=max_prompt,
            max_total=max_total, page_size=page_size)
    else:
        programs = build_serve_program(model, mesh, slots=slots,
                                       max_prompt=max_prompt,
                                       max_total=max_total)
    rec = {"arch": arch, "shape": "serve", "mesh": mesh_name,
           "status": "ok", "chips": chips_in(mesh), "slots": slots,
           "max_prompt": max_prompt, "max_total": max_total,
           "paged": paged, "programs": {}}
    if paged:
        rec["page_size"] = page_size
    for name, (fn, args) in programs.items():
        t0 = time.time()
        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        prec = {
            "lower_s": t_lower, "compile_s": t_compile,
            "flops": float(cost.get("flops", 0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0)),
            "arg_bytes": float(getattr(mem, "argument_size_in_bytes", 0)),
            "out_bytes": float(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": float(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": float(getattr(mem, "alias_size_in_bytes", 0)),
        }
        rec["programs"][name] = prec
        if verbose:
            print(f"[serve {arch} x {mesh_name}] {name}: "
                  f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
            print(f"  flops={prec['flops']:.3e} "
                  f"bytes={prec['bytes_accessed']:.3e} "
                  f"temp={prec['temp_bytes']:.3e}B "
                  f"alias={prec['alias_bytes']:.3e}B")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "multipod10k"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="JSON output path or dir")
    ap.add_argument("--subprocess", action="store_true",
                    help="run each combo in a fresh interpreter")
    ap.add_argument("--sync", default="baseline",
                    choices=["baseline", "star", "local",
                             "tthf-fused", "tthf-rounds",
                             "tthf-fused-interval"],
                    help="lower the TT-HF interval step instead of the "
                         "standard train/serve step (train_4k only); "
                         "tthf-fused-interval = the flat (R, P) carrier "
                         "step (DESIGN.md §12)")
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--consensus-every", type=int, default=4)
    ap.add_argument("--donation-check", action="store_true",
                    help="also compile the interval step WITHOUT buffer "
                         "donation and record the live-param-HBM delta")
    ap.add_argument("--pair-schedule", action="store_true",
                    help="enable the pair-scheduled flash attention "
                         "(skips fully-masked blocks; §Perf)")
    ap.add_argument("--moe-ep", action="store_true",
                    help="expert weights stay put (expert_ffn sharded "
                         "over data, no FSDP gathers); tokens move (§Perf)")
    ap.add_argument("--serve", action="store_true",
                    help="lower the sharded serving pair (admission "
                         "prefill-splice + per-slot decode) instead of a "
                         "train/serve step shape")
    ap.add_argument("--slots", type=int, default=8,
                    help="serve mode: continuous-batching slot count")
    ap.add_argument("--max-prompt", type=int, default=1024,
                    help="serve mode: admission prompt length")
    ap.add_argument("--max-total", type=int, default=2048,
                    help="serve mode: per-slot cache length")
    ap.add_argument("--paged", action="store_true",
                    help="serve mode: lower the PAGED admission/decode "
                         "pair (chunked prefill + page-map decode, "
                         "DESIGN.md §15) instead of the ring pair")
    ap.add_argument("--page-size", type=int, default=64,
                    help="serve mode: tokens per cache page (--paged)")
    args = ap.parse_args(argv)

    configure_xla(args)
    # ^ MUST precede any jax import/init: the dry-run builds the
    #   production 512-chip mesh out of host placeholder devices.

    if args.pair_schedule:
        from repro.models import attention as _attn
        _attn.PAIR_SCHEDULE = True
    if args.moe_ep:
        os.environ["RP_MOE_EP"] = "1"

    if args.serve:
        if not args.arch:
            ap.error("--serve requires --arch")
        try:
            rec = run_serve_one(args.arch, args.mesh, slots=args.slots,
                                max_prompt=args.max_prompt,
                                max_total=args.max_total,
                                paged=args.paged,
                                page_size=args.page_size,
                                verbose=args.out != "-")
        except Exception as e:  # noqa: BLE001 — report, don't crash
            rec = {"arch": args.arch, "shape": "serve", "mesh": args.mesh,
                   "status": "error", "error":
                   f"{type(e).__name__}: {e}\n"
                   + traceback.format_exc()[-1500:]}
        print(f"== serve {args.arch} x {args.mesh}: {rec['status']}",
              file=sys.stderr)
        if args.out == "-":
            print(json.dumps(rec))
        elif args.out:
            import pathlib
            p = pathlib.Path(args.out)
            if p.is_dir():
                p.mkdir(parents=True, exist_ok=True)
                tag = "_paged" if args.paged else ""
                fname = p / f"dryrun_serve{tag}_{args.mesh}.json"
            else:
                fname = p
            fname.write_text(json.dumps(rec, indent=1))
            print(f"wrote {fname}", file=sys.stderr)
        return 1 if rec["status"] == "error" else 0

    from repro.configs import ARCHS, INPUT_SHAPES
    combos = ([(a, s) for a in ARCHS for s in INPUT_SHAPES]
              if args.all else [(args.arch, args.shape)])

    records = []
    for arch, shape in combos:
        if args.subprocess:
            out = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", arch, "--shape", shape, "--mesh", args.mesh,
                 "--out", "-"],
                capture_output=True, text=True, timeout=3600)
            try:
                rec = json.loads(out.stdout.splitlines()[-1])
            except Exception:
                rec = {"arch": arch, "shape": shape, "mesh": args.mesh,
                       "status": "error",
                       "error": (out.stderr or out.stdout)[-2000:]}
        else:
            try:
                rec = run_one(arch, shape, args.mesh,
                              verbose=args.out != "-", sync=args.sync,
                              tau=args.tau,
                              consensus_every=args.consensus_every,
                              donation_check=args.donation_check)
                rec["sync"] = args.sync
                rec["tau"] = args.tau
            except Exception as e:  # noqa: BLE001 — sweep must continue
                rec = {"arch": arch, "shape": shape, "mesh": args.mesh,
                       "status": "error", "error":
                       f"{type(e).__name__}: {e}\n"
                       + traceback.format_exc()[-1500:]}
        records.append(rec)
        status = rec["status"]
        print(f"== {arch} x {shape} x {args.mesh}: {status}",
              file=sys.stderr)

    if args.out == "-":
        print(json.dumps(records[0] if len(records) == 1 else records))
    elif args.out:
        import pathlib
        p = pathlib.Path(args.out)
        if p.is_dir() or args.all:
            p.mkdir(parents=True, exist_ok=True)
            fname = p / f"dryrun_{args.mesh}.json"
        else:
            fname = p
        fname.write_text(json.dumps(records, indent=1))
        print(f"wrote {fname}", file=sys.stderr)

    n_bad = sum(r["status"] == "error" for r in records)
    return 1 if n_bad else 0


if __name__ == "__main__":
    sys.exit(main())
