"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; `dryrun.py` sets XLA_FLAGS *before* importing anything.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: 2 pods = 512 chips as (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def chips_in(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
