"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; `dryrun.py` sets XLA_FLAGS *before* importing anything.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, pods: int = 2):
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: ``pods`` pods of 256 chips as (pod, data=16, model=16) —
    the default 2 pods is the 512-chip production target; pods=40 is the
    10,240-chip scale-out lowering check (``--mesh multipod10k``)."""
    shape = (pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def chips_in(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
