"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; `dryrun.py` sets XLA_FLAGS *before* importing anything.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, pods: int = 2):
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: ``pods`` pods of 256 chips as (pod, data=16, model=16) —
    the default 2 pods is the 512-chip production target; pods=40 is the
    10,240-chip scale-out lowering check (``--mesh multipod10k``)."""
    shape = (pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def chips_in(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n


def make_serve_mesh(spec: str = "host"):
    """Serving mesh over the devices of THIS process (``launch/serve.py
    --mesh``; the production 512-chip meshes stay in
    :func:`make_production_mesh`).

    ``spec``:
      * ``"host"``  — all local devices tensor-parallel: (data=1, model=n)
      * ``"data"``  — all local devices data-parallel:   (data=n, model=1)
      * ``"AxB"``   — explicit (data=A, model=B), e.g. ``"2x4"``

    Axes are always ``("data", "model")`` so the serve rule tables
    resolve identically across specs (absent/size-1 axes no-op).
    """
    n = len(jax.devices())
    if spec == "host":
        shape = (1, n)
    elif spec == "data":
        shape = (n, 1)
    else:
        try:
            d, m = (int(x) for x in spec.split("x"))
        except ValueError:
            raise ValueError(
                f"mesh spec {spec!r}: expected 'host', 'data', or 'AxB'")
        if d * m != n:
            raise ValueError(
                f"mesh spec {spec!r} wants {d * m} devices, have {n}")
        shape = (d, m)
    return jax.make_mesh(shape, ("data", "model"))
