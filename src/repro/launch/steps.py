"""Sharded step builders: train_step / prefill_step / serve_step with
phase-specific sharding rule tables.

This is the single place where logical axes meet the physical mesh; the
§Perf hillclimb edits these tables (or passes overrides) without
touching model code.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.dist.sharding import ShardingRules
from repro.models.registry import ModelApi
from repro.optim import make_optimizer, apply_updates
from repro.optim.schedules import constant

# ---------------------------------------------------------------------------
# rule tables per phase
# ---------------------------------------------------------------------------

TRAIN_RULES = ShardingRules((
    ("batch", ("pod", "data")),
    # params: FSDP over (pod, data) on d_model dims, tensor over model
    ("embed", ("pod", "data")),
    ("embed_nomodel", None),
    ("vocab", "model"),
    ("q_proj", "model"),
    ("kv_proj", "model"),
    ("ffn", "model"),
    ("experts", "model"),
    ("expert_ffn", None),
    ("experts_router", None),
    ("embed_fsdp", ("pod", "data")),
    ("ssm_in", "model"),
    ("ssm_heads", "model"),
    ("ssm_state", None),
    ("rnn_width", "model"),
    ("rnn_width_in", ("pod", "data")),
    ("conv_k", None),
    ("layers", None),
))

# Serving: weights replicated over data (latency path), tensor-parallel
# over model; expert weights stay FSDP-sharded (memory). The table
# lives with the serving stack (DESIGN.md §14); re-exported here so the
# §Perf hillclimb still edits rule tables in one module.
from repro.serving.sharding import (  # noqa: E402
    SERVE_CACHE_RULES, SERVE_PARAM_RULES as SERVE_RULES)

CACHE_RULES_DECODE = ShardingRules((
    ("cache_batch", ("pod", "data")),
    ("cache_seq", "model"),
    ("cache_kv_heads", None),
    ("head_dim", None),
    ("ssm_heads", "model"),
    ("ssm_state", None),
    ("ssm_in", "model"),
    ("rnn_width", "model"),
    ("layers", None),
))

# long_500k: batch = 1 -> parallelize over the sequence/state dims.
CACHE_RULES_LONG = CACHE_RULES_DECODE.with_overrides(
    cache_batch=None,
    cache_seq=("pod", "data", "model"),
)


def _shard(tree_axes, mesh: Mesh, rules: ShardingRules):
    return jax.tree.map(
        lambda ax: NamedSharding(mesh, rules.spec(tuple(ax), mesh)),
        tree_axes, is_leaf=lambda x: isinstance(x, tuple))


def _replicated(mesh):
    return NamedSharding(mesh, P())


def batch_shardings(batch_specs: dict, mesh: Mesh, rules: ShardingRules):
    out = {}
    for k, v in batch_specs.items():
        nd = len(v.shape)
        spec = rules.spec(("batch",) + (None,) * (nd - 1), mesh)
        out[k] = NamedSharding(mesh, spec)
    return out


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(model: ModelApi, *, optimizer: str = "sgd",
                    lr: float = 1e-3, dtype=jnp.bfloat16, remat=True,
                    accum_steps: int = 1):
    """Returns (step_fn, opt) — step(params, opt_state, batch, step_idx).

    ``accum_steps > 1`` scans over microbatches with fp32 gradient
    accumulation: the per-layer activation stack (the dominant training
    temp) shrinks by the same factor.
    """
    opt = make_optimizer(optimizer)
    sched = constant(lr)

    def loss_of(params, mb):
        return model.loss(params, mb, dtype=dtype, remat=remat)

    def step(params, opt_state, batch, step_idx):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            def resh(x):
                b = x.shape[0]
                return x.reshape((accum_steps, b // accum_steps)
                                 + x.shape[1:])
            micro = jax.tree.map(resh, batch)
            # accumulate in fp32 for fp32 params; for bf16-param giants
            # accumulate in bf16 (SGD-only path; on real TPUs pair with
            # stochastic rounding) — halves the accumulator footprint.
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32
                                    if p.dtype == jnp.float32
                                    else p.dtype), params)

            def acc(carry, mb):
                gacc, lacc = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                gacc = jax.tree.map(
                    lambda a, b_: a + b_.astype(a.dtype), gacc, g)
                return (gacc, lacc + l), None

            (grads, loss), _ = jax.lax.scan(
                acc, (g0, jnp.zeros((), jnp.float32)), micro)
            loss = loss / accum_steps
        # grads are SUMMED over microbatches; fold the 1/accum into lr
        # (exact for SGD — avoids a full-param-sized divide temp)
        updates, opt_state = opt.update(grads, opt_state, params,
                                        sched(step_idx) / accum_steps)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return step, opt


def accum_steps_for(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                    budget_bytes: float = 2e9) -> int:
    """Pick gradient-accumulation so the saved per-layer activation
    stack (scan length x b_local x T x d x 2B) stays under ~4 GB."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    repl = sizes.get("pod", 1) * sizes.get("data", 1)
    b_local = max(shape.global_batch // repl, 1)
    n_saves = cfg.num_layers
    if cfg.kind == "moe" and cfg.moe_every > 1:
        n_saves = cfg.num_layers // cfg.moe_every
    if cfg.kind == "hybrid":
        n_saves = cfg.num_layers // (cfg.local_attn_every or 3) + 2
    if cfg.enc_num_layers:
        n_saves += cfg.enc_num_layers
    stack = n_saves * b_local * shape.seq_len * cfg.d_model * 2
    a = 1
    while stack / a > budget_bytes and a < b_local:
        a *= 2
    return a


def opt_state_shardings(optimizer: str, param_shardings, mesh: Mesh):
    if optimizer == "sgd":
        return ()
    if optimizer == "momentum":
        return {"m": param_shardings}
    if optimizer == "adamw":
        return {"m": param_shardings, "v": param_shardings,
                "count": _replicated(mesh)}
    raise ValueError(optimizer)


def make_prefill_step(model: ModelApi, *, dtype=jnp.bfloat16,
                      serve_window=0, remat=True):
    def step(params, batch):
        return model.prefill(params, batch, dtype=dtype,
                             serve_window=serve_window, remat=remat)
    return step


def make_decode_step(model: ModelApi, *, dtype=jnp.bfloat16,
                     serve_window=0):
    def step(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos, dtype=dtype,
                                 serve_window=serve_window)
    return step


# ---------------------------------------------------------------------------
# fully-wired jit programs per (arch, shape, mesh)
# ---------------------------------------------------------------------------

def serve_window_for(cfg: ModelConfig, shape: InputShape) -> int:
    """The sliding-window *serving variant* for long-context decode on
    full-attention archs (DESIGN.md §6)."""
    if shape.name == "long_500k" and cfg.kind in ("dense", "moe", "vlm"):
        return 4096
    return 0


def param_dtype_for(cfg: ModelConfig):
    """bf16 master weights for the giant MoEs (SGD — the paper's
    optimizer — keeps no state, so this is the whole memory story)."""
    if cfg.param_count() > 5e10:
        return jnp.bfloat16
    return jnp.float32


def build_program(model: ModelApi, shape: InputShape, mesh: Mesh, *,
                  optimizer: str = "sgd", dtype=jnp.bfloat16,
                  rules_override: ShardingRules | None = None,
                  cache_rules_override: ShardingRules | None = None,
                  remat: bool = True):
    """Lowerable jit program + abstract inputs for one (arch, shape).

    Returns (jitted_fn, abstract_args) ready for `.lower(*args)`.
    """
    cfg = model.cfg
    pdt = param_dtype_for(cfg)
    params_abs, axes = model.abstract_params(dtype=pdt)
    sw = serve_window_for(cfg, shape)
    specs = model.input_specs(shape, serve_window=sw)

    if shape.phase == "train":
        rules = rules_override or TRAIN_RULES
        p_sh = _shard(axes, mesh, rules)
        step, opt = make_train_step(model, optimizer=optimizer, dtype=dtype,
                                    remat=remat,
                                    accum_steps=accum_steps_for(
                                        cfg, shape, mesh))
        opt_abs = jax.eval_shape(opt.init, params_abs)
        o_sh = opt_state_shardings(optimizer, p_sh, mesh)
        b_sh = batch_shardings(specs["batch"], mesh, rules)
        fn = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh, _replicated(mesh)),
            out_shardings=(p_sh, o_sh, _replicated(mesh)),
            donate_argnums=(0, 1),
        )
        args = (params_abs, opt_abs, specs["batch"],
                jax.ShapeDtypeStruct((), jnp.int32))
        return fn, args

    rules = rules_override or SERVE_RULES
    p_sh = _shard(axes, mesh, rules)

    if shape.phase == "prefill":
        cache_rules = cache_rules_override or CACHE_RULES_DECODE
        c_axes = model.cache_axes()
        c_sh = _shard(c_axes, mesh, cache_rules)
        b_sh = batch_shardings(specs["batch"], mesh, rules)
        step = make_prefill_step(model, dtype=dtype, serve_window=sw,
                                 remat=remat)
        fn = jax.jit(
            step,
            in_shardings=(p_sh, b_sh),
            out_shardings=(_replicated(mesh), c_sh, _replicated(mesh)),
        )
        args = (params_abs, specs["batch"])
        return fn, args

    # decode
    cache_rules = cache_rules_override or (
        CACHE_RULES_LONG if shape.name == "long_500k" else
        CACHE_RULES_DECODE)
    c_axes = model.cache_axes()
    c_sh = _shard(c_axes, mesh, cache_rules)
    tok_sh = NamedSharding(
        mesh, cache_rules.spec(("cache_batch", None), mesh))
    step = make_decode_step(model, dtype=dtype, serve_window=sw)
    fn = jax.jit(
        step,
        in_shardings=(p_sh, tok_sh, c_sh, _replicated(mesh)),
        out_shardings=(tok_sh, c_sh),
        donate_argnums=(2,),
    )
    args = (params_abs, specs["token"], specs["cache"], specs["pos"])
    return fn, args


def build_serve_program(model: ModelApi, mesh: Mesh, *,
                        slots: int = 8, max_prompt: int = 1024,
                        max_total: int = 2048, dtype=jnp.bfloat16,
                        rules: ShardingRules | None = None,
                        cache_rules: ShardingRules | None = None):
    """The continuous-batching serving pair on a production mesh:

    * ``admission`` — batch-1 prefill + ``write_cache_slot`` splice into
      the live ``(slots, max_total)`` cache (traced slot index);
    * ``decode`` — one sharded decode step over all slots with a
      per-slot ``pos`` vector (the cache buffer is donated, mirroring
      the scheduler's steady state).

    Returns ``{"admission": (fn, args), "decode": (fn, args)}`` with
    every boundary pinned by :func:`repro.serving.serve_shardings` —
    the dryrun serve mode lowers exactly what ``ContinuousScheduler``
    runs (ISSUE 8 / DESIGN.md §14).
    """
    from repro.serving import serve_shardings
    cfg = model.cfg
    if cfg.kind in ("vlm", "encdec", "audio"):
        raise ValueError(
            f"serve program is token-only; arch kind {cfg.kind!r} needs "
            "frontend inputs the request path does not carry")
    pdt = param_dtype_for(cfg)
    sh = serve_shardings(model, mesh, slots=slots, max_total=max_total,
                         dtype=dtype, param_dtype=pdt, rules=rules,
                         cache_rules=cache_rules)
    params_abs, _ = model.abstract_params(dtype=pdt)
    cache_abs = model.abstract_cache(slots, max_total, dtype)
    i32 = jnp.int32
    logits_abs = jax.ShapeDtypeStruct((slots, 1, cfg.padded_vocab),
                                      dtype)
    pos_abs = jax.ShapeDtypeStruct((slots,), i32)

    def admission(params, cache, pos, logits, tokens, length, slot):
        lg1, c1, p1 = model.prefill(
            params, {"tokens": tokens}, dtype=dtype, cache_dtype=dtype,
            cache_len=max_total, lengths=length)
        cache, pos = model.write_cache_slot(
            cache, c1, slot, pos=pos, one_pos=p1[0],
            cache_rules=sh.cache_rules)
        logits = jax.lax.dynamic_update_slice(
            logits, lg1.astype(logits.dtype), (slot, 0, 0))
        return cache, pos, logits

    adm = jax.jit(
        admission,
        in_shardings=(sh.params, sh.cache, sh.pos, sh.logits,
                      sh.replicated, sh.replicated, sh.replicated),
        out_shardings=(sh.cache, sh.pos, sh.logits),
        donate_argnums=(1,),
    )
    adm_args = (params_abs, cache_abs, pos_abs, logits_abs,
                jax.ShapeDtypeStruct((1, max_prompt), i32),
                jax.ShapeDtypeStruct((1,), i32),
                jax.ShapeDtypeStruct((), i32))

    def decode(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos, dtype=dtype)

    dec = jax.jit(
        decode,
        in_shardings=(sh.params, sh.token, sh.cache, sh.pos),
        out_shardings=(sh.logits, sh.cache),
        donate_argnums=(2,),
    )
    dec_args = (params_abs, jax.ShapeDtypeStruct((slots, 1), i32),
                cache_abs, pos_abs)
    return {"admission": (adm, adm_args), "decode": (dec, dec_args)}


def build_paged_serve_program(model: ModelApi, mesh: Mesh, *,
                              slots: int = 8, max_prompt: int = 1024,
                              max_total: int = 2048, page_size: int = 64,
                              cache_pages: int | None = None,
                              prefill_chunk: int | None = None,
                              dtype=jnp.bfloat16,
                              rules: ShardingRules | None = None,
                              cache_rules: ShardingRules | None = None):
    """The PAGED serving pair on a production mesh (DESIGN.md §15):

    * ``admission_chunk`` — one chunked-prefill step writing a
      ``prefill_chunk``-token piece of a prompt into the slot's pages
      (traced start/valid/page-row, so one lowering serves every chunk
      of every prompt);
    * ``decode`` — one paged decode step over all slots, gathering K/V
      through the ``(slots, pages_per_slot)`` page map (cache donated,
      mirroring the scheduler's steady state).

    Returns ``{"admission_chunk": (fn, args), "decode": (fn, args)}``
    pinned exactly as ``PagedContinuousScheduler`` pins them.
    """
    from repro.serving import pages_per_slot, serve_shardings
    cfg = model.cfg
    if cfg.kind in ("vlm", "encdec", "audio"):
        raise ValueError(
            f"serve program is token-only; arch kind {cfg.kind!r} needs "
            "frontend inputs the request path does not carry")
    P = pages_per_slot(max_total, page_size)
    if cache_pages is None:
        cache_pages = slots * P + 1
    if prefill_chunk is None:
        prefill_chunk = -(-max_prompt // page_size) * page_size
    assert prefill_chunk % page_size == 0
    pdt = param_dtype_for(cfg)
    sh = serve_shardings(model, mesh, slots=slots, max_total=max_total,
                         dtype=dtype, param_dtype=pdt,
                         page_size=page_size, cache_pages=cache_pages,
                         rules=rules, cache_rules=cache_rules)
    params_abs, _ = model.abstract_params(dtype=pdt)
    cache_abs = model.abstract_paged_cache(slots, cache_pages, page_size,
                                           dtype)
    i32 = jnp.int32
    logits_abs = jax.ShapeDtypeStruct((slots, 1, cfg.padded_vocab), dtype)
    pos_abs = jax.ShapeDtypeStruct((slots,), i32)

    def admission_chunk(params, cache, logits, tokens, start, valid, row,
                        slot):
        c1, lg = model.prefill_chunk(params, cache, tokens, start, valid,
                                     row, slot, dtype=dtype)
        logits = jax.lax.dynamic_update_slice(
            logits, lg.astype(logits.dtype), (slot, 0, 0))
        return c1, logits

    adm = jax.jit(
        admission_chunk,
        in_shardings=(sh.params, sh.paged_cache, sh.logits,
                      sh.replicated, sh.replicated, sh.replicated,
                      sh.replicated, sh.replicated),
        out_shardings=(sh.paged_cache, sh.logits),
        donate_argnums=(1,),
    )
    adm_args = (params_abs, cache_abs, logits_abs,
                jax.ShapeDtypeStruct((1, prefill_chunk), i32),
                jax.ShapeDtypeStruct((), i32),
                jax.ShapeDtypeStruct((), i32),
                jax.ShapeDtypeStruct((P,), i32),
                jax.ShapeDtypeStruct((), i32))

    def decode(params, token, cache, pos, page_map, live):
        return model.decode_step_paged(params, token, cache, pos,
                                       page_map, live, dtype=dtype)

    dec = jax.jit(
        decode,
        in_shardings=(sh.params, sh.token, sh.paged_cache, sh.pos,
                      sh.page_map, sh.live),
        out_shardings=(sh.logits, sh.paged_cache),
        donate_argnums=(2,),
    )
    dec_args = (params_abs, jax.ShapeDtypeStruct((slots, 1), i32),
                cache_abs, pos_abs,
                jax.ShapeDtypeStruct((slots, P), i32),
                jax.ShapeDtypeStruct((slots,), jnp.bool_))
    return {"admission_chunk": (adm, adm_args), "decode": (dec, dec_args)}
