"""D2D consensus operators (eq. 10) — simulation mode.

State layout: device parameters stacked on a leading axis, reshaped per
cluster to ``(N, s, M)``. One consensus *round* is the block-diagonal
product ``z <- V_c z`` applied independently per cluster; an *event*
applies ``Gamma_c`` rounds (possibly different per cluster — devices in
cluster c stop mixing after Gamma_c rounds).

Execution is delegated to the unified engine in
:mod:`repro.core.mixing` (DESIGN.md §5): the default backend is the
jittable ``masked_loop``; ``use_kernel=True`` (or ``backend="pallas"``)
routes through the fused Pallas kernel, and ``backend`` exposes the
full dispatch table (``reference``/``masked_loop``/``pallas``/
``fused_power``).  This module keeps the simulation-facing API and the
consensus *metrics* (Definitions 2-3).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import mixing


def mix_once(z: jax.Array, V: jax.Array) -> jax.Array:
    """One consensus round. z: (N, s, M); V: (N, s, s)."""
    return jnp.einsum("nij,njm->nim", V, z,
                      preferred_element_type=z.dtype)


def _resolve_backend(use_kernel: bool, backend: str | None) -> str:
    if backend is not None:
        return mixing.canonical_backend(backend)
    return "pallas" if use_kernel else "masked_loop"


@partial(jax.jit, static_argnames=("backend",))
def _mix_jit(z, V, gamma, backend):
    return mixing.mix(z, V, gamma, backend=backend)


def mix(z: jax.Array, V: jax.Array, gamma: jax.Array,
        use_kernel: bool = False, backend: str | None = None) -> jax.Array:
    """Apply per-cluster consensus: z_c <- V_c^{gamma_c} z_c.

    z: (N, s, M); V: (N, s, s); gamma: scalar or (N,) int32.
    The ``reference`` backend unrolls gamma in Python, so it runs
    outside this function's jit (gamma must stay concrete).
    """
    backend = _resolve_backend(use_kernel, backend)
    if backend == "reference":
        return mixing.mix(z, V, gamma, backend=backend)
    return _mix_jit(z, V, gamma, backend)


def mix_pytree(params, V: jax.Array, gamma: jax.Array, num_clusters: int,
               use_kernel: bool = False, backend: str | None = None):
    """Consensus over a pytree whose leaves have leading axis I = N*s.

    Mixing is linear and elementwise across parameters, so each leaf is
    reshaped (I, ...) -> (N, s, M) and mixed independently.
    """
    return mixing.mix_pytree(params, V, gamma, num_clusters,
                             backend=_resolve_backend(use_kernel, backend))


def cluster_means(z: jax.Array) -> jax.Array:
    """(N, s, M) -> (N, M): the targets of perfect consensus."""
    return z.mean(axis=1)


def consensus_error(z: jax.Array) -> jax.Array:
    """Per-cluster mean squared consensus error (Definition 3):
    (1/s) sum_i ||e_i||^2 with e_i = z_i - zbar_c. Returns (N,)."""
    e = z - cluster_means(z)[:, None, :]
    return jnp.mean(jnp.sum(e * e, axis=-1), axis=1)


def divergence_upsilon(z: jax.Array) -> jax.Array:
    """Definition 2: per-cluster max elementwise spread Upsilon_c.
    z: (N, s, M) -> (N,)."""
    return jnp.max(z.max(axis=1) - z.min(axis=1), axis=-1)


def masked_divergence_upsilon(z: jax.Array, device_mask: jax.Array
                              ) -> jax.Array:
    """Definition-2 spread over the ACTIVE devices only (netsim churn).

    Dropped devices hold stale parameters that cannot take part in the
    coming consensus event, so they must not inflate the Remark-1
    round count. Clusters with < 2 active devices have zero spread.
    z: (N, s, M), device_mask: (N, s) -> (N,).
    """
    m = device_mask[..., None]
    big = jnp.finfo(z.dtype).max
    hi = jnp.max(jnp.where(m, z, -big), axis=1)
    lo = jnp.min(jnp.where(m, z, big), axis=1)
    spread = jnp.max(hi - lo, axis=-1)
    enough = jnp.sum(device_mask, axis=1) >= 2
    return jnp.where(enough, spread, 0.0)
