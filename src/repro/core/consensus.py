"""D2D consensus operators (eq. 10) — simulation mode.

State layout: device parameters stacked on a leading axis, reshaped per
cluster to ``(N, s, M)``. One consensus *round* is the block-diagonal
product ``z <- V_c z`` applied independently per cluster; an *event*
applies ``Gamma_c`` rounds (possibly different per cluster — devices in
cluster c stop mixing after Gamma_c rounds, which we express as masked
selects inside a fori_loop so the whole event stays jittable).

The Pallas kernel (`repro.kernels.consensus_mix`) implements the fused
Gamma-round product for the TPU target; `use_kernel=True` routes through
it (interpret mode on CPU).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def mix_once(z: jax.Array, V: jax.Array) -> jax.Array:
    """One consensus round. z: (N, s, M); V: (N, s, s)."""
    return jnp.einsum("nij,njm->nim", V, z,
                      preferred_element_type=z.dtype)


@partial(jax.jit, static_argnames=("use_kernel",))
def mix(z: jax.Array, V: jax.Array, gamma: jax.Array,
        use_kernel: bool = False) -> jax.Array:
    """Apply per-cluster consensus: z_c <- V_c^{gamma_c} z_c.

    z: (N, s, M); V: (N, s, s); gamma: scalar or (N,) int32.
    """
    gamma = jnp.asarray(gamma, jnp.int32)
    if gamma.ndim == 0:
        gamma = jnp.full((z.shape[0],), gamma)
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.consensus_mix(z, V, gamma)

    max_gamma = jnp.max(gamma)

    def body(r, zz):
        mixed = mix_once(zz, V)
        keep = (r < gamma)[:, None, None]    # cluster still mixing?
        return jnp.where(keep, mixed, zz)

    # bounded loop: max over clusters; masked per cluster
    return jax.lax.fori_loop(0, max_gamma, body, z)


def mix_pytree(params, V: jax.Array, gamma: jax.Array, num_clusters: int,
               use_kernel: bool = False):
    """Consensus over a pytree whose leaves have leading axis I = N*s.

    Mixing is linear and elementwise across parameters, so each leaf is
    reshaped (I, ...) -> (N, s, M) and mixed independently.
    """
    def one(leaf):
        I = leaf.shape[0]
        s = I // num_clusters
        flat = leaf.reshape(num_clusters, s, -1)
        mixed = mix(flat, V.astype(flat.dtype), gamma, use_kernel=use_kernel)
        return mixed.reshape(leaf.shape)

    return jax.tree.map(one, params)


def cluster_means(z: jax.Array) -> jax.Array:
    """(N, s, M) -> (N, M): the targets of perfect consensus."""
    return z.mean(axis=1)


def consensus_error(z: jax.Array) -> jax.Array:
    """Per-cluster mean squared consensus error (Definition 3):
    (1/s) sum_i ||e_i||^2 with e_i = z_i - zbar_c. Returns (N,)."""
    e = z - cluster_means(z)[:, None, :]
    return jnp.mean(jnp.sum(e * e, axis=-1), axis=1)


def divergence_upsilon(z: jax.Array) -> jax.Array:
    """Definition 2: per-cluster max elementwise spread Upsilon_c.
    z: (N, s, M) -> (N,)."""
    return jnp.max(z.max(axis=1) - z.min(axis=1), axis=-1)
