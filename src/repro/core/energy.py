"""Energy / delay accounting model (Fig. 6).

The paper evaluates the *total* energy and wall-clock delay incurred to
reach a target accuracy, under ratios E_D2D/E_Glob and Delta_D2D/
Delta_Glob. Uplink reference: 24 dBm transmit power for 0.25 s per
upload [17] -> E_Glob = P_tx * Delta_Glob per device upload.

We count events, then price them:

  uplinks   : devices transmitting model -> server at a global agg
  downlink  : server broadcast (free for devices, counted separately)
  d2d_msgs  : one per (device, neighbour) per consensus round
"""
from __future__ import annotations

from dataclasses import dataclass, field


DBM24_WATTS = 10 ** ((24 - 30) / 10)      # 24 dBm ~ 0.251 W
DELTA_GLOB_S = 0.25                        # per-upload delay [17]
E_GLOB_J = DBM24_WATTS * DELTA_GLOB_S      # Joules per uplink transmission


@dataclass
class CommLedger:
    """Counts communication events during a run.

    Straggler accounting (``repro.netsim``): the two ``straggler_*``
    fields accumulate EXTRA uplink-equivalents / round-equivalents of
    tail latency beyond the baseline — a consensus round at tail
    multiplier m adds (m - 1) round-equivalents, an uplink from a
    straggling device adds (m - 1) uplink-equivalents. They stay 0
    without dynamics, so historical energy/delay numbers are unchanged.
    Stragglers are slow, not chatty: the tail stretches ``delay`` but
    moves no extra bits, so ``energy`` is untouched.
    """
    uplinks: int = 0
    broadcasts: int = 0
    d2d_msgs: int = 0
    d2d_rounds: int = 0
    local_steps: int = 0
    straggler_uplink_extra: float = 0.0   # uplink-equivalents of tail delay
    straggler_round_extra: float = 0.0    # D2D-round-equivalents
    # level-tagged uplink accounting (repro.hierarchy): tier 1 counts
    # device -> fog uploads, tier l >= 2 counts fog -> fog relays.
    # ``uplinks`` stays the total over all tiers, so flat runs are
    # unchanged and energy/delay keep pricing every transmitted model.
    uplinks_by_level: dict = field(default_factory=dict)
    # per-event attribution (repro.obs, DESIGN.md §13): every record_*
    # call appends rows {"kind", "event", ...} so the totals above can
    # be decomposed per cluster / per level / per event after the run.
    # Attribution never feeds pricing — energy()/delay() read only the
    # counters — and checkpoints persist the counters, not the rows.
    events: list = field(default_factory=list)
    _event_idx: int = 0

    def next_event(self) -> int:
        """Advance the attribution event index (one logical comms
        event: a consensus event, an aggregation, an interval).
        Returns the new index; rows recorded after this call carry it."""
        self._event_idx += 1
        return self._event_idx

    def record_uplinks(self, n: int, level: int = 1,
                       uplink_delay_mults=None) -> None:
        """Count ``n`` model uploads entering a tier-``level``
        aggregate (no broadcast implied — fog tiers relay upward)."""
        self.uplinks += n
        self.uplinks_by_level[level] = \
            self.uplinks_by_level.get(level, 0) + n
        self.events.append({"kind": "uplink", "event": self._event_idx,
                            "level": int(level), "n": int(n)})
        if uplink_delay_mults is not None:
            for m in uplink_delay_mults:
                self.straggler_uplink_extra += max(float(m) - 1.0, 0.0)

    def record_aggregation(self, devices_sampled: int,
                           uplink_delay_mults=None,
                           level: int = 1) -> None:
        """``uplink_delay_mults``: per-sampled-device tail multipliers
        (>= 1); each uplink pays its own device's multiplier."""
        self.record_uplinks(devices_sampled, level, uplink_delay_mults)
        self.broadcasts += 1
        self.events.append({"kind": "broadcast",
                            "event": self._event_idx, "n": 1})

    def record_hierarchy_event(self, uplinks_by_level: dict,
                               uplink_delay_mults=None) -> None:
        """One multi-level aggregation event: tier-1 device uploads
        (one broadcast, straggler multipliers apply) plus the fog ->
        fog relays of every deeper tier. Shared by both trainers so
        sim and scale mode cannot diverge on hierarchy pricing."""
        for level in sorted(uplinks_by_level):
            if level == 1:
                self.record_aggregation(uplinks_by_level[1],
                                        uplink_delay_mults, level=1)
            else:
                self.record_uplinks(uplinks_by_level[level], level=level)

    def record_consensus(self, rounds_per_cluster, edges_per_cluster,
                         tail_mult_per_cluster=None) -> None:
        """rounds/edges: iterables over clusters. ``tail_mult_per_
        cluster``: the slowest active participant's multiplier — every
        round in that cluster completes at the tail's pace."""
        rounds = list(rounds_per_cluster)
        edges = list(edges_per_cluster)
        n = len(rounds)
        for i, (g, e) in enumerate(zip(rounds, edges)):
            self.d2d_rounds += int(g)
            self.d2d_msgs += int(g) * 2 * int(e)   # bidirectional
            if int(g):
                # position within one event's per-cluster vector; a
                # caller replaying repeats must call once per repeat
                # (Billing.charge does) so i stays the cluster index
                self.events.append({
                    "kind": "consensus", "event": self._event_idx,
                    "cluster": i % max(n, 1), "rounds": int(g),
                    "msgs": int(g) * 2 * int(e)})
            if tail_mult_per_cluster is not None:
                mult = float(tail_mult_per_cluster[i])
                self.straggler_round_extra += int(g) * max(mult - 1.0, 0.0)

    def record_local_step(self, devices: int = 1) -> None:
        self.local_steps += devices

    # -- attribution queries (repro.obs) ------------------------------------
    def d2d_by_cluster(self) -> dict[int, dict[str, int]]:
        """{cluster: {rounds, msgs}} summed over every consensus row."""
        out: dict[int, dict[str, int]] = {}
        for ev in self.events:
            if ev["kind"] != "consensus":
                continue
            d = out.setdefault(ev["cluster"], {"rounds": 0, "msgs": 0})
            d["rounds"] += ev["rounds"]
            d["msgs"] += ev["msgs"]
        return out

    def uplinks_by_event(self) -> dict[int, int]:
        out: dict[int, int] = {}
        for ev in self.events:
            if ev["kind"] == "uplink":
                out[ev["event"]] = out.get(ev["event"], 0) + ev["n"]
        return out

    def attribution_totals(self) -> dict:
        """Recompute the headline counters from the attribution rows —
        tests assert these equal the counters the pricing reads."""
        up = sum(e["n"] for e in self.events if e["kind"] == "uplink")
        bc = sum(e["n"] for e in self.events if e["kind"] == "broadcast")
        msgs = sum(e["msgs"] for e in self.events
                   if e["kind"] == "consensus")
        rounds = sum(e["rounds"] for e in self.events
                     if e["kind"] == "consensus")
        by_level: dict[int, int] = {}
        for e in self.events:
            if e["kind"] == "uplink":
                by_level[e["level"]] = by_level.get(e["level"], 0) + e["n"]
        return {"uplinks": up, "broadcasts": bc, "d2d_msgs": msgs,
                "d2d_rounds": rounds, "uplinks_by_level": by_level}

    def attribution_since(self, idx: int) -> list[dict]:
        """Rows appended after ``idx`` (= a previous ``len(events)``) —
        the per-round comms delta the telemetry stream records."""
        return self.events[idx:]

    # -- pricing ------------------------------------------------------------
    def energy(self, e_ratio: float, e_glob: float = E_GLOB_J) -> float:
        """Total J given E_D2D = e_ratio * E_Glob."""
        return self.uplinks * e_glob + self.d2d_msgs * e_ratio * e_glob

    def delay(self, d_ratio: float, delta_glob: float = DELTA_GLOB_S,
              sequential_uplinks: bool = True) -> float:
        """Total seconds given Delta_D2D = d_ratio * Delta_Glob.

        Uplinks are sequential per aggregation (the scarce-uplink premise);
        D2D rounds within a cluster run in parallel across devices but
        rounds are sequential. Straggler tails stretch both terms.
        """
        up = self.uplinks if sequential_uplinks else self.broadcasts
        up = up + self.straggler_uplink_extra
        rounds = self.d2d_rounds + self.straggler_round_extra
        return up * delta_glob + rounds * d_ratio * delta_glob
