"""Cluster topologies and consensus matrices (Sec. II-A, Assumption 2).

Builds the D2D graphs G_c and consensus matrices V_c:

* random geometric graphs (paper Sec. IV-A), with the connection radius
  tuned so the average spectral radius rho(V_c - 11^T/s_c) hits a target
  (the paper uses 0.7);
* ring graphs (the TPU-native default in scale mode — ICI neighbours);
* complete graphs (fastest mixing, 1 round suffices with uniform weights).

Weights satisfy Assumption 2: (i) sparsity matches E_c, (ii) row sums 1,
(iii) symmetric, (iv) rho(V - 11^T/s) < 1 (for connected G).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

from repro.configs.base import TopologyConfig


# ---------------------------------------------------------------------------
# graph generators -> adjacency (s, s) bool, no self loops
# ---------------------------------------------------------------------------

def ring_adjacency(s: int) -> np.ndarray:
    a = np.zeros((s, s), bool)
    for i in range(s):
        a[i, (i + 1) % s] = a[(i + 1) % s, i] = True
    if s == 2:
        a[0, 1] = a[1, 0] = True
    return a


def complete_adjacency(s: int) -> np.ndarray:
    a = np.ones((s, s), bool)
    np.fill_diagonal(a, False)
    return a


def geometric_adjacency(s: int, radius: float,
                        rng: np.random.Generator,
                        fallback_counter: list | None = None) -> np.ndarray:
    """Random geometric graph in the unit square; re-draws until connected.

    If 200 draws never produce a connected graph (the radius is too
    small for s points) we fall back to a ring — which is NOT a
    geometric graph and has a very different spectral radius, so the
    fallback is loud: a ``RuntimeWarning`` is emitted and, when the
    caller passes a ``fallback_counter`` list, an entry is appended so
    :func:`build_network` can surface the count on the
    :class:`Network` (``geometric_fallbacks``)."""
    for _ in range(200):
        pts = rng.random((s, 2))
        d = np.linalg.norm(pts[:, None] - pts[None, :], axis=-1)
        a = (d < radius) & ~np.eye(s, dtype=bool)
        if _connected(a):
            return a
    warnings.warn(
        f"geometric_adjacency: no connected graph in 200 draws "
        f"(s={s}, radius={radius:.3f}); falling back to a ring — the "
        f"tuned spectral radius will NOT match the geometric target",
        RuntimeWarning, stacklevel=2)
    if fallback_counter is not None:
        fallback_counter.append((s, radius))
    return ring_adjacency(s)


def _connected(a: np.ndarray) -> bool:
    s = a.shape[0]
    seen = {0}
    frontier = [0]
    while frontier:
        i = frontier.pop()
        for j in np.flatnonzero(a[i]):
            if j not in seen:
                seen.add(j)
                frontier.append(j)
    return len(seen) == s


# ---------------------------------------------------------------------------
# consensus weights (Assumption 2)
# ---------------------------------------------------------------------------

def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings: v_ij = 1/(1+max(d_i,d_j)), v_ii = 1 - sum."""
    deg = adj.sum(1)
    s = adj.shape[0]
    v = np.zeros((s, s))
    for i in range(s):
        for j in range(s):
            if adj[i, j]:
                v[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
    np.fill_diagonal(v, 1.0 - v.sum(1))
    return v


def laplacian_weights(adj: np.ndarray, eps: float | None = None) -> np.ndarray:
    """V = I - eps * L with eps < 1/d_max (Xiao & Boyd 2004)."""
    deg = adj.sum(1)
    L = np.diag(deg) - adj.astype(float)
    if eps is None:
        eps = 1.0 / (deg.max() + 1.0)
    return np.eye(adj.shape[0]) - eps * L


def spectral_radius(v: np.ndarray) -> float:
    """rho(V - 11^T/s): the consensus contraction factor lambda_c."""
    s = v.shape[0]
    m = v - np.ones((s, s)) / s
    return float(np.max(np.abs(np.linalg.eigvalsh((m + m.T) / 2))))


def check_assumption2(v: np.ndarray, adj: np.ndarray,
                      atol: float = 1e-9) -> None:
    s = v.shape[0]
    offdiag = ~np.eye(s, dtype=bool)
    assert np.all(np.abs(v[offdiag & ~adj]) < atol), "sparsity violated"
    assert np.allclose(v.sum(1), 1.0, atol=atol), "rows must sum to 1"
    assert np.allclose(v, v.T, atol=atol), "V must be symmetric"
    assert spectral_radius(v) < 1.0 - 1e-12, "rho(V - 11^T/s) must be < 1"


# ---------------------------------------------------------------------------
# network assembly
# ---------------------------------------------------------------------------

@dataclass
class Network:
    """The full edge network: N equal clusters of s devices.

    V: (N, s, s) stacked consensus matrices
    adj: (N, s, s) adjacencies
    lambdas: (N,) spectral radii rho(V_c - 11^T/s)
    """
    V: np.ndarray
    adj: np.ndarray
    lambdas: np.ndarray
    num_clusters: int
    cluster_size: int
    # how many clusters of the CHOSEN adjacency set came from the
    # ring fallback of geometric_adjacency (0 for non-geometric graphs
    # and healthy geometric draws) — experiments can detect a corrupted
    # spectral-radius tuning instead of silently trusting it
    geometric_fallbacks: int = 0

    @property
    def num_devices(self) -> int:
        return self.num_clusters * self.cluster_size

    @property
    def varrho(self) -> np.ndarray:
        """Cluster weights varrho_c = s_c / I (uniform: equal clusters)."""
        return np.full((self.num_clusters,),
                       self.cluster_size / self.num_devices)

    def num_d2d_edges(self) -> np.ndarray:
        return self.adj.sum((1, 2)) // 2


def _weights_for(adj: np.ndarray, scheme: str) -> np.ndarray:
    if scheme == "metropolis":
        return metropolis_weights(adj)
    if scheme == "laplacian":
        return laplacian_weights(adj)
    raise ValueError(f"unknown weight scheme {scheme!r}")


def build_network(cfg: TopologyConfig) -> Network:
    """Build N clusters; for geometric graphs, tune the radius so the
    average rho(V_c - 11^T/s) approaches ``cfg.target_spectral_radius``."""
    rng = np.random.default_rng(cfg.seed)
    N, s = cfg.num_clusters, cfg.cluster_size

    fallbacks = 0
    if cfg.graph == "ring":
        adjs = np.stack([ring_adjacency(s) for _ in range(N)])
    elif cfg.graph == "complete":
        adjs = np.stack([complete_adjacency(s) for _ in range(N)])
    elif cfg.graph == "geometric":
        adjs, fallbacks = _tuned_geometric(N, s, cfg.target_spectral_radius,
                                           cfg.weights, rng)
    else:
        raise ValueError(f"unknown graph {cfg.graph!r}")

    V = np.stack([_weights_for(a, cfg.weights) for a in adjs])
    for v, a in zip(V, adjs):
        check_assumption2(v, a)
    lambdas = np.array([spectral_radius(v) for v in V])
    return Network(V=V.astype(np.float32), adj=adjs, lambdas=lambdas,
                   num_clusters=N, cluster_size=s,
                   geometric_fallbacks=fallbacks)


def _tuned_geometric(N: int, s: int, target: float, scheme: str,
                     rng: np.random.Generator) -> tuple[np.ndarray, int]:
    """Bisection on the connection radius to match the average spectral
    radius (paper: 'tuned such that clusters have an average spectral
    radius of rho = 0.7'). Returns (adjacencies, ring-fallback count
    among the CHOSEN adjacencies)."""
    lo, hi = 0.3, 1.5   # radius range: sparse ... complete

    def avg_rho(radius: float, trial_rng
                ) -> tuple[float, np.ndarray, int]:
        counter: list = []
        adjs = np.stack([geometric_adjacency(s, radius, trial_rng,
                                             fallback_counter=counter)
                         for _ in range(N)])
        rhos = [spectral_radius(_weights_for(a, scheme)) for a in adjs]
        return float(np.mean(rhos)), adjs, len(counter)

    best_adjs, best_err, best_fb = None, np.inf, 0
    for _ in range(12):
        mid = 0.5 * (lo + hi)
        rho, adjs, fb = avg_rho(mid,
                                np.random.default_rng(rng.integers(2**31)))
        err = abs(rho - target)
        if err < best_err:
            best_err, best_adjs, best_fb = err, adjs, fb
        # denser graph (larger radius) -> faster mixing -> smaller rho
        if rho > target:
            lo = mid
        else:
            hi = mid
    return best_adjs, best_fb
