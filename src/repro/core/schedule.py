"""TT-HF schedules: the decaying step size and the aperiodic D2D-round
rule of Remark 1.

Remark 1:  Gamma_c^(t) = max{ ceil( log(eta_t*phi / (s_c*Upsilon_c^(t)*M))
                                    / log(lambda_c) ), 0 }
so that Lemma 1 gives ||e_i^(t)|| <= lambda^Gamma * s_c * Upsilon_c * M
                              <= eta_t * phi  ==  the Theorem-2 condition
eps^(t) = eta_t * phi. When local models have already agreed
(Upsilon small), Gamma = 0 — consensus is aperiodic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.schedules import paper_schedule, constant


def make_lr_schedule(cfg) -> callable:
    """cfg: TTHFConfig."""
    if cfg.constant_lr > 0:
        return constant(cfg.constant_lr)
    return paper_schedule(cfg.gamma, cfg.alpha)


def adaptive_gamma(eta_t: jax.Array, phi: float, upsilon: jax.Array,
                   lambdas: jax.Array, cluster_size,
                   model_dim: int, max_rounds: int = 64) -> jax.Array:
    """Remark-1 D2D round counts. upsilon, lambdas: (N,) -> (N,) int32.

    ``cluster_size`` may be a scalar (the static s_c) or an (N,) vector
    of per-cluster ACTIVE device counts (netsim churn): the Lemma-1
    prefactor then tracks the devices that actually mix, and a cluster
    with <= 1 active device runs 0 rounds — there is nobody to
    exchange with, so any Gamma would be wasted energy.
    """
    target = eta_t * phi
    sizes = jnp.asarray(cluster_size)
    # Lemma-1 prefactor s_c * Upsilon_c * M
    pref = sizes * upsilon * model_dim
    safe_pref = jnp.maximum(pref, 1e-30)
    ratio = jnp.clip(target / safe_pref, 1e-30, None)
    # lambda^Gamma <= ratio  =>  Gamma >= log(ratio)/log(lambda)
    need = jnp.log(ratio) / jnp.log(jnp.clip(lambdas, 1e-6, 1 - 1e-9))
    gamma = jnp.ceil(need).astype(jnp.int32)
    gamma = jnp.where(pref <= target, 0, gamma)   # already within target
    gamma = jnp.where(sizes <= 1, 0, gamma)       # isolated: nobody to mix
    return jnp.clip(gamma, 0, max_rounds)


def fixed_gamma(num_clusters: int, rounds: int) -> jax.Array:
    return jnp.full((num_clusters,), rounds, jnp.int32)
