"""Unified D2D consensus-mixing engine (DESIGN.md §5).

One operator, four interchangeable backends for the paper's eq. (10)
``z_c <- V_c^{Gamma_c} z_c`` applied to N stacked clusters:

=============  ============================================================
backend        execution strategy
=============  ============================================================
reference      per-round masked einsum, Python-unrolled (the oracle;
               needs concrete gamma)
masked_loop    jittable bounded ``fori_loop`` with per-cluster masking —
               works with *traced* gamma (Remark-1 adaptive rounds)
pallas         fused Gamma-round Pallas TPU kernel
               (``repro.kernels.consensus_mix``; interpret mode on CPU)
fused_power    ONE einsum against the stacked matrix powers
               ``W_c = V_c^{Gamma_c}`` — the scale-mode collective
               collapse; W is precomputed at plan-build time
=============  ============================================================

Every backend accepts a *vector* per-cluster ``gamma: (N,)`` (Remark 1:
aperiodic, heterogeneous round counts), including ``fused_power`` —
each cluster's block of W is raised to its own power.

Call sites (the four previously-divergent paths, now routed here):
``core/consensus.py::mix/mix_pytree`` (simulation public API),
``core/tthf.py`` (simulation trainer), ``core/distributed.py``
(TT-HF scale mode) and ``kernels/ops.py`` (kernel wrapper).

Prefer :func:`build_mixing_plan` + :meth:`MixingPlan.apply` when gamma
and the topology are known at step-build time — the plan precomputes
``W`` exactly once (numpy, exact integer powers) instead of re-deriving
it per call, and pins the dispatch statically so the jitted step closes
over constants only.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

BACKENDS = ("reference", "masked_loop", "pallas", "fused_power")

# scale-mode consensus_mode names kept for backward compatibility
_BACKEND_ALIASES = {
    "fused": "fused_power",     # one collective of the same payload
    "rounds": "reference",      # paper-faithful sequential exchanges
    "kernel": "pallas",
}


def canonical_backend(name: str) -> str:
    """Resolve aliases ("fused", "rounds", "kernel") to backend names."""
    backend = _BACKEND_ALIASES.get(name, name)
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown mixing backend {name!r}; expected one of "
            f"{BACKENDS} or aliases {tuple(_BACKEND_ALIASES)}")
    return backend


def _normalize_gamma(gamma: Any, num_clusters: int) -> jax.Array:
    gamma = jnp.asarray(gamma, jnp.int32)
    if gamma.ndim == 0:
        gamma = jnp.full((num_clusters,), gamma)
    if gamma.shape != (num_clusters,):
        raise ValueError(
            f"gamma must be scalar or ({num_clusters},), got {gamma.shape}")
    return gamma


def masked_consensus_matrix(V: jax.Array, device_mask: jax.Array) -> jax.Array:
    """Drop devices from a consensus-matrix stack (netsim contract).

    Zeroes the dropped devices' rows and columns and returns the
    removed mass to each row's self-loop, so the result is still
    symmetric and row-stochastic:

      * dropped device i: row becomes e_i — a consensus step leaves
        its parameters untouched;
      * active device i: v'_ii = v_ii + sum_{j dropped} v_ij — it
        mixes only among the remaining active devices.

    V: (N, s, s); device_mask: (N, s) bool/0-1. Works under jit (the
    mask may be traced) and commutes with powers: masking then raising
    to Gamma keeps dropped rows identity.
    """
    m = device_mask.astype(V.dtype)
    s = V.shape[-1]
    eye = jnp.eye(s, dtype=V.dtype)
    offdiag = V * (1.0 - eye) * m[:, :, None] * m[:, None, :]
    return offdiag + (1.0 - offdiag.sum(-1))[..., None] * eye


def matrix_powers(V: jax.Array, gamma: jax.Array) -> jax.Array:
    """In-graph stacked powers ``W_c = V_c^{gamma_c}``; (N, s, s).

    Masked bounded loop over max(gamma) — O(max_gamma * N * s^3), which
    is tiny next to the (N, s, M) mixing it replaces.  Jittable with
    traced gamma (the adaptive Remark-1 path).
    """
    N, s, _ = V.shape
    Vf = V.astype(jnp.float32)
    eye = jnp.broadcast_to(jnp.eye(s, dtype=jnp.float32), (N, s, s))

    def body(r, W):
        nxt = jnp.einsum("nij,njk->nik", Vf, W,
                         preferred_element_type=jnp.float32)
        return jnp.where((r < gamma)[:, None, None], nxt, W)

    return jax.lax.fori_loop(0, jnp.max(gamma), body, eye)


# ---------------------------------------------------------------------------
# backend implementations — all (N, s, M) x (N, s, s) x (N,) -> (N, s, M)
# ---------------------------------------------------------------------------

def _mix_reference(z, V, gamma):
    from repro.kernels import ref
    try:
        return ref.consensus_mix_ref(z, V, gamma)
    except (jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError) as e:
        raise ValueError(
            "backend='reference' unrolls gamma rounds in Python and needs "
            "a concrete gamma; use 'masked_loop' (or 'pallas'/"
            "'fused_power') under jit with traced gamma") from e


def _mix_masked_loop(z, V, gamma):
    Vz = V.astype(z.dtype)

    def body(r, zz):
        mixed = jnp.einsum("nij,njm->nim", Vz, zz,
                           preferred_element_type=zz.dtype)
        return jnp.where((r < gamma)[:, None, None], mixed, zz)

    return jax.lax.fori_loop(0, jnp.max(gamma), body, z)


def _mix_pallas(z, V, gamma, blk_m=512):
    from repro.kernels import consensus_mix as _cm
    from repro.kernels import ops as kops
    return _cm.consensus_mix(z, V, gamma, blk_m=blk_m,
                             interpret=kops.INTERPRET)


def _mix_fused_power(z, V, gamma, W=None):
    if W is None:
        W = matrix_powers(V, gamma)
    return jnp.einsum("nij,njm->nim", W.astype(z.dtype), z,
                      preferred_element_type=z.dtype)


def mix(z: jax.Array, V: jax.Array, gamma: Any, *,
        backend: str = "masked_loop", W: Optional[jax.Array] = None,
        device_mask: Optional[jax.Array] = None,
        blk_m: int = 512) -> jax.Array:
    """Apply per-cluster consensus ``z_c <- V_c^{gamma_c} z_c``.

    z: (N, s, M); V: (N, s, s); gamma: scalar or (N,) int32.
    ``W`` (fused_power only): precomputed stacked powers; derived
    in-graph when omitted.
    ``device_mask`` (N, s): drop devices via
    :func:`masked_consensus_matrix` before dispatch — dropped rows hold
    their values through every backend. Incompatible with a
    precomputed ``W`` (powers must be taken AFTER masking).
    """
    backend = canonical_backend(backend)
    gamma = _normalize_gamma(gamma, z.shape[0])
    if device_mask is not None:
        if W is not None:
            raise ValueError(
                "device_mask with precomputed W is ambiguous: powers "
                "must be taken after masking — pass V and let the "
                "backend derive W, or precompute W from the masked V")
        V = masked_consensus_matrix(V, device_mask)
    if backend == "reference":
        return _mix_reference(z, V, gamma)
    if backend == "masked_loop":
        return _mix_masked_loop(z, V, gamma)
    if backend == "pallas":
        return _mix_pallas(z, V, gamma, blk_m=blk_m)
    return _mix_fused_power(z, V, gamma, W=W)


def mix_pytree(params, V: jax.Array, gamma: Any, num_clusters: int, *,
               backend: str = "masked_loop",
               W: Optional[jax.Array] = None,
               device_mask: Optional[jax.Array] = None):
    """Consensus over a pytree whose leaves have leading axis I = N*s.

    Mixing is linear and elementwise across parameters, so each leaf is
    reshaped (I, ...) -> (N, s, M) and mixed independently.
    ``device_mask``: see :func:`mix` — applied once, outside the
    per-leaf loop.
    """
    if device_mask is not None:
        if W is not None:
            raise ValueError(
                "device_mask with precomputed W is ambiguous (see mix)")
        V = masked_consensus_matrix(V, device_mask)

    def one(leaf):
        I = leaf.shape[0]
        s = I // num_clusters
        flat = leaf.reshape(num_clusters, s, -1)
        mixed = mix(flat, V.astype(flat.dtype), gamma,
                    backend=backend, W=W)
        return mixed.reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree.map(one, params)


# ---------------------------------------------------------------------------
# step-build-time plans
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MixingPlan:
    """A consensus event bound to (topology, gamma, backend) at build
    time.  ``W`` is the exact stacked power for ``fused_power`` —
    computed ONCE here (numpy integer matrix powers), never re-derived
    inside the step."""
    backend: str
    num_clusters: int
    cluster_size: int
    V: jax.Array                    # (N, s, s) float32
    gamma: jax.Array                # (N,) int32
    W: Optional[jax.Array] = None   # (N, s, s) float32, fused_power only

    @property
    def is_noop(self) -> bool:
        return bool(np.all(np.asarray(self.gamma) == 0))

    def _matrices(self, refresh: Optional[jax.Array]):
        """Resolve (V, W) given an optional per-call refresh matrix.

        A refresh (from :func:`refresh_matrices`) is whatever the
        backend consumes: the stacked powers W for ``fused_power``, the
        (masked) consensus matrices V otherwise. It may be traced — the
        netsim W-refresh path jits the step once and feeds new
        matrices each aggregation round.
        """
        if refresh is None:
            return self.V, self.W
        if self.backend == "fused_power":
            return self.V, refresh
        return refresh, None

    def apply(self, z: jax.Array,
              refresh: Optional[jax.Array] = None) -> jax.Array:
        """z: (N, s, M) -> mixed (N, s, M)."""
        V, W = self._matrices(refresh)
        return mix(z, V, self.gamma, backend=self.backend, W=W)

    def fused_w(self, refresh: Optional[jax.Array] = None
                ) -> Optional[jax.Array]:
        """The stacked (N, s, s) powers if this plan applies as ONE
        matrix product (``fused_power`` backend), else None.

        The fused-interval step (``core/distributed.py``) uses this to
        route block-ends through the fused SGD+mix kernel; other
        backends fall back to :meth:`apply`.
        """
        if self.backend != "fused_power":
            return None
        return self._matrices(refresh)[1]

    def apply_pytree(self, params, refresh: Optional[jax.Array] = None):
        """params: pytree with leading replica/device axis I = N*s."""
        if self.is_noop and refresh is None:
            return params
        V, W = self._matrices(refresh)
        return mix_pytree(params, V, self.gamma, self.num_clusters,
                          backend=self.backend, W=W)


def build_mixing_plan(net, gamma: Any,
                      backend: str = "fused_power") -> MixingPlan:
    """Build a :class:`MixingPlan` from a ``Network`` (or a raw (N, s, s)
    consensus-matrix stack), concrete per-cluster gamma, and a backend.

    gamma may be a scalar or an (N,) vector (heterogeneous Remark-1
    round counts) but must be concrete — plans exist so the expensive
    derivations happen at step-build time.
    """
    backend = canonical_backend(backend)
    V = np.asarray(getattr(net, "V", net), np.float32)
    N, s, _ = V.shape
    g = np.asarray(gamma, np.int32)
    if g.ndim == 0:
        g = np.full((N,), g, np.int32)
    if g.shape != (N,):
        raise ValueError(f"gamma must be scalar or ({N},), got {g.shape}")
    if (g < 0).any():
        raise ValueError(f"gamma must be >= 0 rounds, got {g.tolist()}")
    W = None
    if backend == "fused_power":
        W = jnp.asarray(
            np.stack([np.linalg.matrix_power(V[c], int(g[c]))
                      for c in range(N)]), jnp.float32)
    return MixingPlan(backend=backend, num_clusters=N, cluster_size=s,
                      V=jnp.asarray(V), gamma=jnp.asarray(g), W=W)


def refresh_matrices(plan: MixingPlan, V: Any,
                     device_mask: Any = None) -> jax.Array:
    """Host-side per-event matrices for ``MixingPlan.apply*(refresh=)``.

    Takes the event's consensus-matrix stack (e.g. a netsim
    ``NetworkSnapshot.V``), optionally drops devices, and returns what
    the plan's backend consumes: exact numpy integer powers
    ``W = V^Gamma`` for ``fused_power``, the (masked) ``V`` itself
    otherwise. This is the scale-mode refresh path — the jitted step
    stays compiled once while the matrices change per aggregation round.
    """
    Vn = np.asarray(V, np.float32)
    if device_mask is not None:
        Vn = np.asarray(masked_consensus_matrix(
            jnp.asarray(Vn), jnp.asarray(device_mask)), np.float32)
    if plan.backend != "fused_power":
        return jnp.asarray(Vn)
    g = np.asarray(plan.gamma, np.int32)
    return jnp.asarray(
        np.stack([np.linalg.matrix_power(Vn[c], int(g[c]))
                  for c in range(Vn.shape[0])]), jnp.float32)


__all__ = ["BACKENDS", "MixingPlan", "build_mixing_plan",
           "canonical_backend", "masked_consensus_matrix",
           "matrix_powers", "mix", "mix_pytree", "refresh_matrices"]
