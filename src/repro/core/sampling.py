"""Global aggregation with cluster sampling (eq. 7).

At t = t_k the server samples ONE device n_c uniformly from each cluster
and forms  w_hat = sum_c varrho_c * w_{n_c}.  Unbiasedness w.r.t. the
cluster means (used in Theorem 1's proof) holds because sampling is
uniform and consensus keeps E[e_{n_c}] = 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_devices(key: jax.Array, num_clusters: int,
                   cluster_size: int) -> jax.Array:
    """(N,) int32 — the sampled local index n_c within each cluster."""
    return jax.random.randint(key, (num_clusters,), 0, cluster_size)


def sampled_global_model(z: jax.Array, picks: jax.Array,
                         varrho: jax.Array) -> jax.Array:
    """z: (N, s, M), picks: (N,), varrho: (N,) -> (M,) the new w_hat."""
    chosen = jnp.take_along_axis(
        z, picks[:, None, None].astype(jnp.int32), axis=1)[:, 0, :]
    return jnp.einsum("c,cm->m", varrho.astype(z.dtype), chosen)


def sampled_global_pytree(params, picks: jax.Array, varrho: jax.Array,
                          num_clusters: int):
    """Pytree version: leaves (I, ...) -> global model leaves (...)
    broadcast back by the caller."""
    def one(leaf):
        I = leaf.shape[0]
        s = I // num_clusters
        z = leaf.reshape(num_clusters, s, -1)
        g = sampled_global_model(z, picks, varrho)
        return g.reshape(leaf.shape[1:])
    return jax.tree.map(one, params)


def full_global_pytree(params, varrho: jax.Array, num_clusters: int):
    """Full-participation aggregation (baseline FL): weighted mean of all
    devices = sum_c varrho_c * (1/s_c) sum_i w_i."""
    def one(leaf):
        I = leaf.shape[0]
        s = I // num_clusters
        z = leaf.reshape(num_clusters, s, -1).mean(axis=1)
        g = jnp.einsum("c,cm->m", varrho.astype(z.dtype), z)
        return g.reshape(leaf.shape[1:])
    return jax.tree.map(one, params)


def broadcast_pytree(global_params, num_devices: int):
    """Server broadcast: w_i <- w_hat for all i."""
    return jax.tree.map(
        lambda g: jnp.broadcast_to(g[None], (num_devices,) + g.shape),
        global_params)
