"""Global aggregation with cluster sampling (eq. 7).

At t = t_k the server samples ONE device n_c uniformly from each cluster
and forms  w_hat = sum_c varrho_c * w_{n_c}.  Unbiasedness w.r.t. the
cluster means (used in Theorem 1's proof) holds because sampling is
uniform and consensus keeps E[e_{n_c}] = 0.

``sample_per_cluster > 1`` generalizes to k representatives drawn
WITHOUT replacement and averaged within the cluster:
w_hat = sum_c varrho_c * (1/k) sum_j w_{n_{c,j}} — still unbiased, with
variance shrunk by the within-cluster averaging. The ledger bills
exactly N * k uplinks, matching what is actually transmitted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_devices(key: jax.Array, num_clusters: int,
                   cluster_size: int) -> jax.Array:
    """(N,) int32 — the sampled local index n_c within each cluster."""
    return jax.random.randint(key, (num_clusters,), 0, cluster_size)


def sample_devices_multi(key: jax.Array, num_clusters: int,
                         cluster_size: int, k: int) -> jax.Array:
    """(N, k) int32 — k DISTINCT local indices per cluster, uniform
    without replacement (Gumbel-top-k: rank iid uniforms).

    k == 1 delegates to :func:`sample_devices` so the historical
    single-representative sampling stream is reproduced bit-for-bit.
    """
    if not 1 <= k <= cluster_size:
        raise ValueError(
            f"sample_per_cluster must be in [1, {cluster_size}], got {k}")
    if k == 1:
        return sample_devices(key, num_clusters, cluster_size)[:, None]
    scores = jax.random.uniform(key, (num_clusters, cluster_size))
    _, idx = jax.lax.top_k(scores, k)
    return idx.astype(jnp.int32)


def sampled_global_model(z: jax.Array, picks: jax.Array,
                         varrho: jax.Array) -> jax.Array:
    """z: (N, s, M), picks: (N,), varrho: (N,) -> (M,) the new w_hat."""
    chosen = jnp.take_along_axis(
        z, picks[:, None, None].astype(jnp.int32), axis=1)[:, 0, :]
    return jnp.einsum("c,cm->m", varrho.astype(z.dtype), chosen)


def sampled_global_model_multi(z: jax.Array, picks: jax.Array,
                               varrho: jax.Array) -> jax.Array:
    """z: (N, s, M), picks: (N, k) -> (M,): varrho-weighted mean of the
    per-cluster averages of the k sampled representatives."""
    chosen = jnp.take_along_axis(
        z, picks[..., None].astype(jnp.int32), axis=1)      # (N, k, M)
    k = picks.shape[1]
    return jnp.einsum("c,ckm->m", varrho.astype(z.dtype) / k, chosen)


def sampled_global_pytree(params, picks: jax.Array, varrho: jax.Array,
                          num_clusters: int):
    """Pytree version: leaves (I, ...) -> global model leaves (...)
    broadcast back by the caller. ``picks`` may be (N,) — one
    representative, the paper's eq. (7) — or (N, k) for averaged
    multi-device sampling."""
    def one(leaf):
        I = leaf.shape[0]
        s = I // num_clusters
        z = leaf.reshape(num_clusters, s, -1)
        if picks.ndim == 1:
            g = sampled_global_model(z, picks, varrho)
        else:
            g = sampled_global_model_multi(z, picks, varrho)
        return g.reshape(leaf.shape[1:])
    return jax.tree.map(one, params)


def full_global_pytree(params, varrho: jax.Array, num_clusters: int):
    """Full-participation aggregation (baseline FL): weighted mean of all
    devices = sum_c varrho_c * (1/s_c) sum_i w_i."""
    def one(leaf):
        I = leaf.shape[0]
        s = I // num_clusters
        z = leaf.reshape(num_clusters, s, -1).mean(axis=1)
        g = jnp.einsum("c,cm->m", varrho.astype(z.dtype), z)
        return g.reshape(leaf.shape[1:])
    return jax.tree.map(one, params)


def broadcast_pytree(global_params, num_devices: int):
    """Server broadcast: w_i <- w_hat for all i."""
    return jax.tree.map(
        lambda g: jnp.broadcast_to(g[None], (num_devices,) + g.shape),
        global_params)
