"""Algorithm 1 — TT-HF simulation engine (vmapped device fleet).

The engine runs the exact two-timescale procedure of the paper on a
stacked device fleet: every pytree leaf carries a leading device axis
``I = N * s``; local SGD is ``vmap`` over that axis; consensus reshapes
to ``(N, s, M)`` and applies the block-diagonal mixing; aggregations
implement the cluster-sampled global model of eq. (7).

Baselines (Sec. IV-B) are the same engine with ``mode``:
  * ``tthf``        — Algorithm 1 (sampled aggregation + D2D consensus)
  * ``fedavg``      — star FL, full participation, no D2D (tau as given)
  * ``centralized`` — star FL with tau = 1 (the paper's upper bound)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    DynamicsConfig, HierarchyConfig, TTHFConfig, TopologyConfig)
from repro.core import consensus as cns
from repro.core import mixing
from repro.core import sampling as smp
from repro.core.energy import CommLedger
from repro.core.schedule import adaptive_gamma, fixed_gamma, make_lr_schedule
from repro.core.topology import Network, build_network
from repro.data.synth import FederatedDataset
from repro.models.simple import SimModel


@dataclass
class TTHFState:
    params: Any                  # pytree, leaves (I, ...)
    global_params: Any           # pytree, leaves (...)
    t: int
    key: jax.Array


@dataclass
class History:
    ts: list = field(default_factory=list)
    global_loss: list = field(default_factory=list)
    global_acc: list = field(default_factory=list)
    dispersion: list = field(default_factory=list)   # A^(t) estimate
    consensus_err: list = field(default_factory=list)
    gamma_used: list = field(default_factory=list)
    uplinks: list = field(default_factory=list)
    d2d_msgs: list = field(default_factory=list)
    active_devices: list = field(default_factory=list)   # netsim churn

    def as_arrays(self) -> dict[str, np.ndarray]:
        return {k: np.asarray(v) for k, v in dataclasses.asdict(self).items()}


class TTHFTrainer:
    """Drives Algorithm 1 over a :class:`FederatedDataset`."""

    def __init__(self, model: SimModel, data: FederatedDataset,
                 topo_cfg: TopologyConfig, algo: TTHFConfig,
                 batch_size: int = 16, eval_x: np.ndarray | None = None,
                 eval_y: np.ndarray | None = None,
                 use_kernel: bool = False, backend: str | None = None,
                 dynamics: Optional[DynamicsConfig] = None,
                 hierarchy: Optional[HierarchyConfig] = None):
        assert data.num_devices == topo_cfg.num_devices
        assert 1 <= algo.sample_per_cluster <= topo_cfg.cluster_size, \
            "sample_per_cluster must be within the cluster size"
        self.model = model
        self.data = data
        self.algo = algo
        self.net: Network = build_network(topo_cfg)
        self.batch_size = batch_size
        self.use_kernel = use_kernel
        # netsim dynamics: a static (or absent) config takes the exact
        # historical code path below — bit-for-bit trajectories
        self.dynamics = dynamics
        self.tvnet = None
        if dynamics is not None and not dynamics.is_static:
            from repro.netsim.dynamics import TimeVaryingNetwork
            self.tvnet = TimeVaryingNetwork(self.net, dynamics,
                                            weights=topo_cfg.weights)
        # multi-stage fog hierarchy (repro.hierarchy): a flat (L = 2)
        # config IS two-timescale TT-HF — it adds nothing, so it is
        # ignored entirely (the TT-HF knobs come from ``algo``) and the
        # historical code path below runs bit-for-bit
        self.hierarchy = None
        self.tree = None
        if hierarchy is not None and not hierarchy.is_flat:
            assert algo.mode == "tthf" and not algo.full_participation, \
                "hierarchical aggregation implies sampled tthf mode"
            assert hierarchy.taus[0] == algo.tau, \
                f"tier-1 period {hierarchy.taus[0]} must equal tau={algo.tau}"
            assert hierarchy.sample[0] == algo.sample_per_cluster, \
                "tier-1 fan-in must equal sample_per_cluster"
            from repro.hierarchy import build_tree
            self.hierarchy = hierarchy
            self.tree = build_tree(hierarchy, self.net.num_clusters,
                                   self.net.cluster_size)
        # consensus backend (core/mixing.py): gamma is traced inside the
        # jitted consensus (Remark-1 adaptive rounds), so the default is
        # the masked bounded loop; use_kernel routes through Pallas.
        if backend is None:
            backend = "pallas" if use_kernel else "masked_loop"
        self.backend = mixing.canonical_backend(backend)
        self.eta = make_lr_schedule(algo)
        self.ledger = CommLedger()
        self.x = jnp.asarray(data.x)
        self.y = jnp.asarray(data.y)
        self.eval_x = jnp.asarray(eval_x) if eval_x is not None else None
        self.eval_y = jnp.asarray(eval_y) if eval_y is not None else None
        self.V = jnp.asarray(self.net.V)
        self.varrho = jnp.asarray(self.net.varrho, jnp.float32)
        self.lambdas = jnp.asarray(self.net.lambdas, jnp.float32)
        self._edges = self.net.num_d2d_edges()
        self.model_dim = None    # set at init()

        self._local_step = jax.jit(self._local_step_impl)
        self._consensus = jax.jit(self._consensus_impl)
        self._aggregate = jax.jit(self._aggregate_impl,
                                  static_argnames=("full",))
        self._eval = jax.jit(self._eval_impl)
        self._upsilon = jax.jit(self._upsilon_impl)
        # dynamic-mode (netsim) variants: V / masks become call arguments
        self._local_step_dyn = jax.jit(self._local_step_dyn_impl)
        self._consensus_dyn = jax.jit(self._consensus_dyn_impl)
        self._aggregate_dyn = jax.jit(self._aggregate_dyn_impl)
        self._upsilon_dyn = jax.jit(self._upsilon_dyn_impl)
        # hierarchical variants: the event's composed (I, I) device
        # matrix and the root's (I,) source weights are call arguments
        self._apply_event = jax.jit(self._apply_event_impl)
        self._global_from_weights = jax.jit(self._global_from_weights_impl)

    # ------------------------------------------------------------------
    def init(self, seed: int = 0) -> TTHFState:
        key = jax.random.PRNGKey(seed)
        k0, key = jax.random.split(key)
        w0 = self.model.init(k0)
        self.model_dim = int(sum(np.prod(l.shape)
                                 for l in jax.tree.leaves(w0)))
        params = smp.broadcast_pytree(w0, self.data.num_devices)
        return TTHFState(params=params, global_params=w0, t=0, key=key)

    # ------------------------------------------------------------------
    # jitted pieces
    # ------------------------------------------------------------------
    def _local_step_impl(self, params, key, eta_t):
        """One vmapped SGD iteration (eqs. 8-9) for every device."""
        I, D = self.y.shape
        keys = jax.random.split(key, I)

        def dev_step(p, k, xd, yd):
            idx = jax.random.randint(k, (self.batch_size,), 0, D)
            xb, yb = xd[idx], yd[idx]
            g = jax.grad(self.model.loss)(p, xb, yb)
            return jax.tree.map(lambda w, gg: w - eta_t * gg, p, g)

        return jax.vmap(dev_step)(params, keys, self.x, self.y)

    def _consensus_impl(self, params, gamma):
        return mixing.mix_pytree(params, self.V, gamma,
                                 self.net.num_clusters,
                                 backend=self.backend)

    def _aggregate_impl(self, params, key, full: bool):
        if full:
            g = smp.full_global_pytree(params, self.varrho,
                                       self.net.num_clusters)
        elif self.algo.sample_per_cluster == 1:
            picks = smp.sample_devices(key, self.net.num_clusters,
                                       self.net.cluster_size)
            g = smp.sampled_global_pytree(params, picks, self.varrho,
                                          self.net.num_clusters)
        else:
            # k representatives without replacement, averaged (eq. 7
            # generalized) — the ledger's N * k uplinks are now real
            picks = smp.sample_devices_multi(key, self.net.num_clusters,
                                             self.net.cluster_size,
                                             self.algo.sample_per_cluster)
            g = smp.sampled_global_pytree(params, picks, self.varrho,
                                          self.net.num_clusters)
        return g, smp.broadcast_pytree(g, self.data.num_devices)

    def _eval_impl(self, global_params):
        """Global loss F(w_hat) (eq. 3) + accuracy over all local data."""
        def dev_loss(xd, yd):
            return self.model.loss(global_params, xd, yd)
        losses = jax.vmap(dev_loss)(self.x, self.y)
        loss = jnp.mean(losses)     # equal rho_{i,c}, varrho_c=s/I
        if self.eval_x is not None:
            acc = self.model.accuracy(global_params, self.eval_x,
                                      self.eval_y)
        else:
            flat_x = self.x.reshape(-1, self.x.shape[-1])
            flat_y = self.y.reshape(-1)
            acc = self.model.accuracy(global_params, flat_x, flat_y)
        return loss, acc

    def _upsilon_impl(self, params):
        """Definition-2 divergence per cluster, max over leaves."""
        ups = []
        for leaf in jax.tree.leaves(params):
            z = leaf.reshape(self.net.num_clusters, self.net.cluster_size, -1)
            ups.append(cns.divergence_upsilon(z))
        return jnp.max(jnp.stack(ups), axis=0)

    # ------------------------------------------------------------------
    # netsim (dynamic-mode) jitted pieces: the event's V / masks / agg
    # weights arrive as call arguments so one compilation serves every
    # event of a run
    # ------------------------------------------------------------------
    def _local_step_dyn_impl(self, params, key, eta_t, device_up_flat):
        """Local SGD with churn: a dropped device is offline — it takes
        no gradient step and simply holds its parameters."""
        stepped = self._local_step_impl(params, key, eta_t)

        def freeze(new, old):
            m = device_up_flat.reshape((-1,) + (1,) * (old.ndim - 1))
            return jnp.where(m, new, old)

        return jax.tree.map(freeze, stepped, params)

    def _consensus_dyn_impl(self, params, V, gamma):
        return mixing.mix_pytree(params, V, gamma,
                                 self.net.num_clusters,
                                 backend=self.backend)

    def _aggregate_dyn_impl(self, params, weights, device_up_flat):
        """Availability-aware eq. (7): aggregate with per-device weights
        (netsim.faults builders) and broadcast only to devices that are
        up — offline devices cannot hear the server."""
        from repro.netsim.faults import weighted_global_pytree
        g = weighted_global_pytree(params, weights, self.net.num_clusters)
        bcast = smp.broadcast_pytree(g, self.data.num_devices)

        def receive(new, old):
            m = device_up_flat.reshape((-1,) + (1,) * (old.ndim - 1))
            return jnp.where(m, new, old)

        return g, jax.tree.map(receive, bcast, params)

    def _apply_event_impl(self, params, device_matrix):
        from repro.hierarchy.aggregate import apply_device_matrix_pytree
        return apply_device_matrix_pytree(params, device_matrix)

    def _global_from_weights_impl(self, params, gw):
        from repro.hierarchy.aggregate import global_from_weights
        return global_from_weights(params, gw)

    def _upsilon_dyn_impl(self, params, device_up):
        """Definition-2 divergence over ACTIVE devices, max over leaves."""
        ups = []
        for leaf in jax.tree.leaves(params):
            z = leaf.reshape(self.net.num_clusters, self.net.cluster_size, -1)
            ups.append(cns.masked_divergence_upsilon(z, device_up))
        return jnp.max(jnp.stack(ups), axis=0)

    # ------------------------------------------------------------------
    # consensus events — shared by the static, dynamic and hierarchical
    # loops (one home for the gamma schedule + ledger billing)
    # ------------------------------------------------------------------
    def _consensus_event_static(self, st, eta_t) -> np.ndarray:
        """One consensus event on the base topology; mutates st.params,
        bills the ledger, returns the per-cluster rounds used."""
        algo = self.algo
        if algo.gamma_d2d >= 0:
            gamma = fixed_gamma(self.net.num_clusters, algo.gamma_d2d)
        else:
            ups = self._upsilon(st.params)
            gamma = adaptive_gamma(eta_t, algo.phi, ups, self.lambdas,
                                   self.net.cluster_size, self.model_dim)
        st.params = self._consensus(st.params, gamma)
        gamma_used = np.asarray(gamma)
        self.ledger.record_consensus(gamma_used, self._edges)
        return gamma_used

    def _consensus_event_dynamic(self, st, snap, eta_t, up) -> np.ndarray:
        """One consensus event on the snapshot's active subgraph.
        Clusters with no live edge have nothing to exchange: mixing
        there is the identity, so neither run nor bill rounds (covers
        lambda=0 under the adaptive rule too)."""
        from repro.netsim import faults

        algo = self.algo
        if algo.gamma_d2d >= 0:
            gamma = fixed_gamma(self.net.num_clusters, algo.gamma_d2d)
        else:
            ups = self._upsilon_dyn(st.params, up)
            gamma = adaptive_gamma(
                eta_t, algo.phi, ups,
                jnp.asarray(snap.lambdas, jnp.float32),
                jnp.asarray(snap.active_per_cluster, jnp.int32),
                self.model_dim)
        gamma = jnp.where(
            jnp.asarray(snap.num_active_edges()) == 0, 0, gamma)
        st.params = self._consensus_dyn(
            st.params, jnp.asarray(snap.V), gamma)
        gamma_used = np.asarray(gamma)
        self.ledger.record_consensus(
            gamma_used, snap.num_active_edges(),
            tail_mult_per_cluster=faults.consensus_tail_mult(
                snap.delay_mult, snap.device_up, snap.adj))
        return gamma_used

    def _dispersion(self, params):
        """A^(t) sample: sum_c varrho_c ||wbar_c - wbar||^2."""
        total = 0.0
        for leaf in jax.tree.leaves(params):
            z = leaf.reshape(self.net.num_clusters, self.net.cluster_size, -1)
            means = cns.cluster_means(z)
            gmean = jnp.einsum("c,cm->m", self.varrho.astype(z.dtype), means)
            total += jnp.sum(self.varrho *
                             jnp.sum((means - gmean) ** 2, axis=-1))
        return total

    def _consensus_error(self, params):
        total = 0.0
        for leaf in jax.tree.leaves(params):
            z = leaf.reshape(self.net.num_clusters, self.net.cluster_size, -1)
            total += jnp.sum(self.varrho * cns.consensus_error(z))
        return total

    # ------------------------------------------------------------------
    def run(self, steps: int, seed: int = 0, eval_every: int = 5,
            state: TTHFState | None = None,
            record_dispersion: bool = True) -> tuple[TTHFState, History]:
        """Drive Algorithm 1. With a non-static ``dynamics`` config the
        netsim path runs instead; a static/absent config takes the
        historical code path (bit-for-bit identical trajectories).
        A non-flat ``hierarchy`` config routes to the multi-stage fog
        loop (a flat one is plain TT-HF and stays on this path)."""
        if self.tree is not None:
            return self._run_hierarchical(steps, seed, eval_every, state,
                                          record_dispersion)
        if self.tvnet is not None:
            return self._run_dynamic(steps, seed, eval_every, state,
                                     record_dispersion)
        st = state or self.init(seed)
        hist = History()
        algo = self.algo

        for t in range(st.t + 1, st.t + steps + 1):
            eta_t = self.eta(t - 1)
            st.key, k_step, k_agg = jax.random.split(st.key, 3)
            st.params = self._local_step(st.params, k_step, eta_t)
            self.ledger.record_local_step(self.data.num_devices)

            gamma_used = np.zeros((self.net.num_clusters,), np.int32)
            if algo.is_consensus_step(t):
                gamma_used = self._consensus_event_static(st, eta_t)

            if algo.is_aggregation_step(t):
                full = algo.full_participation or algo.mode != "tthf"
                g, st.params = self._aggregate(st.params, k_agg, full=full)
                st.global_params = g
                n_up = (self.data.num_devices if full
                        else self.net.num_clusters * algo.sample_per_cluster)
                self.ledger.record_aggregation(n_up)

            if t % eval_every == 0 or t == st.t + steps:
                loss, acc = self._eval(st.global_params)
                hist.ts.append(t)
                hist.global_loss.append(float(loss))
                hist.global_acc.append(float(acc))
                if record_dispersion:
                    hist.dispersion.append(float(self._dispersion(st.params)))
                    hist.consensus_err.append(
                        float(self._consensus_error(st.params)))
                hist.gamma_used.append(gamma_used.copy())
                hist.uplinks.append(self.ledger.uplinks)
                hist.d2d_msgs.append(self.ledger.d2d_msgs)
                hist.active_devices.append(self.data.num_devices)

        st.t += steps
        return st, hist

    # ------------------------------------------------------------------
    def _run_dynamic(self, steps: int, seed: int = 0, eval_every: int = 5,
                     state: TTHFState | None = None,
                     record_dispersion: bool = True
                     ) -> tuple[TTHFState, History]:
        """Algorithm 1 under time-varying network dynamics.

        Per iteration the :class:`~repro.netsim.dynamics.
        TimeVaryingNetwork` snapshot supplies the active topology:
        dropped devices freeze (no SGD, no mixing, no uplink, no
        broadcast), consensus mixes over the event's rebuilt ``V`` with
        Remark-1 gammas driven by the event's component-wise lambdas
        and the ACTIVE-device divergence, sampling draws only among
        available devices with dark clusters renormalized away, and
        stragglers stretch the ledger's delay. The JAX PRNG *key
        schedule* is split exactly as in the static path, but sampling
        draws go through a host-side generator seeded from the key, so
        trajectories differ from the static path even under an all-up
        event stream — bit-for-bit static reproduction comes from
        ``run()`` routing static configs to the static path, not from
        this loop.
        """
        from repro.netsim import faults

        st = state or self.init(seed)
        hist = History()
        algo = self.algo
        N, s = self.net.num_clusters, self.net.cluster_size
        k = algo.sample_per_cluster

        for t in range(st.t + 1, st.t + steps + 1):
            eta_t = self.eta(t - 1)
            st.key, k_step, k_agg = jax.random.split(st.key, 3)
            snap = self.tvnet.snapshot(t)
            up = jnp.asarray(snap.device_up)
            up_flat = up.reshape(-1)
            st.params = self._local_step_dyn(st.params, k_step, eta_t,
                                             up_flat)
            self.ledger.record_local_step(int(snap.device_up.sum()))

            gamma_used = np.zeros((N,), np.int32)
            if algo.is_consensus_step(t):
                gamma_used = self._consensus_event_dynamic(st, snap,
                                                           eta_t, up)

            if algo.is_aggregation_step(t):
                full = algo.full_participation or algo.mode != "tthf"
                if full:
                    weights = faults.full_participation_weights(
                        snap.device_up, np.asarray(self.net.varrho))
                    n_up = int(snap.device_up.sum())
                    mults = snap.delay_mult[snap.device_up]
                else:
                    # availability-aware cluster sampling: the jax key
                    # seeds a host-side draw among available devices
                    rng = np.random.default_rng(
                        int(jax.random.randint(k_agg, (), 0, 2**31 - 1)))
                    picks, counts = faults.availability_sample(
                        rng, snap.device_up, k=k)
                    weights = faults.aggregation_weights(
                        picks, counts, snap.varrho, s)
                    n_up = int(counts.sum())
                    mults = faults.uplink_tail_mults(
                        snap.delay_mult, picks, counts)
                if n_up > 0:
                    g, st.params = self._aggregate_dyn(
                        st.params, jnp.asarray(weights, jnp.float32),
                        up_flat)
                    st.global_params = g
                    self.ledger.record_aggregation(
                        n_up, uplink_delay_mults=mults)
                # an all-dark fleet skips the aggregation entirely: no
                # uplinks, no broadcast, the global model stays put

            if t % eval_every == 0 or t == st.t + steps:
                loss, acc = self._eval(st.global_params)
                hist.ts.append(t)
                hist.global_loss.append(float(loss))
                hist.global_acc.append(float(acc))
                if record_dispersion:
                    hist.dispersion.append(float(self._dispersion(st.params)))
                    hist.consensus_err.append(
                        float(self._consensus_error(st.params)))
                hist.gamma_used.append(gamma_used.copy())
                hist.uplinks.append(self.ledger.uplinks)
                hist.d2d_msgs.append(self.ledger.d2d_msgs)
                hist.active_devices.append(int(snap.device_up.sum()))

        st.t += steps
        return st, hist

    # ------------------------------------------------------------------
    def _run_hierarchical(self, steps: int, seed: int = 0,
                          eval_every: int = 5,
                          state: TTHFState | None = None,
                          record_dispersion: bool = True
                          ) -> tuple[TTHFState, History]:
        """Algorithm 1 generalized to the multi-stage fog hierarchy
        (DESIGN.md §9).

        Local SGD and D2D consensus run exactly as in the static (or,
        with a non-static ``dynamics``, the netsim) loop. At every
        tier-1 step (``hierarchy.taus[0] == algo.tau``) the host
        resolves a :class:`~repro.hierarchy.aggregate.HierarchyEvent`:
        the event calendar picks the depth (nested periods — a root
        event composes every tier below it), sampling draws only among
        available devices/subtrees with dark subtrees renormalized
        away, and the composed (I, I) device matrix is applied in one
        jitted einsum — devices below a depth-d ancestor receive that
        subtree's aggregate, offline devices hold their parameters.
        ``global_params`` (the served model) updates only when the
        root fires; the ledger tags every tier's uplinks by level.
        """
        from repro.hierarchy import build_event
        from repro.netsim import faults

        st = state or self.init(seed)
        hist = History()
        algo = self.algo
        N, s = self.net.num_clusters, self.net.cluster_size

        for t in range(st.t + 1, st.t + steps + 1):
            eta_t = self.eta(t - 1)
            st.key, k_step, k_agg = jax.random.split(st.key, 3)
            snap = (self.tvnet.snapshot(t)
                    if self.tvnet is not None else None)
            if snap is None:
                st.params = self._local_step(st.params, k_step, eta_t)
                self.ledger.record_local_step(self.data.num_devices)
            else:
                up = jnp.asarray(snap.device_up)
                st.params = self._local_step_dyn(st.params, k_step, eta_t,
                                                 up.reshape(-1))
                self.ledger.record_local_step(int(snap.device_up.sum()))

            gamma_used = np.zeros((N,), np.int32)
            if algo.is_consensus_step(t):
                if snap is None:
                    gamma_used = self._consensus_event_static(st, eta_t)
                else:
                    gamma_used = self._consensus_event_dynamic(
                        st, snap, eta_t, up)

            if algo.is_aggregation_step(t):
                rng = np.random.default_rng(
                    int(jax.random.randint(k_agg, (), 0, 2**31 - 1)))
                device_up = (snap.device_up if snap is not None
                             else np.ones((N, s), bool))
                ev = build_event(rng, self.tree, self.hierarchy, t,
                                 device_up, receive_offline=False)
                if ev is not None and ev.total_uplinks > 0:
                    if ev.global_weights is not None:
                        st.global_params = self._global_from_weights(
                            st.params, jnp.asarray(ev.global_weights))
                    st.params = self._apply_event(
                        st.params, jnp.asarray(ev.device_matrix))
                    self.ledger.record_hierarchy_event(
                        ev.uplinks_by_level,
                        uplink_delay_mults=(faults.uplink_tail_mults(
                            snap.delay_mult, ev.picks, ev.counts)
                            if snap is not None else None))
                # an all-dark fleet skips the event: no uplinks, no
                # broadcast, every model (and the global one) stays put

            if t % eval_every == 0 or t == st.t + steps:
                loss, acc = self._eval(st.global_params)
                hist.ts.append(t)
                hist.global_loss.append(float(loss))
                hist.global_acc.append(float(acc))
                if record_dispersion:
                    hist.dispersion.append(float(self._dispersion(st.params)))
                    hist.consensus_err.append(
                        float(self._consensus_error(st.params)))
                hist.gamma_used.append(gamma_used.copy())
                hist.uplinks.append(self.ledger.uplinks)
                hist.d2d_msgs.append(self.ledger.d2d_msgs)
                hist.active_devices.append(
                    int(snap.device_up.sum()) if snap is not None
                    else self.data.num_devices)

        st.t += steps
        return st, hist


def make_baseline_config(mode: str, tau: int) -> TTHFConfig:
    """Paper baselines: FL with full participation (tau=1 'centralized'
    upper bound, or tau=20 per [6])."""
    if mode == "centralized":
        return TTHFConfig(mode="centralized", tau=1, full_participation=True,
                          consensus_every=0, gamma_d2d=0)
    if mode == "fedavg":
        return TTHFConfig(mode="fedavg", tau=tau, full_participation=True,
                          consensus_every=0, gamma_d2d=0)
    raise ValueError(mode)
