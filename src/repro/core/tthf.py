"""Algorithm 1 — TT-HF simulation engine (vmapped device fleet).

The engine runs the exact two-timescale procedure of the paper on a
stacked device fleet: every pytree leaf carries a leading device axis
``I = N * s``; local SGD is ``vmap`` over that axis; consensus reshapes
to ``(N, s, M)`` and applies the block-diagonal mixing; aggregations
implement the cluster-sampled global model of eq. (7).

Every scenario — static, netsim dynamics, fog hierarchy, and their
compositions — runs through ONE loop: a
:class:`~repro.rounds.resolver.RoundResolver` turns the declarative
:class:`~repro.rounds.program.RoundProgram` into per-round events, and
the local-SGD iterations between events execute as one jitted
``lax.scan`` (DESIGN.md §10), so the host dispatches per *event*
rather than per iteration.

Baselines (Sec. IV-B) are the same engine with ``mode``:
  * ``tthf``        — Algorithm 1 (sampled aggregation + D2D consensus)
  * ``fedavg``      — star FL, full participation, no D2D (tau as given)
  * ``centralized`` — star FL with tau = 1 (the paper's upper bound)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    DynamicsConfig, HierarchyConfig, TTHFConfig, TopologyConfig)
from repro.core import consensus as cns
from repro.core import mixing
from repro.core import sampling as smp
from repro.core.energy import CommLedger
from repro.core.schedule import adaptive_gamma, fixed_gamma, make_lr_schedule
from repro.core.topology import Network, build_network
from repro.data.synth import FederatedDataset
from repro.models.simple import SimModel
from repro.obs.sink import NULL_OBS
from repro.rounds import RoundProgram, RoundResolver


@dataclass
class TTHFState:
    params: Any                  # pytree, leaves (I, ...)
    global_params: Any           # pytree, leaves (...)
    t: int
    key: jax.Array


@dataclass
class History:
    ts: list = field(default_factory=list)
    global_loss: list = field(default_factory=list)
    global_acc: list = field(default_factory=list)
    dispersion: list = field(default_factory=list)   # A^(t) estimate
    consensus_err: list = field(default_factory=list)
    gamma_used: list = field(default_factory=list)
    uplinks: list = field(default_factory=list)
    d2d_msgs: list = field(default_factory=list)
    active_devices: list = field(default_factory=list)   # netsim churn

    def as_arrays(self) -> dict[str, np.ndarray]:
        return {k: np.asarray(v) for k, v in dataclasses.asdict(self).items()}


class TTHFTrainer:
    """Drives Algorithm 1 over a :class:`FederatedDataset`."""

    def __init__(self, model: SimModel, data: FederatedDataset,
                 topo_cfg: TopologyConfig, algo: TTHFConfig,
                 batch_size: int = 16, eval_x: np.ndarray | None = None,
                 eval_y: np.ndarray | None = None,
                 use_kernel: bool = False, backend: str | None = None,
                 dynamics: Optional[DynamicsConfig] = None,
                 hierarchy: Optional[HierarchyConfig] = None,
                 program: Optional[RoundProgram] = None,
                 chunked: bool = True):
        assert data.num_devices == topo_cfg.num_devices
        assert 1 <= algo.sample_per_cluster <= topo_cfg.cluster_size, \
            "sample_per_cluster must be within the cluster size"
        self.model = model
        self.data = data
        self.algo = algo
        self.net: Network = build_network(topo_cfg)
        self.batch_size = batch_size
        self.use_kernel = use_kernel
        # the declarative round program (DESIGN.md §10): a static (or
        # absent) dynamics config and a flat (L = 2) hierarchy resolve
        # to the exact historical code path — bit-for-bit trajectories.
        # ``dynamics``/``hierarchy`` kwargs are sugar for a program.
        if program is None:
            program = RoundProgram(dynamics=dynamics, hierarchy=hierarchy)
        else:
            assert dynamics is None and hierarchy is None, \
                "pass either program= or the dynamics=/hierarchy= sugar " \
                "kwargs, not both (the kwargs would be silently ignored)"
        self.program = program
        self._resolver = RoundResolver.for_sim(
            self.net, algo, program, topo_weights=topo_cfg.weights)
        self.dynamics = program.dynamics
        self.hierarchy = self._resolver.hierarchy
        self.tvnet = self._resolver.tvnet
        self.tree = self._resolver.tree
        # chunked=False forces per-iteration spans — the pre-engine
        # dispatch cadence, kept as the benchmark baseline
        self.chunked = chunked
        # consensus backend (core/mixing.py): gamma is traced inside the
        # jitted consensus (Remark-1 adaptive rounds), so the default is
        # the masked bounded loop; use_kernel routes through Pallas.
        if backend is None:
            backend = "pallas" if use_kernel else "masked_loop"
        self.backend = mixing.canonical_backend(backend)
        self.eta = make_lr_schedule(algo)
        self.ledger = CommLedger()
        self.x = jnp.asarray(data.x)
        self.y = jnp.asarray(data.y)
        self.eval_x = jnp.asarray(eval_x) if eval_x is not None else None
        self.eval_y = jnp.asarray(eval_y) if eval_y is not None else None
        self.V = jnp.asarray(self.net.V)
        self.varrho = jnp.asarray(self.net.varrho, jnp.float32)
        self.lambdas = jnp.asarray(self.net.lambdas, jnp.float32)
        self._edges = self.net.num_d2d_edges()
        self.model_dim = None    # set at init()

        self._local_step = jax.jit(self._local_step_impl)
        self._consensus = jax.jit(self._consensus_impl)
        self._aggregate = jax.jit(self._aggregate_impl,
                                  static_argnames=("full",))
        self._eval = jax.jit(self._eval_impl)
        self._upsilon = jax.jit(self._upsilon_impl)
        # dynamic-mode (netsim) variants: V / masks become call arguments
        self._local_step_dyn = jax.jit(self._local_step_dyn_impl)
        self._consensus_dyn = jax.jit(self._consensus_dyn_impl)
        self._aggregate_dyn = jax.jit(self._aggregate_dyn_impl)
        self._upsilon_dyn = jax.jit(self._upsilon_dyn_impl)
        # hierarchical variants: the event's composed (I, I) device
        # matrix and the root's (I,) source weights are call arguments
        self._apply_event = jax.jit(self._apply_event_impl)
        self._global_from_weights = jax.jit(self._global_from_weights_impl)
        # the event-chunked hot loop: every local-SGD iteration between
        # two round-program events runs inside ONE scan dispatch
        self._scan_local = jax.jit(self._scan_local_impl)
        self._scan_local_dyn = jax.jit(self._scan_local_dyn_impl)
        # observability (repro.obs): probes/gauges built lazily on the
        # first instrumented run — read-only, so instrumented and
        # uninstrumented trajectories are bitwise-identical
        self._obs_probe = None
        self._obs_grad_probe = None
        self._obs_gauges = None

    # ------------------------------------------------------------------
    def init(self, seed: int = 0) -> TTHFState:
        key = jax.random.PRNGKey(seed)
        k0, key = jax.random.split(key)
        w0 = self.model.init(k0)
        self.model_dim = int(sum(np.prod(l.shape)
                                 for l in jax.tree.leaves(w0)))
        params = smp.broadcast_pytree(w0, self.data.num_devices)
        return TTHFState(params=params, global_params=w0, t=0, key=key)

    # ------------------------------------------------------------------
    # jitted pieces
    # ------------------------------------------------------------------
    def _local_step_impl(self, params, key, eta_t):
        """One vmapped SGD iteration (eqs. 8-9) for every device."""
        I, D = self.y.shape
        keys = jax.random.split(key, I)

        def dev_step(p, k, xd, yd):
            idx = jax.random.randint(k, (self.batch_size,), 0, D)
            xb, yb = xd[idx], yd[idx]
            g = jax.grad(self.model.loss)(p, xb, yb)
            return jax.tree.map(lambda w, gg: w - eta_t * gg, p, g)

        return jax.vmap(dev_step)(params, keys, self.x, self.y)

    def _consensus_impl(self, params, gamma):
        return mixing.mix_pytree(params, self.V, gamma,
                                 self.net.num_clusters,
                                 backend=self.backend)

    def _aggregate_impl(self, params, key, full: bool):
        if full:
            g = smp.full_global_pytree(params, self.varrho,
                                       self.net.num_clusters)
        elif self.algo.sample_per_cluster == 1:
            picks = smp.sample_devices(key, self.net.num_clusters,
                                       self.net.cluster_size)
            g = smp.sampled_global_pytree(params, picks, self.varrho,
                                          self.net.num_clusters)
        else:
            # k representatives without replacement, averaged (eq. 7
            # generalized) — the ledger's N * k uplinks are now real
            picks = smp.sample_devices_multi(key, self.net.num_clusters,
                                             self.net.cluster_size,
                                             self.algo.sample_per_cluster)
            g = smp.sampled_global_pytree(params, picks, self.varrho,
                                          self.net.num_clusters)
        return g, smp.broadcast_pytree(g, self.data.num_devices)

    def _eval_impl(self, global_params):
        """Global loss F(w_hat) (eq. 3) + accuracy over all local data."""
        def dev_loss(xd, yd):
            return self.model.loss(global_params, xd, yd)
        losses = jax.vmap(dev_loss)(self.x, self.y)
        loss = jnp.mean(losses)     # equal rho_{i,c}, varrho_c=s/I
        if self.eval_x is not None:
            acc = self.model.accuracy(global_params, self.eval_x,
                                      self.eval_y)
        else:
            flat_x = self.x.reshape(-1, self.x.shape[-1])
            flat_y = self.y.reshape(-1)
            acc = self.model.accuracy(global_params, flat_x, flat_y)
        return loss, acc

    def _upsilon_impl(self, params):
        """Definition-2 divergence per cluster, max over leaves."""
        ups = []
        for leaf in jax.tree.leaves(params):
            z = leaf.reshape(self.net.num_clusters, self.net.cluster_size, -1)
            ups.append(cns.divergence_upsilon(z))
        return jnp.max(jnp.stack(ups), axis=0)

    # ------------------------------------------------------------------
    # netsim (dynamic-mode) jitted pieces: the event's V / masks / agg
    # weights arrive as call arguments so one compilation serves every
    # event of a run
    # ------------------------------------------------------------------
    def _local_step_dyn_impl(self, params, key, eta_t, device_up_flat):
        """Local SGD with churn: a dropped device is offline — it takes
        no gradient step and simply holds its parameters."""
        stepped = self._local_step_impl(params, key, eta_t)

        def freeze(new, old):
            m = device_up_flat.reshape((-1,) + (1,) * (old.ndim - 1))
            return jnp.where(m, new, old)

        return jax.tree.map(freeze, stepped, params)

    # ------------------------------------------------------------------
    # event-chunked local spans: the resolver knows the next event
    # boundary ahead of time, so the n pure local-SGD iterations up to
    # it run as ONE lax.scan — one dispatch per event, not per
    # iteration. The scan body splits the PRNG key exactly as the
    # per-iteration loop did (and carries the boundary's k_agg out),
    # so trajectories are bit-for-bit identical (tests/test_rounds.py).
    # ------------------------------------------------------------------
    def _scan_local_impl(self, params, key, etas):
        def body(carry, eta):
            params, key, _ = carry
            key, k_step, k_agg = jax.random.split(key, 3)
            params = self._local_step_impl(params, k_step, eta)
            return (params, key, k_agg), None

        (params, key, k_agg), _ = jax.lax.scan(
            body, (params, key, key), etas)
        return params, key, k_agg

    def _scan_local_dyn_impl(self, params, key, etas, up_masks):
        def body(carry, x):
            eta, up_flat = x
            params, key, _ = carry
            key, k_step, k_agg = jax.random.split(key, 3)
            params = self._local_step_dyn_impl(params, k_step, eta,
                                               up_flat)
            return (params, key, k_agg), None

        (params, key, k_agg), _ = jax.lax.scan(
            body, (params, key, key), (etas, up_masks))
        return params, key, k_agg

    def _consensus_dyn_impl(self, params, V, gamma):
        return mixing.mix_pytree(params, V, gamma,
                                 self.net.num_clusters,
                                 backend=self.backend)

    def _aggregate_dyn_impl(self, params, weights, device_up_flat):
        """Availability-aware eq. (7): aggregate with per-device weights
        (netsim.faults builders) and broadcast only to devices that are
        up — offline devices cannot hear the server."""
        from repro.netsim.faults import weighted_global_pytree
        g = weighted_global_pytree(params, weights, self.net.num_clusters)
        bcast = smp.broadcast_pytree(g, self.data.num_devices)

        def receive(new, old):
            m = device_up_flat.reshape((-1,) + (1,) * (old.ndim - 1))
            return jnp.where(m, new, old)

        return g, jax.tree.map(receive, bcast, params)

    def _apply_event_impl(self, params, device_matrix):
        from repro.hierarchy.aggregate import apply_device_matrix_pytree
        return apply_device_matrix_pytree(params, device_matrix)

    def _global_from_weights_impl(self, params, gw):
        from repro.hierarchy.aggregate import global_from_weights
        return global_from_weights(params, gw)

    def _upsilon_dyn_impl(self, params, device_up):
        """Definition-2 divergence over ACTIVE devices, max over leaves."""
        ups = []
        for leaf in jax.tree.leaves(params):
            z = leaf.reshape(self.net.num_clusters, self.net.cluster_size, -1)
            ups.append(cns.masked_divergence_upsilon(z, device_up))
        return jnp.max(jnp.stack(ups), axis=0)

    # ------------------------------------------------------------------
    # round-program events — ONE home for the gamma schedule and the
    # aggregation operators across every scenario (DESIGN.md §10)
    # ------------------------------------------------------------------
    def _consensus_event(self, st, spec, eta_t) -> np.ndarray:
        """One consensus event from a resolved
        :class:`~repro.rounds.program.ConsensusSpec`; mutates
        st.params and returns the per-cluster rounds used. A static
        spec mixes on the base topology; a dynamic one mixes on the
        event's active subgraph — clusters with no live edge have
        nothing to exchange, so they neither run nor bill rounds
        (covers lambda=0 under the adaptive rule too)."""
        algo = self.algo
        if not spec.dynamic:
            if algo.gamma_d2d >= 0:
                gamma = fixed_gamma(self.net.num_clusters, algo.gamma_d2d)
            else:
                ups = self._upsilon(st.params)
                gamma = adaptive_gamma(eta_t, algo.phi, ups, self.lambdas,
                                       self.net.cluster_size,
                                       self.model_dim)
            st.params = self._consensus(st.params, gamma)
            return np.asarray(gamma)
        if algo.gamma_d2d >= 0:
            gamma = fixed_gamma(self.net.num_clusters, algo.gamma_d2d)
        else:
            ups = self._upsilon_dyn(st.params, jnp.asarray(spec.device_up))
            gamma = adaptive_gamma(
                eta_t, algo.phi, ups,
                jnp.asarray(spec.lambdas, jnp.float32),
                jnp.asarray(spec.active_sizes, jnp.int32),
                self.model_dim)
        gamma = jnp.where(jnp.asarray(spec.edges) == 0, 0, gamma)
        st.params = self._consensus_dyn(
            st.params, jnp.asarray(spec.V), gamma)
        return np.asarray(gamma)

    def _apply_aggregation(self, st, spec, k_agg) -> None:
        """Apply a resolved :class:`~repro.rounds.program.
        AggregationSpec` — the three operator forms every scenario
        reduces to (jit-sampled eq. (7), per-device weight matrix,
        composed hierarchy device matrix)."""
        if spec.kind == "static":
            g, st.params = self._aggregate(st.params, k_agg,
                                           full=spec.full)
            st.global_params = g
        elif spec.kind == "weights":
            g, st.params = self._aggregate_dyn(
                st.params, jnp.asarray(spec.weights, jnp.float32),
                jnp.asarray(spec.device_up.reshape(-1)))
            st.global_params = g
        else:                       # "matrix": the fog hierarchy
            if spec.global_weights is not None:
                st.global_params = self._global_from_weights(
                    st.params, jnp.asarray(spec.global_weights))
            st.params = self._apply_event(
                st.params, jnp.asarray(spec.device_matrix))

    def _dispersion(self, params):
        """A^(t) sample: sum_c varrho_c ||wbar_c - wbar||^2."""
        total = 0.0
        for leaf in jax.tree.leaves(params):
            z = leaf.reshape(self.net.num_clusters, self.net.cluster_size, -1)
            means = cns.cluster_means(z)
            gmean = jnp.einsum("c,cm->m", self.varrho.astype(z.dtype), means)
            total += jnp.sum(self.varrho *
                             jnp.sum((means - gmean) ** 2, axis=-1))
        return total

    def _consensus_error(self, params):
        total = 0.0
        for leaf in jax.tree.leaves(params):
            z = leaf.reshape(self.net.num_clusters, self.net.cluster_size, -1)
            total += jnp.sum(self.varrho * cns.consensus_error(z))
        return total

    def _local_span(self, st, t_from: int, t_to: int) -> tuple[Any, int]:
        """Run the pure local-SGD iterations t_from..t_to (inclusive)
        as one scanned dispatch; mutates st.params/st.key and returns
        (the boundary iteration's k_agg, device-steps taken). Under
        dynamics each iteration's snapshot supplies its device-up mask
        — dropped devices hold their parameters, exactly as the
        per-iteration loop did."""
        etas = jnp.stack([self.eta(u - 1)
                          for u in range(t_from, t_to + 1)])
        if self.tvnet is None:
            st.params, st.key, k_agg = self._scan_local(
                st.params, st.key, etas)
            return k_agg, self.data.num_devices * (t_to - t_from + 1)
        masks, live = [], 0
        for u in range(t_from, t_to + 1):
            snap = self.tvnet.snapshot(u)
            masks.append(snap.device_up.reshape(-1))
            live += int(snap.device_up.sum())
        st.params, st.key, k_agg = self._scan_local_dyn(
            st.params, st.key, etas, jnp.asarray(np.stack(masks)))
        return k_agg, live

    # ------------------------------------------------------------------
    # observability (DESIGN.md §13) — read-only probes + theory gauges
    # ------------------------------------------------------------------
    def _ensure_obs(self):
        from repro.obs.telemetry import (
            TheoryGauges, default_constants, make_divergence_probe,
            make_sim_grad_probe)

        if self._obs_probe is None:
            self._obs_probe = make_divergence_probe(
                self.net.num_clusters, self.net.cluster_size,
                self.net.varrho)
            self._obs_grad_probe = make_sim_grad_probe(
                self.model, self.x, self.y)
        if self._obs_gauges is None:
            algo = self.algo
            k = default_constants(float(np.min(self.net.varrho)))
            if algo.constant_lr > 0:
                self._obs_gauges = TheoryGauges(
                    constants=k, tau=algo.tau, model_dim=self.model_dim,
                    phi=algo.phi, lr=algo.constant_lr)
            else:
                self._obs_gauges = TheoryGauges(
                    constants=k, tau=algo.tau, model_dim=self.model_dim,
                    phi=algo.phi, gamma=algo.gamma, alpha=algo.alpha)

    def _upsilon_for(self, st, spec):
        """Pre-mixing Definition-2 divergence for a consensus event —
        the measured Υ_c that Lemma 1's bound takes as input."""
        if spec is not None and spec.dynamic:
            return np.asarray(self._upsilon_dyn(
                st.params, jnp.asarray(spec.device_up)))
        return np.asarray(self._upsilon(st.params))

    def _emit_round_telemetry(self, obs, st, b, ev, gamma_used, ups_pre,
                              eta_b, t_prev_agg, ledger_mark):
        """One fenced drain per round: block on the round's params,
        run the jitted probe, and emit the measured quantities, the
        theory-bound gauges, and the round's comms attribution into
        the shared JSONL stream (same ``step`` for all three)."""
        jax.block_until_ready(jax.tree.leaves(st.params)[0])
        aux = {k: np.asarray(v)
               for k, v in self._obs_probe(st.params).items()}
        rec = {"active_devices": ev.active_devices, "eta": float(eta_b),
               **aux}
        rec.update(self._obs_gauges.round_gauges(b, t_prev_agg))
        if ev.consensus is not None:
            spec = ev.consensus
            lambdas = (spec.lambdas if spec.dynamic
                       else self.net.lambdas)
            sizes = (spec.active_sizes if spec.dynamic
                     else self.net.cluster_size)
            rec["gamma_used"] = gamma_used
            rec["upsilon_pre"] = ups_pre
            rec["lemma1_bound"] = self._obs_gauges.lemma1(
                lambdas, gamma_used, sizes, ups_pre)
        obs.emit("round", b, **rec)
        rows = self.ledger.attribution_since(ledger_mark)
        if rows:
            up_lv, d2d_cl = {}, {}
            ups = msgs = rounds = 0
            for r in rows:
                if r["kind"] == "uplink":
                    ups += r["n"]
                    up_lv[r["level"]] = up_lv.get(r["level"], 0) + r["n"]
                elif r["kind"] == "consensus":
                    msgs += r["msgs"]
                    rounds += r["rounds"]
                    c = r["cluster"]
                    d2d_cl[c] = d2d_cl.get(c, 0) + r["msgs"]
            obs.emit("comm", b, uplinks=ups, uplinks_by_level=up_lv,
                     d2d_msgs=msgs, d2d_rounds=rounds,
                     d2d_msgs_by_cluster=d2d_cl,
                     event=self.ledger._event_idx)
        obs.counter("ledger", uplinks=self.ledger.uplinks,
                    d2d_msgs=self.ledger.d2d_msgs,
                    local_steps=self.ledger.local_steps)

    # ------------------------------------------------------------------
    def run(self, steps: int, seed: int = 0, eval_every: int = 5,
            state: TTHFState | None = None,
            record_dispersion: bool = True,
            obs=None) -> tuple[TTHFState, History]:
        """Drive Algorithm 1 — ONE loop for every scenario.

        The :class:`~repro.rounds.resolver.RoundResolver` owns the
        composition (static topology x optional netsim dynamics x
        optional fog hierarchy): per boundary iteration it emits the
        consensus spec, the aggregation operator, and the round's bill;
        this loop scans the local-SGD iterations up to each boundary in
        one jitted dispatch and applies the events. Offline devices
        freeze (no SGD, no mixing, no uplink, no broadcast); the served
        ``global_params`` updates when the (root) aggregation fires;
        the JAX key schedule and the host-side RNG seeding are exactly
        the historical ones, so static/dynamic/hierarchical
        trajectories are bit-for-bit those of the pre-engine loops.
        """
        assert eval_every >= 1, "eval_every must be a positive period"
        obs = obs if obs is not None else NULL_OBS
        st = state or self.init(seed)
        if obs.enabled:
            self._ensure_obs()      # model_dim is set by init()
        self._resolver.obs = obs
        hist = History()
        res = self._resolver
        N = self.net.num_clusters
        t_last = st.t + steps
        t_prev_agg = st.t           # Σ_t spans since the last aggregation
        t = st.t + 1
        with obs.span("run", mode="sim", steps=steps, t0=st.t):
            while t <= t_last:
                b = (res.span_end(t, t_last, eval_every) if self.chunked
                     else t)
                with obs.span("round", t=b):
                    with obs.span("interval", t_from=t, t_to=b):
                        k_agg, live = self._local_span(st, t, b)
                    self.ledger.record_local_step(live)

                    eta_b = self.eta(b - 1)
                    ev = res.resolve(b, k_agg)
                    ups_pre = None
                    if ev.consensus is not None and obs.enabled:
                        ups_pre = self._upsilon_for(st, ev.consensus)
                    gamma_used = np.zeros((N,), np.int32)
                    if ev.consensus is not None:
                        with obs.span("consensus_event", t=b):
                            gamma_used = self._consensus_event(
                                st, ev.consensus, eta_b)
                    if ev.aggregation is not None:
                        with obs.span("aggregation", t=b,
                                      kind=ev.aggregation.kind):
                            self._apply_aggregation(st, ev.aggregation,
                                                    k_agg)
                    ledger_mark = len(self.ledger.events)
                    ev.billing.charge(self.ledger, gamma_used)
                    if obs.enabled:
                        self._emit_round_telemetry(
                            obs, st, b, ev, gamma_used, ups_pre, eta_b,
                            t_prev_agg, ledger_mark)
                    if ev.aggregation is not None:
                        t_prev_agg = b

                    if b % eval_every == 0 or b == t_last:
                        loss, acc = self._eval(st.global_params)
                        hist.ts.append(b)
                        hist.global_loss.append(float(loss))
                        hist.global_acc.append(float(acc))
                        if record_dispersion:
                            hist.dispersion.append(
                                float(self._dispersion(st.params)))
                            hist.consensus_err.append(
                                float(self._consensus_error(st.params)))
                        hist.gamma_used.append(gamma_used.copy())
                        hist.uplinks.append(self.ledger.uplinks)
                        hist.d2d_msgs.append(self.ledger.d2d_msgs)
                        hist.active_devices.append(ev.active_devices)
                        if obs.enabled:
                            obs.emit(
                                "eval", b, loss=float(loss),
                                acc=float(acc),
                                grad_norm=float(self._obs_grad_probe(
                                    st.global_params)))
                t = b + 1

        st.t += steps
        obs.flush()
        return st, hist


def make_baseline_config(mode: str, tau: int) -> TTHFConfig:
    """Paper baselines: FL with full participation (tau=1 'centralized'
    upper bound, or tau=20 per [6])."""
    if mode == "centralized":
        return TTHFConfig(mode="centralized", tau=1, full_participation=True,
                          consensus_every=0, gamma_d2d=0)
    if mode == "fedavg":
        return TTHFConfig(mode="fedavg", tau=tau, full_participation=True,
                          consensus_every=0, gamma_d2d=0)
    raise ValueError(mode)
