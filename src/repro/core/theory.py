"""Convergence-theory calculators (Sec. III).

Implements, symbol-for-symbol, the quantities of Proposition 1 and
Theorem 2 so experiments can (a) check the tunable-parameter conditions
and (b) overlay the analytic bound nu/(t+alpha) on measured loss gaps.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ProblemConstants:
    """Loss-landscape and noise constants (Assumptions 1, 3, Def. 1)."""
    mu: float            # strong convexity of F
    beta: float          # smoothness of every F_i
    sigma: float         # SGD noise std bound
    delta: float         # gradient diversity bound
    varrho_min: float    # min_c varrho_c


def check_theorem2_conditions(k: ProblemConstants, gamma: float,
                              alpha: float) -> dict[str, bool]:
    """gamma > 1/mu and alpha >= gamma * beta^2 / mu (Thm 2)."""
    return {
        "gamma_gt_inv_mu": gamma > 1.0 / k.mu,
        "alpha_ge_gamma_beta2_over_mu": alpha >= gamma * k.beta ** 2 / k.mu,
        "eta0_le_mu_over_beta2": gamma / alpha <= k.mu / k.beta ** 2 + 1e-12,
    }


def sigma_t(k: ProblemConstants, gamma: float, alpha: float, tau: int,
            t: int, t_prev_agg: int) -> float:
    """Sigma_t = sum_{l=t_{k-1}}^{t-1} beta*eta_l prod_{j=l+1}^{t-1}
    (1 + 2 eta_j beta)   (Proposition 1)."""
    def eta(j):
        return gamma / (j + alpha)
    total = 0.0
    for ell in range(t_prev_agg, t):
        prod = 1.0
        for j in range(ell + 1, t):
            prod *= 1.0 + 2.0 * eta(j) * k.beta
        total += k.beta * eta(ell) * prod
    return total


def dispersion_bound(k: ProblemConstants, gamma: float, alpha: float,
                     tau: int, t: int, t_prev_agg: int,
                     eps0: float) -> float:
    """Proposition 1 RHS: bound on A^(t)."""
    s = sigma_t(k, gamma, alpha, tau, t, t_prev_agg)
    return (12.0 / k.varrho_min) * s ** 2 * (
        k.sigma ** 2 / k.beta ** 2 + k.delta ** 2 / k.beta ** 2 + eps0 ** 2)


def theorem2_Z(k: ProblemConstants, gamma: float, alpha: float, tau: int,
               phi: float) -> float:
    """Z from Theorem 2."""
    if tau <= 1:
        cluster_term = 0.0
    else:
        cluster_term = (
            24.0 / k.varrho_min * k.beta * gamma * (tau - 1)
            * (1.0 + (tau - 2) / alpha)
            * (1.0 + (tau - 1) / (alpha - 1.0)) ** (4.0 * k.beta * gamma)
            * (k.sigma ** 2 / k.beta + phi ** 2 / k.beta
               + k.delta ** 2 / k.beta))
    return 0.5 * (k.sigma ** 2 / k.beta + 2.0 * phi ** 2 / k.beta) \
        + cluster_term


def theorem2_nu(k: ProblemConstants, gamma: float, alpha: float, tau: int,
                phi: float, initial_gap: float) -> float:
    """nu = max{ beta^2 gamma^2 Z / (mu gamma - 1),
                 alpha * (F(w0) - F*) }   (Theorem 2)."""
    conds = check_theorem2_conditions(k, gamma, alpha)
    if not conds["gamma_gt_inv_mu"]:
        raise ValueError("Theorem 2 requires gamma > 1/mu")
    z = theorem2_Z(k, gamma, alpha, tau, phi)
    return max(k.beta ** 2 * gamma ** 2 * z / (k.mu * gamma - 1.0),
               alpha * initial_gap)


def bound_curve(nu: float, alpha: float, ts: np.ndarray) -> np.ndarray:
    """The O(1/t) envelope nu / (t + alpha)."""
    return nu / (np.asarray(ts, float) + alpha)


def lemma1_bound(lambda_c: float, gamma_rounds: int, s_c: int,
                 upsilon: float, model_dim: int) -> float:
    """Lemma 1: ||e_i|| <= lambda^Gamma * s_c * Upsilon * M."""
    return (lambda_c ** gamma_rounds) * s_c * upsilon * model_dim


# ---------------------------------------------------------------------------
# empirical estimators for the constants (used by experiments to
# instantiate the bound on real runs)
# ---------------------------------------------------------------------------

def estimate_gradient_diversity(cluster_grads: np.ndarray,
                                varrho: np.ndarray) -> float:
    """delta >= max_c || grad F_c - grad F ||, estimated at a set of
    iterates. cluster_grads: (T, N, M)."""
    g = np.asarray(cluster_grads)
    global_g = np.einsum("c,tcm->tm", varrho, g)
    dev = np.linalg.norm(g - global_g[:, None], axis=-1)
    return float(dev.max())


def estimate_sgd_noise(sample_grads: np.ndarray,
                       full_grad: np.ndarray) -> float:
    """sigma^2 >= E||ghat - gradF_i||^2 estimate from repeated draws."""
    d = sample_grads - full_grad[None]
    return float(np.sqrt((d * d).sum(-1).mean()))
