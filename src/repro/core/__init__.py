"""TT-HF core — the paper's contribution as a composable JAX module."""
from repro.core.topology import (
    Network, build_network, metropolis_weights, laplacian_weights,
    spectral_radius, check_assumption2, ring_adjacency,
    complete_adjacency, geometric_adjacency,
)
from repro.core.consensus import (
    mix, mix_once, mix_pytree, cluster_means, consensus_error,
    divergence_upsilon, masked_divergence_upsilon,
)
from repro.core.mixing import (
    BACKENDS, MixingPlan, build_mixing_plan, canonical_backend,
    masked_consensus_matrix, matrix_powers, refresh_matrices,
)
from repro.core.schedule import adaptive_gamma, fixed_gamma, make_lr_schedule
from repro.core.sampling import (
    sample_devices, sample_devices_multi, sampled_global_model,
    sampled_global_model_multi, sampled_global_pytree,
    full_global_pytree, broadcast_pytree,
)
from repro.core.theory import (
    ProblemConstants, check_theorem2_conditions, theorem2_Z, theorem2_nu,
    bound_curve, lemma1_bound, dispersion_bound,
)
from repro.core.energy import CommLedger, E_GLOB_J, DELTA_GLOB_S
from repro.core.tthf import TTHFTrainer, TTHFState, History, \
    make_baseline_config

__all__ = [
    "Network", "build_network", "metropolis_weights", "laplacian_weights",
    "spectral_radius", "check_assumption2", "ring_adjacency",
    "complete_adjacency", "geometric_adjacency",
    "mix", "mix_once", "mix_pytree", "cluster_means", "consensus_error",
    "divergence_upsilon", "masked_divergence_upsilon",
    "BACKENDS", "MixingPlan", "build_mixing_plan", "canonical_backend",
    "masked_consensus_matrix", "matrix_powers", "refresh_matrices",
    "adaptive_gamma", "fixed_gamma", "make_lr_schedule",
    "sample_devices", "sample_devices_multi", "sampled_global_model",
    "sampled_global_model_multi", "sampled_global_pytree",
    "full_global_pytree", "broadcast_pytree",
    "ProblemConstants", "check_theorem2_conditions", "theorem2_Z",
    "theorem2_nu", "bound_curve", "lemma1_bound", "dispersion_bound",
    "CommLedger", "E_GLOB_J", "DELTA_GLOB_S",
    "TTHFTrainer", "TTHFState", "History", "make_baseline_config",
]
