"""TT-HF *scale mode*: the paper's two-timescale sync as a first-class
distributed-training strategy for the model zoo (DESIGN.md §3-4).

Mapping:
  FL device  -> model replica  = one slice of the (pod, data) axes
                (each replica holds a full copy, tensor-sharded over
                 ``model``)
  cluster    -> a contiguous block of replicas (on the multi-pod mesh a
                cluster == a pod, so D2D = intra-pod ICI and global
                aggregation = cross-pod traffic — the paper's
                cheap-links/expensive-uplink dichotomy, verbatim)
  local SGD  -> tau microsteps with NO cross-replica collective
  D2D round  -> block-diagonal mixing einsum over the replica axis
  global agg -> cluster-sampled, varrho-weighted average + broadcast

One ``train_step`` call = one full aggregation interval T_k (Algorithm 1
lines 4-15): nested scans [blocks x consensus_every microsteps] keep the
consensus events static in the HLO (aperiodicity via the *fixed* event
calendar; the Remark-1 adaptive round count is a simulation-mode
feature — scale mode takes Gamma from config).

Consensus execution dispatches through the unified engine
(:mod:`repro.core.mixing`, DESIGN.md §5).  ``consensus_mode`` is a
backend name; the legacy aliases remain the §Perf comparison axis:
  * ``rounds`` (-> ``reference``) — paper-faithful: Gamma sequential
    ``z <- V z`` products, one neighbour exchange each (what edge
    devices must do);
  * ``fused``  (-> ``fused_power``) — beyond-paper: W = V^Gamma is
    precomputed ONCE at step-build time and applied as ONE mixing
    einsum; on a TPU mesh every cluster member is reachable, so Gamma
    exchanges collapse into one collective of the same payload.
    Identical math (associativity), ~Gamma x less launch + latency
    cost.  Per-cluster aperiodic Gamma_c vectors (Remark 1) are now
    supported in scale mode — each cluster's block of W gets its own
    power.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import TopologyConfig
from repro.core.mixing import MixingPlan, build_mixing_plan
from repro.core.topology import Network, build_network
from repro.dist.sharding import drop_hint_axes
from repro.hierarchy.aggregate import apply_device_matrix_pytree
from repro.models.registry import ModelApi
from repro.netsim.faults import weighted_global_pytree


@dataclass(frozen=True)
class TTHFScaleConfig:
    replicas: int = 16              # I (devices) = replica count
    cluster_size: int = 4           # s_c
    tau: int = 20                   # local interval length
    consensus_every: int = 5        # D2D event calendar
    gamma_d2d: int = 2              # rounds per event (static)
    consensus_mode: str = "fused"   # mixing backend (core/mixing.py):
                                    # fused|rounds aliases or reference|
                                    # masked_loop|pallas|fused_power
    lr: float = 1e-2
    sample_per_cluster: int = 1
    graph: str = "ring"             # TPU-native default
    granularity: str = "dp"         # dp (replica = data rank) | pod
    seed: int = 0

    @property
    def num_clusters(self) -> int:
        assert self.replicas % self.cluster_size == 0
        return self.replicas // self.cluster_size

    def network(self) -> Network:
        return build_network(TopologyConfig(
            num_devices=self.replicas, num_clusters=self.num_clusters,
            graph=self.graph, seed=self.seed))


# ---------------------------------------------------------------------------
# replica-axis consensus / aggregation (pjit-native: collectives emerge
# from the replica-axis sharding of the mixing einsum)
# ---------------------------------------------------------------------------

def consensus_event(params, net: Network, gamma, mode: str = "fused"):
    """One D2D consensus event over the replica axis.

    ``gamma`` may be a scalar or a per-cluster (N,) vector (Remark-1
    heterogeneous round counts); ``mode`` is a mixing backend name or
    one of the legacy aliases ("fused", "rounds").  Thin wrapper over
    :func:`repro.core.mixing.build_mixing_plan` — prefer building the
    plan once at step-build time (as ``make_tthf_train_step`` does)
    instead of calling this per event.
    """
    plan = build_mixing_plan(net, gamma, backend=mode)
    return plan.apply_pytree(params)


def sampled_aggregation(params, net: Network, picks: jax.Array):
    """eq. (7): w_hat = sum_c varrho_c w_{n_c}; broadcast to all replicas.

    The static-topology path. Under netsim dynamics the aggregation is
    :func:`weighted_aggregation` instead — availability-renormalized
    per-device weights rather than one pick per cluster."""
    varrho = jnp.asarray(net.varrho, jnp.float32)
    N, s = net.num_clusters, net.cluster_size

    def one(leaf):
        R = leaf.shape[0]
        z = leaf.reshape(N, s, -1)
        chosen = jnp.take_along_axis(
            z, picks[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        w_hat = jnp.einsum("c,cm->m", varrho.astype(leaf.dtype), chosen)
        return jnp.broadcast_to(w_hat[None], (R,) + w_hat.shape
                                ).reshape(leaf.shape)

    return jax.tree.map(one, params)


def weighted_aggregation(params, net: Network, weights: jax.Array):
    """Availability-aware eq. (7) over the replica axis.

    ``weights``: the (N, s) per-device aggregation weight matrix from
    :func:`repro.netsim.faults.aggregation_weights` — EVERY sampled
    replica enters the aggregate with its renormalized weight (the
    ledger's uplink count and the aggregate agree under
    ``sample_per_cluster > 1``), and a dark cluster's devices carry 0.
    The global model is broadcast to every replica (replicas are
    physical shards — scale-mode churn shapes the sync pattern, not the
    broadcast); an all-dark event (weights sum to 0) is the identity.
    """
    g = weighted_global_pytree(params, weights, net.num_clusters)
    alive = weights.sum() > 0

    def one(gl, pl):
        return jnp.where(alive, jnp.broadcast_to(gl[None], pl.shape), pl)

    return jax.tree.map(one, g, params)


def full_aggregation(params, net: Network):
    """Star/FedAvg baseline: full-participation weighted mean."""
    varrho = jnp.asarray(net.varrho, jnp.float32)
    N, s = net.num_clusters, net.cluster_size

    def one(leaf):
        R = leaf.shape[0]
        z = leaf.reshape(N, s, -1).mean(axis=1)
        w_hat = jnp.einsum("c,cm->m", varrho.astype(leaf.dtype), z)
        return jnp.broadcast_to(w_hat[None], (R,) + w_hat.shape
                                ).reshape(leaf.shape)

    return jax.tree.map(one, params)


# ---------------------------------------------------------------------------
# the flattened replica buffer of the fused interval (DESIGN.md §12)
# ---------------------------------------------------------------------------

LANE = 128      # TPU lane width — the flat buffer is lane-padded once


@dataclass(frozen=True)
class FlatParamSpec:
    """Layout of the lane-padded flat ``(R, P)`` replica buffer.

    The fused interval (``make_tthf_train_step(fused_interval=True)``)
    carries every replica's parameters as ONE ``(R, P)`` array: leaves
    packed back-to-back along P (per-replica layout — shapes here
    exclude the leading replica axis), P padded up to a lane multiple
    exactly once at build time. SGD updates and consensus mixing then
    run as single whole-buffer ops instead of per-leaf launches;
    :meth:`unflatten` is only needed at aggregation/eval boundaries and
    is a pure view (slice + reshape, no copy).

    Mixing/aggregation correctness under padding: every interval op is
    per-column linear over the replica axis, so the zero pad columns
    stay zero and real columns are untouched by the packing.
    """
    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    offsets: tuple[int, ...]
    sizes: tuple[int, ...]
    dtype: Any
    total: int          # packed length (sum of leaf sizes)
    padded: int         # lane-padded P

    @classmethod
    def for_tree(cls, tree) -> "FlatParamSpec":
        """Build from a per-replica pytree of arrays/ShapeDtypeStructs
        (leaf shapes WITHOUT the replica axis)."""
        leaves, treedef = jax.tree.flatten(tree)
        assert leaves, "empty parameter pytree"
        dtypes = {jnp.dtype(l.dtype) for l in leaves}
        assert len(dtypes) == 1, \
            f"flat buffer needs a uniform param dtype, got {dtypes}"
        shapes = tuple(tuple(int(d) for d in l.shape) for l in leaves)
        sizes = tuple(int(np.prod(s, dtype=np.int64)) for s in shapes)
        offsets = tuple(int(o) for o in
                        np.concatenate([[0], np.cumsum(sizes)[:-1]]))
        total = int(sum(sizes))
        padded = -(-total // LANE) * LANE
        return cls(treedef=treedef, shapes=shapes, offsets=offsets,
                   sizes=sizes, dtype=dtypes.pop(), total=total,
                   padded=padded)

    @classmethod
    def for_model(cls, model: ModelApi, dtype=jnp.float32) -> "FlatParamSpec":
        p_abs, _ = model.abstract_params(dtype=dtype)
        return cls.for_tree(p_abs)

    # -- conversions ----------------------------------------------------
    def flatten(self, tree) -> jax.Array:
        """Replicated pytree (leaves (R, *shape)) -> flat (R, P).

        Leaves are cast to the spec dtype (the reference microstep's
        ``g.astype(w.dtype)`` contract for gradient trees)."""
        leaves = jax.tree.flatten(tree)[0]
        R = leaves[0].shape[0]
        flat = jnp.concatenate(
            [l.astype(self.dtype).reshape(R, -1) for l in leaves], axis=1)
        if self.padded != self.total:
            flat = jnp.pad(flat, ((0, 0), (0, self.padded - self.total)))
        return flat

    def unflatten(self, flat: jax.Array):
        """Flat (R, P) -> replicated pytree (leaves (R, *shape))."""
        R = flat.shape[0]
        leaves = [flat[:, o:o + n].reshape((R,) + s)
                  for o, n, s in zip(self.offsets, self.sizes, self.shapes)]
        return jax.tree.unflatten(self.treedef, leaves)

    def unflatten_one(self, row: jax.Array):
        """One replica's row (P,) -> per-replica pytree (leaves shape)."""
        leaves = [row[o:o + n].reshape(s)
                  for o, n, s in zip(self.offsets, self.sizes, self.shapes)]
        return jax.tree.unflatten(self.treedef, leaves)

    def abstract(self, replicas: int) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct((replicas, self.padded), self.dtype)


# flat (R, P) counterparts of the pytree aggregations above — the same
# per-column linear maps, so fused-interval trajectories are bitwise the
# reference path's (asserted in tests/test_fused_interval.py)

def sampled_aggregation_flat(flat: jax.Array, net: Network,
                             picks: jax.Array) -> jax.Array:
    varrho = jnp.asarray(net.varrho, jnp.float32)
    N, s = net.num_clusters, net.cluster_size
    R, P = flat.shape
    z = flat.reshape(N, s, P)
    chosen = jnp.take_along_axis(
        z, picks[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    w_hat = jnp.einsum("c,cm->m", varrho.astype(flat.dtype), chosen)
    return jnp.broadcast_to(w_hat[None], (R, P))


def weighted_aggregation_flat(flat: jax.Array, net: Network,
                              weights: jax.Array) -> jax.Array:
    N, s = net.num_clusters, net.cluster_size
    R, P = flat.shape
    g = jnp.einsum("cs,csm->m", weights.astype(flat.dtype),
                   flat.reshape(N, s, P))
    alive = weights.sum() > 0
    return jnp.where(alive, jnp.broadcast_to(g[None], (R, P)), flat)


def full_aggregation_flat(flat: jax.Array, net: Network) -> jax.Array:
    varrho = jnp.asarray(net.varrho, jnp.float32)
    N, s = net.num_clusters, net.cluster_size
    R, P = flat.shape
    z = flat.reshape(N, s, P).mean(axis=1)
    w_hat = jnp.einsum("c,cm->m", varrho.astype(flat.dtype), z)
    return jnp.broadcast_to(w_hat[None], (R, P))


def apply_device_matrix_flat(flat: jax.Array, M: jax.Array) -> jax.Array:
    return jnp.einsum("ij,jm->im", M.astype(flat.dtype), flat,
                      preferred_element_type=flat.dtype)


# ---------------------------------------------------------------------------
# the TT-HF interval step
# ---------------------------------------------------------------------------

def make_tthf_train_step(model: ModelApi, scale: TTHFScaleConfig, *,
                         dtype=jnp.bfloat16, remat: bool = True,
                         sync: str = "tthf", refreshable: bool = False,
                         hierarchy=None, fused_interval: bool = False,
                         fused_kernel: Optional[bool] = None,
                         param_dtype=jnp.float32):
    """Returns step(params_R, batch, agg, step_idx, ...) -> (params_R, loss).

    params_R: every leaf has leading replica axis R.

    ``fused_interval=True`` builds the flat-buffer variant (DESIGN.md
    §12): the step carries parameters as ONE lane-padded ``(R, P)``
    array (:class:`FlatParamSpec`; the returned ``step`` exposes it as
    ``step.spec``), SGD updates and consensus mixing run as whole-buffer
    ops instead of per-leaf launches, and each consensus block's last
    SGD update fuses with the ``W = V^Gamma`` mixing product — one
    read-w/read-g/write-mixed-w parameter-stream pass
    (:mod:`repro.kernels.fused_consensus_sgd`) instead of two.
    Trajectories are BITWISE the reference path's in f32
    (``tests/test_fused_interval.py``). ``fused_kernel`` forces the
    Pallas kernel on/off for that fused block-end (None = auto: kernel
    on real TPUs, the identical-math XLA einsum off-TPU);
    ``param_dtype`` fixes the buffer dtype. Only the ``fused_power``
    ("fused") consensus backend fuses; other backends keep their exact
    per-event semantics on the flat buffer.
    batch: {"tokens": (tau, R, b, T), "labels": ...} — one aggregation
    interval's worth of microbatches.
    sync: "tthf" (Algorithm 1) | "star" (FedAvg: full participation,
    no D2D) | "local" (no sync at all — diagnostics).

    The aggregation argument ``agg`` depends on the mode — one fixed
    form per build, so each step traces exactly once:

    * default — ``picks``: (N,) int32 sampled representative per
      cluster (the historical signature, bit-for-bit preserved);
    * ``sample_per_cluster > 1`` or ``refreshable=True`` — ``agg_w``:
      the (N, s) per-device aggregation weight matrix from
      :func:`repro.netsim.faults.aggregation_weights`. All k sampled
      replicas per cluster enter the aggregate (the multi-sampling
      the ledger bills — the static path used to draw ONE device and
      bill N uplinks), dark clusters carry weight 0, and an all-dark
      event is the identity. ``refreshable=True`` (netsim dynamics)
      additionally takes ``mix_refresh``, the
      per-aggregation-round consensus matrices from
      :func:`repro.core.mixing.refresh_matrices` (stacked powers
      ``W = V^Gamma`` for the ``fused`` backend, the masked ``V``
      otherwise) — churned replicas hold their parameters through
      every consensus event of the interval;
    * ``hierarchy`` (a non-flat :class:`~repro.configs.base.
      HierarchyConfig`) — ``agg_m``: the composed (R, R) device matrix
      of a :class:`~repro.hierarchy.aggregate.HierarchyEvent`. Its
      fixed shape encodes ANY aggregation depth (hold-rows included),
      so one compilation serves every interval of an L-level run; the
      per-level weight matrices change per call, never the HLO. A flat
      (L = 2) hierarchy config is exactly TT-HF and takes the
      historical ``picks`` path. Composes with ``refreshable``
      (``mix_refresh`` stays the last argument).
    """
    net = scale.network()
    if hierarchy is not None and hierarchy.is_flat:
        hierarchy = None            # plain TT-HF: the historical path
    if hierarchy is not None:
        assert sync == "tthf", "hierarchical aggregation implies tthf sync"
        assert hierarchy.taus[0] == scale.tau, \
            f"tier-1 period {hierarchy.taus[0]} must equal the " \
            f"interval length tau={scale.tau}"
        assert hierarchy.sample[0] == scale.sample_per_cluster, \
            f"tier-1 fan-in {hierarchy.sample[0]} must equal " \
            f"sample_per_cluster={scale.sample_per_cluster}"
    assert scale.tau % scale.consensus_every == 0
    n_blocks = scale.tau // scale.consensus_every
    # one build-time plan: for fused_power this precomputes W = V^Gamma
    # exactly once (numpy) instead of re-deriving it inside the step
    plan: MixingPlan | None = None
    if sync == "tthf":
        plan = build_mixing_plan(net, scale.gamma_d2d,
                                 backend=scale.consensus_mode)

    # which mesh axes carry replicas: dp granularity -> (pod, data);
    # pod granularity (giant models: a replica needs a whole pod's HBM,
    # FSDP over `data` stays *inside* the replica) -> (pod,)
    replica_axes = (("pod",) if scale.granularity == "pod"
                    else ("pod", "data"))

    def replica_loss(p, mb):
        # the replica axes are carried by the vmap dim; model/data
        # hints still apply inside each replica
        with drop_hint_axes(replica_axes):
            return model.loss(p, mb, dtype=dtype, remat=remat)

    def microstep(params, mb, lr):
        """vmapped per-replica SGD (eq. 8-9) — zero cross-replica comms."""
        losses, grads = jax.vmap(
            lambda p, m: jax.value_and_grad(replica_loss)(p, m))(params, mb)
        # lr cast per-leaf: an f32 scalar would promote bf16 params
        params = jax.tree.map(
            lambda w, g: w - jnp.asarray(lr, w.dtype) * g.astype(w.dtype),
            params, grads)
        return params, jnp.mean(losses)

    # one aggregation form per build — the jitted step traces exactly
    # once; multi-sampling routes through the (N, s) weight form so
    # every billed uplink actually enters the aggregate
    agg_kind = ("matrix" if hierarchy is not None
                else "weights" if (refreshable or
                                   scale.sample_per_cluster > 1)
                else "picks")

    if fused_interval:
        return _make_fused_interval_step(
            model, scale, net=net, plan=plan, sync=sync,
            refreshable=refreshable, agg_kind=agg_kind,
            n_blocks=n_blocks, replica_loss=replica_loss,
            fused_kernel=fused_kernel, param_dtype=param_dtype)

    def interval(params, batch, agg, mix_refresh):
        lr = jnp.asarray(scale.lr, jnp.float32)
        # (tau, R, b, T) -> (blocks, consensus_every, R, b, T)
        def resh(x):
            return x.reshape((n_blocks, scale.consensus_every) + x.shape[1:])
        batch_b = jax.tree.map(resh, batch)

        def block(params, block_batch):
            def inner(params, mb):
                params, loss = microstep(params, mb, lr)
                return params, loss
            params, losses = jax.lax.scan(inner, params, block_batch)
            if plan is not None:
                params = plan.apply_pytree(params, refresh=mix_refresh)
            return params, jnp.mean(losses)

        params, block_losses = jax.lax.scan(block, params, batch_b)
        if sync == "tthf":
            if agg_kind == "picks":
                params = sampled_aggregation(params, net, agg)
            elif agg_kind == "weights":
                params = weighted_aggregation(params, net, agg)
            else:
                params = apply_device_matrix_pytree(params, agg)
        elif sync == "star":
            params = full_aggregation(params, net)
        return params, jnp.mean(block_losses)

    if refreshable:
        def step(params, batch, agg, step_idx, mix_refresh):
            return interval(params, batch, agg, mix_refresh)
    else:
        def step(params, batch, agg, step_idx):
            return interval(params, batch, agg, None)

    return step, net


def _make_fused_interval_step(model: ModelApi, scale: TTHFScaleConfig, *,
                              net: Network, plan: Optional[MixingPlan],
                              sync: str, refreshable: bool, agg_kind: str,
                              n_blocks: int, replica_loss,
                              fused_kernel: Optional[bool],
                              param_dtype) -> tuple[Any, Network]:
    """The ``fused_interval=True`` build — see ``make_tthf_train_step``.

    Arithmetic mirrors the reference interval exactly: grads come from
    the identical unflattened tree, the SGD update is the same
    elementwise expression on the concatenated buffer, and every
    mixing/aggregation einsum is per-column identical to its per-leaf
    counterpart — so fused and reference trajectories are bitwise equal
    in f32 (asserted in tests and in ``benchmarks/scale_sync.py``).
    """
    spec = FlatParamSpec.for_model(model, dtype=param_dtype)
    N, s = net.num_clusters, net.cluster_size
    if fused_kernel is None:
        from repro.kernels.runtime import default_interpret
        # auto: Mosaic kernel on real TPUs; off-TPU the XLA einsum below
        # IS the fused pass after fusion, and skipping pallas interpret
        # overhead keeps the CPU path fast
        fused_kernel = not default_interpret()
    if fused_kernel:
        from repro.kernels.fused_consensus_sgd import (
            fused_consensus_sgd as _fused_kernel_fn)

    def grad_flat(flat, mb):
        """Mean loss + flat (R, P) grads; pad columns stay zero."""
        losses, grads = jax.vmap(
            lambda p, m: jax.value_and_grad(replica_loss)(p, m)
        )(spec.unflatten(flat), mb)
        return spec.flatten(grads), jnp.mean(losses)

    def interval(flat, batch, agg, mix_refresh):
        lr = jnp.asarray(scale.lr, jnp.float32)
        mix_active = plan is not None and not (plan.is_noop and
                                               mix_refresh is None)

        def resh(x):
            return x.reshape((n_blocks, scale.consensus_every) + x.shape[1:])
        batch_b = jax.tree.map(resh, batch)

        def sgd(flat, mb):
            """One microstep on the flat carrier — bitwise-critical.

            The update runs in the PYTREE domain and the updated tree
            reflattens (a concat XLA fuses into the update writes, so
            the carry stays one buffer with no extra HBM pass).
            Updating the flat buffer directly against flattened GRADS
            instead fuses the concat into the grad epilogue and
            re-vectorizes it — a 1-ulp drift vs the reference step on
            non-lane-aligned models.
            """
            params = spec.unflatten(flat)
            losses, grads = jax.vmap(
                lambda p, m: jax.value_and_grad(replica_loss)(p, m)
            )(params, mb)
            params = jax.tree.map(
                lambda w, g: w - jnp.asarray(lr, w.dtype)
                * g.astype(w.dtype), params, grads)
            return spec.flatten(params), jnp.mean(losses)

        # W available => the block-end collapses to ONE matrix product
        W0 = plan.fused_w(mix_refresh) if mix_active else None
        kernel_end = fused_kernel and mix_active and W0 is not None

        def block(flat, block_batch):
            if kernel_end:
                # Pallas path: the LAST microstep's SGD update fuses
                # with the mixing product — one read-w/read-g/
                # write-mixed-w HBM pass (repro.kernels.
                # fused_consensus_sgd). The inline last-step grad can
                # re-vectorize vs the in-scan instance, so this path
                # carries the kernel tolerance contract, not the
                # bitwise one (it is auto-selected on TPUs only).
                head = jax.tree.map(lambda x: x[:-1], block_batch)
                last = jax.tree.map(lambda x: x[-1], block_batch)
                flat, head_losses = jax.lax.scan(sgd, flat, head)
                g, last_loss = grad_flat(flat, last)
                flat = _fused_kernel_fn(
                    flat.reshape(N, s, -1), g.reshape(N, s, -1),
                    W0, lr).reshape(flat.shape)
                losses = jnp.concatenate([head_losses, last_loss[None]])
                return flat, jnp.mean(losses)
            # XLA path — bitwise contract: the microstep scan matches
            # the reference structure exactly (splitting the last step
            # out of the scan compiles its grad graph in a different
            # fusion context — a 1-ulp drift on non-lane-aligned
            # models), then the block-end applies as ONE whole-buffer
            # op instead of per-leaf launches
            flat, losses = jax.lax.scan(sgd, flat, block_batch)
            if mix_active:
                if W0 is not None:
                    flat = jnp.einsum(
                        "nij,njm->nim", W0.astype(flat.dtype),
                        flat.reshape(N, s, -1),
                        preferred_element_type=flat.dtype
                    ).reshape(flat.shape)
                else:
                    # non-fused_power backend: exact per-event
                    # semantics on the flat buffer
                    flat = plan.apply(flat.reshape(N, s, -1),
                                      refresh=mix_refresh
                                      ).reshape(flat.shape)
            return flat, jnp.mean(losses)

        flat, block_losses = jax.lax.scan(block, flat, batch_b)
        if sync == "tthf":
            if agg_kind == "picks":
                flat = sampled_aggregation_flat(flat, net, agg)
            elif agg_kind == "weights":
                flat = weighted_aggregation_flat(flat, net, agg)
            else:
                flat = apply_device_matrix_flat(flat, agg)
        elif sync == "star":
            flat = full_aggregation_flat(flat, net)
        return flat, jnp.mean(block_losses)

    if refreshable:
        def step(flat, batch, agg, step_idx, mix_refresh):
            return interval(flat, batch, agg, mix_refresh)
    else:
        def step(flat, batch, agg, step_idx):
            return interval(flat, batch, agg, None)

    step.spec = spec
    return step, net


# ---------------------------------------------------------------------------
# sharding plumbing
# ---------------------------------------------------------------------------

def replica_axes_tree(axes_tree):
    """Prefix every logical-axes tuple with the replica axis."""
    return jax.tree.map(lambda a: ("replica",) + tuple(a), axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


TTHF_PARAM_RULES = (
    ("replica", ("pod", "data")),
    # within-replica: tensor parallel over model ONLY (a replica must be
    # self-contained — no fsdp over the replica axes)
    ("embed", None),
    ("embed_nomodel", None),
    ("embed_fsdp", None),
    ("vocab", "model"),
    ("q_proj", "model"),
    ("kv_proj", "model"),
    ("ffn", "model"),
    ("experts", "model"),
    ("expert_ffn", None),
    ("experts_router", None),
    ("ssm_in", "model"),
    ("ssm_heads", "model"),
    ("ssm_state", None),
    ("rnn_width", "model"),
    ("rnn_width_in", None),
    ("conv_k", None),
    ("layers", None),
    ("batch", None),
)


def tthf_shardings(model: ModelApi, scale: TTHFScaleConfig, mesh: Mesh,
                   param_dtype=jnp.float32):
    """(abstract replicated params, NamedSharding tree, batch sharding).

    granularity == "pod": the replica axis maps to `pod` only and each
    replica FSDP-shards its weights over `data` — this is how the 400B
    MoE holds divergent TT-HF copies (a 16-chip replica cannot).
    """
    from repro.dist.sharding import ShardingRules
    table = dict(TTHF_PARAM_RULES)
    if scale.granularity == "pod":
        table.update(replica=("pod",), embed=("data",),
                     embed_fsdp=("data",), rnn_width_in=("data",),
                     batch="data")
    rules = ShardingRules(tuple(table.items()))
    p_abs, axes = model.abstract_params(dtype=param_dtype)
    R = scale.replicas
    p_abs_R = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((R,) + s.shape, s.dtype), p_abs)
    axes_R = replica_axes_tree(axes)
    sh = jax.tree.map(
        lambda a: NamedSharding(mesh, rules.spec(tuple(a), mesh)),
        axes_R, is_leaf=lambda x: isinstance(x, tuple))
    # batch (tau, R, b, T): replica dim on the replica axes; per-replica
    # batch on `data` at pod granularity (the table already encodes
    # both — and rules.spec drops axes the mesh lacks, so the same
    # table serves the single-pod (data, model) mesh)
    batch_spec = rules.spec((None, "replica", "batch", None), mesh)
    return p_abs_R, sh, NamedSharding(mesh, batch_spec)


def stack_replicas(params, replicas: int):
    """w_i^(0) = w_hat^(0): identical initial copies (server broadcast)."""
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (replicas,) + l.shape), params)
