"""starcoder2-3b [dense]: GQA, RoPE. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    kind="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12_288,
    vocab_size=49_152,
    mlp_variant="gelu",       # starcoder2 uses a plain GELU MLP
    rope=True,
    norm="layernorm",
    qkv_bias=True,            # starcoder2 keeps biases
    tie_embeddings=True,
    sliding_window=4096,      # starcoder2-3b ships with SWA-4096
    source="arXiv:2402.19173",
)
