"""llama4-maverick-400b-a17b [moe]: 128 experts top-1, MoE every other
layer (interleaved, per the Llama-4 arch), early-fusion text backbone.
[hf:meta-llama/Llama-4-Scout-17B-16E]

Total ~400B params (24 MoE layers x 128 experts x 3*d*d_ff ~ 386B + dense),
~17B active per token with top-1 routing.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    kind="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    mlp_variant="swiglu",
    rope=True,
    norm="rmsnorm",
    tie_embeddings=False,
    moe_num_experts=128,
    moe_top_k=1,
    moe_every=2,              # interleaved MoE (every other layer)
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
