"""granite-3-8b [dense]: GQA. [hf:ibm-granite/granite-3.0-2b-base]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    kind="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12_800,
    vocab_size=49_155,
    mlp_variant="swiglu",
    rope=True,
    norm="rmsnorm",
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base",
)
