"""Configuration system for the repro framework.

Two families of configs:

* :class:`ModelConfig` — architecture hyperparameters for the model zoo.
  One instance per assigned architecture lives in ``repro/configs/<id>.py``.
* :class:`TTHFConfig` — the paper's algorithm knobs (tau, Gamma schedule,
  consensus topology, step-size schedule, cluster sampling).

Configs are plain frozen dataclasses: hashable (usable as jit static
args), serializable, and composable.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Model zoo configs
# ---------------------------------------------------------------------------

ARCH_KINDS = (
    "dense",      # decoder-only dense transformer
    "moe",        # decoder-only with MoE FFN layers
    "ssm",        # attention-free state space model (Mamba-2 / SSD)
    "hybrid",     # RG-LRU recurrent blocks + local attention (RecurrentGemma)
    "encdec",     # encoder-decoder (Whisper)
    "vlm",        # vision-language: stub vision frontend + dense decoder
    "audio",      # audio: stub conv frontend + encoder-decoder backbone
)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters.

    Field conventions follow the assignment sheet: ``num_layers`` L,
    ``d_model``, ``num_heads`` H (query heads), ``num_kv_heads`` (GQA;
    1 = MQA), ``d_ff``, ``vocab_size``.
    """

    name: str
    kind: str                       # one of ARCH_KINDS
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # --- MLP / activation ---
    mlp_variant: str = "swiglu"     # swiglu | geglu | gelu
    # --- attention details ---
    rope: bool = True
    rope_theta: float = 10_000.0
    qkv_bias: bool = False          # Qwen1.5 style
    sliding_window: int = 0         # 0 = full attention; >0 = SWA width
    local_attn_every: int = 0       # hybrid: attention layer period (RG)
    logit_softcap: float = 0.0      # gemma-style final softcap (0 = off)
    # --- norm / embedding ---
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    tie_embeddings: bool = True
    scale_embed: bool = False       # gemma multiplies embeds by sqrt(d)
    # --- MoE ---
    moe_num_experts: int = 0        # 0 = dense FFN
    moe_top_k: int = 1
    moe_every: int = 1              # MoE FFN on every k-th layer
    moe_aux_loss_weight: float = 0.01
    moe_capacity_factor: float = 1.25
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state_dim: int = 0
    ssm_num_heads: int = 0          # SSD heads (v-heads)
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    # --- hybrid (RG-LRU) ---
    rglru_width: int = 0            # recurrent block width (RG: d_model)
    rglru_conv_width: int = 4
    attention_window: int = 2048    # local attention window for hybrid
    # --- encoder (enc-dec / vlm / audio) ---
    enc_num_layers: int = 0
    enc_seq_len: int = 0            # fixed encoder context (1500 whisper,
                                    # 256 paligemma patches)
    enc_is_stub: bool = True        # frontend provides embeddings directly
    cross_attention: bool = False
    # --- decode limits ---
    max_seq_len: int = 1_048_576
    # citation for the config (paper / model card)
    source: str = ""

    def __post_init__(self):
        assert self.kind in ARCH_KINDS, f"unknown kind {self.kind}"
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived quantities -------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the vocab dim shards
        evenly over the 16-way model axis (padded ids are never targets)."""
        return ((self.vocab_size + 255) // 256) * 256

    def param_count(self) -> int:
        """Approximate parameter count (used for roofline MODEL_FLOPS)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        emb = v * d if self.tie_embeddings else 2 * v * d
        n = emb
        kd = self.head_dim * self.num_kv_heads
        qd = self.head_dim * self.num_heads
        attn = d * qd + 2 * d * kd + qd * d
        gates = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
        for layer in range(L):
            if self.kind == "ssm":
                din = self.ssm_expand * d
                n += d * (2 * din + 2 * self.ssm_num_heads * self.ssm_state_dim
                          + self.ssm_num_heads) + din * d
                continue
            if self.kind == "hybrid" and not self._is_attn_layer(layer):
                w = self.rglru_width or d
                n += d * w * 2 + w * w + 2 * w + w * d  # in-proj, gates, out
            else:
                n += attn
            if self.moe_num_experts and (layer % self.moe_every == self.moe_every - 1):
                n += self.moe_num_experts * gates * d * f + d * self.moe_num_experts
            else:
                n += gates * d * f
        if self.enc_num_layers and not self.enc_is_stub:
            n += self.enc_num_layers * (attn + gates * d * f)
        elif self.enc_num_layers:
            # stub frontend: encoder layers still counted (backbone spec)
            n += self.enc_num_layers * (attn + gates * d * f)
        return n

    def active_param_count(self) -> int:
        """Params active per token (MoE: top_k experts only)."""
        if not self.moe_num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        gates = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
        n_moe_layers = len([l for l in range(self.num_layers)
                            if l % self.moe_every == self.moe_every - 1])
        dense_equiv = self.param_count() - n_moe_layers * (
            self.moe_num_experts * gates * d * f + d * self.moe_num_experts)
        return dense_equiv + n_moe_layers * self.moe_top_k * gates * d * f

    def _is_attn_layer(self, layer: int) -> bool:
        """Hybrid models: which layers are (local) attention layers."""
        if self.kind != "hybrid":
            return True
        p = self.local_attn_every or 3
        return layer % p == p - 1  # RG: 2 recurrent : 1 attention

    def reduced(self, *, num_layers: int = 2, d_model: int = 256,
                d_ff: int = 512, vocab_size: int = 512,
                num_experts: int = 4) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        heads = max(2, min(4, self.num_heads))
        kv = max(1, min(self.num_kv_heads, heads))
        hd = max(32, d_model // heads)
        changes = dict(
            num_layers=num_layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=d_ff,
            vocab_size=vocab_size,
            max_seq_len=4096,
        )
        if self.moe_num_experts:
            changes["moe_num_experts"] = min(num_experts, 4)
        if self.kind == "ssm":
            d_in = self.ssm_expand * d_model
            changes.update(ssm_state_dim=32, ssm_head_dim=32,
                           ssm_num_heads=d_in // 32, ssm_chunk=32,
                           num_heads=0, num_kv_heads=0, head_dim=0)
        if self.kind == "hybrid":
            # 3 layers = one full (rec, rec, local-attn) group
            changes.update(rglru_width=d_model, attention_window=128,
                           num_layers=max(num_layers, 3))
        if self.enc_num_layers:
            changes.update(enc_num_layers=2, enc_seq_len=16)
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# TT-HF algorithm config (the paper's knobs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TopologyConfig:
    """Cluster/D2D topology (Sec. II-A)."""
    num_devices: int = 125          # I
    num_clusters: int = 25          # N
    graph: str = "geometric"        # geometric | ring | complete
    target_spectral_radius: float = 0.7   # rho(V - 11^T/s) tuning target
    weights: str = "metropolis"     # metropolis | laplacian
    seed: int = 0

    @property
    def cluster_size(self) -> int:
        assert self.num_devices % self.num_clusters == 0
        return self.num_devices // self.num_clusters


@dataclass(frozen=True)
class DynamicsConfig:
    """Time-varying network dynamics (``repro.netsim``).

    Describes the event processes the :class:`~repro.netsim.events.
    EventStream` draws at each iteration t:

    * per-edge 2-state Markov chains over the BASE D2D edges
      (``p_link_fail`` = P(up -> down), ``p_link_recover`` =
      P(down -> up), applied once per iteration);
    * per-device churn Markov chains (``p_device_drop`` /
      ``p_device_return``) — a dropped device neither trains, mixes,
      uploads, nor receives broadcasts: it *holds* its parameters;
    * stragglers: a fixed ``straggler_frac`` of devices drawn at
      stream construction; each consensus/uplink involving one pays a
      lognormal tail-delay multiplier ``1 + LogNormal(mu, sigma)``;
    * flash crowd: a deterministic mass departure — ``flash_drop_frac``
      of devices dark for ``t in [flash_at, flash_at+flash_duration)``.

    The all-defaults config is *static* (every process degenerate) and
    the trainers take the exact pre-netsim code path for it, so
    ``static`` trajectories are bit-for-bit the historical ones.
    """
    name: str = "static"
    # link dynamics (per base edge, per iteration)
    p_link_fail: float = 0.0
    p_link_recover: float = 1.0
    # device churn (per device, per iteration)
    p_device_drop: float = 0.0
    p_device_return: float = 1.0
    # stragglers
    straggler_frac: float = 0.0
    straggler_mu: float = 0.0        # lognormal location of the tail
    straggler_sigma: float = 1.0     # lognormal scale of the tail
    # flash crowd (deterministic window)
    flash_at: int = 0
    flash_duration: int = 0
    flash_drop_frac: float = 0.0
    seed: int = 0

    @property
    def is_static(self) -> bool:
        """True iff no event process can ever fire."""
        return (self.p_link_fail == 0.0 and self.p_device_drop == 0.0
                and self.straggler_frac == 0.0
                and (self.flash_duration == 0 or self.flash_drop_frac == 0.0))


@dataclass(frozen=True)
class HierarchyConfig:
    """L-level aggregation tree (``repro.hierarchy``, DESIGN.md §9).

    TT-HF's two timescales are the L = 2 special case of a multi-stage
    D2D-enabled fog hierarchy (Hosseinalipour et al. 2020): level 0 is
    the per-cluster D2D consensus tier (unchanged — ``core/mixing.py``),
    levels 1..L-1 are parent-node aggregations over child subtrees, and
    level L-1 is the root (the global model). Each aggregation tier
    l = 1..L-1 has its own period ``taus[l-1]`` and sampling fan-in
    ``sample[l-1]``:

    * tier 1 aggregates clusters — ``sample[0]`` is the paper's
      ``sample_per_cluster`` (devices drawn per cluster, eq. 7);
    * tier l >= 2 aggregates level-(l-1) nodes — ``sample[l-1]``
      children are drawn per parent (0 = full participation);
    * periods nest: ``taus[l-1]`` divides ``taus[l]``, so a deeper
      aggregation always composes with the shallower ones below it.

    ``branching[l-1]`` gives the children per level-l parent for the
    intermediate tiers l = 1..L-2 (the root absorbs every remaining
    node); an empty tuple asks :func:`repro.hierarchy.tree.build_tree`
    to balance the fan-ins automatically. The L = 2 config
    (``is_flat``) is today's TT-HF and the trainers route it through
    the historical code path — bit-for-bit identical trajectories.
    """
    levels: int = 2
    branching: Tuple[int, ...] = ()
    taus: Tuple[int, ...] = (20,)
    sample: Tuple[int, ...] = (1,)
    weights: str = "mass"           # child weights: subtree device mass

    def __post_init__(self):
        assert self.levels >= 2, "a hierarchy needs at least root+clusters"
        tiers = self.levels - 1
        assert len(self.taus) == tiers, \
            f"need one tau per aggregation tier: {tiers}, got {self.taus}"
        assert len(self.sample) == tiers, \
            f"need one fan-in per aggregation tier: {tiers}, " \
            f"got {self.sample}"
        assert len(self.branching) in (0, max(self.levels - 2, 0)), \
            "branching must be empty (auto) or cover every " \
            "intermediate tier (the root absorbs the rest)"
        assert all(t >= 1 for t in self.taus)
        assert all(k >= 0 for k in self.sample)
        assert self.sample[0] >= 1, "tier 1 must sample >= 1 device"
        for lo, hi in zip(self.taus, self.taus[1:]):
            assert hi % lo == 0, \
                f"tier periods must nest (each divides the next): {self.taus}"
        assert self.weights in ("mass",), f"unknown weights {self.weights!r}"

    @property
    def is_flat(self) -> bool:
        """True iff this is plain two-timescale TT-HF (no fog tiers)."""
        return self.levels == 2


@dataclass(frozen=True)
class TTHFConfig:
    """Algorithm 1 knobs + schedules (Sec. II-C, III)."""
    tau: int = 20                   # local model training interval length
    # step size eta_t = gamma / (t + alpha)
    gamma: float = 1.0
    alpha: float = 1.0
    constant_lr: float = 0.0        # >0 overrides the decaying schedule
    # D2D consensus schedule
    consensus_every: int = 5        # run consensus each k-th local step
    gamma_d2d: int = 2              # fixed Gamma (rounds per event); -1 = adaptive
    phi: float = 1.0                # target eps^(t) = eta_t * phi (Remark 1)
    # cluster sampling
    sample_per_cluster: int = 1
    # baseline switches
    mode: str = "tthf"              # tthf | fedavg (star) | centralized
    full_participation: bool = False
    seed: int = 0

    def is_aggregation_step(self, t: int) -> bool:
        return t > 0 and t % self.tau == 0

    def is_consensus_step(self, t: int) -> bool:
        if self.mode != "tthf":
            return False
        return self.consensus_every > 0 and t % self.consensus_every == 0


@dataclass(frozen=True)
class TrainConfig:
    """Scale-mode training-loop config."""
    global_batch: int = 256
    seq_len: int = 4096
    steps: int = 100
    learning_rate: float = 3e-3
    warmup: int = 0
    optimizer: str = "sgd"          # sgd | momentum | adamw
    momentum: float = 0.9
    weight_decay: float = 0.0
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    # TT-HF scale mode
    sync: str = "star"              # star | tthf
    tthf: TTHFConfig = field(default_factory=TTHFConfig)
    clusters_of_replicas: int = 4   # N in scale mode
    seed: int = 0


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    phase: str                      # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
