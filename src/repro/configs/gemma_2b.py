"""gemma-2b [dense]: GeGLU, head_dim=256, MQA. [arXiv:2403.08295]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    kind="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,           # MQA
    head_dim=256,
    d_ff=16_384,
    vocab_size=256_000,
    mlp_variant="geglu",
    rope=True,
    norm="rmsnorm",
    scale_embed=True,         # gemma scales embeddings by sqrt(d_model)
    tie_embeddings=True,
    source="arXiv:2403.08295",
)
