"""recurrentgemma-9b [hybrid]: RG-LRU + local attention 2:1. [arXiv:2402.19427]

38 blocks: pattern (recurrent, recurrent, local-attention) repeating.
Sub-quadratic by construction => runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    kind="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,           # MQA on the local-attention layers
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    mlp_variant="geglu",
    rope=True,
    norm="rmsnorm",
    scale_embed=True,
    local_attn_every=3,       # 1 attention per 2 recurrent blocks
    attention_window=2048,    # local (sliding window) attention
    rglru_width=4096,
    source="arXiv:2402.19427",
)
