"""Config registry: ``--arch <id>`` resolution for all assigned archs."""
from __future__ import annotations

from repro.configs.base import (
    ARCH_KINDS,
    INPUT_SHAPES,
    DynamicsConfig,
    HierarchyConfig,
    InputShape,
    ModelConfig,
    TopologyConfig,
    TrainConfig,
    TTHFConfig,
)

from repro.configs.whisper_small import CONFIG as _whisper_small
from repro.configs.gemma_2b import CONFIG as _gemma_2b
from repro.configs.recurrentgemma_9b import CONFIG as _recurrentgemma_9b
from repro.configs.llama4_maverick_400b_a17b import CONFIG as _maverick
from repro.configs.paligemma_3b import CONFIG as _paligemma_3b
from repro.configs.granite_3_8b import CONFIG as _granite_3_8b
from repro.configs.mamba2_370m import CONFIG as _mamba2_370m
from repro.configs.starcoder2_3b import CONFIG as _starcoder2_3b
from repro.configs.qwen15_05b import CONFIG as _qwen15_05b
from repro.configs.llama4_scout_17b_a16e import CONFIG as _scout

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _whisper_small,
        _gemma_2b,
        _recurrentgemma_9b,
        _maverick,
        _paligemma_3b,
        _granite_3_8b,
        _mamba2_370m,
        _starcoder2_3b,
        _qwen15_05b,
        _scout,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(
            f"unknown shape {name!r}; choose from {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]


__all__ = [
    "ARCHS", "ARCH_KINDS", "INPUT_SHAPES", "DynamicsConfig",
    "HierarchyConfig", "InputShape", "ModelConfig", "TopologyConfig",
    "TrainConfig", "TTHFConfig", "get_arch", "get_shape",
]
