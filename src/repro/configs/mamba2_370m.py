"""mamba2-370m [ssm]: SSD (state-space duality), attention-free.
[arXiv:2405.21060]

48L d_model=1024, ssm_state=128, expand=2 => d_inner=2048, head_dim=64
=> 32 SSD heads. Sub-quadratic => runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    kind="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,              # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    rope=False,
    norm="rmsnorm",
    tie_embeddings=True,
    ssm_state_dim=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_num_heads=32,         # (expand * d_model) / head_dim
    ssm_chunk=256,
    ssm_conv_width=4,
    source="arXiv:2405.21060",
)
