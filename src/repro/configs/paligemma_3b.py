"""paligemma-3b [vlm]: SigLIP vision frontend (STUB) + gemma-2b decoder.
[arXiv:2407.07726]

Vision tower supplies 256 patch embeddings (stubbed per the carve-out);
the language model prefixes them to the text stream.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    kind="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab_size=257_216,
    mlp_variant="geglu",
    rope=True,
    norm="rmsnorm",
    scale_embed=True,
    enc_num_layers=0,         # vision tower fully stubbed (projector output)
    enc_seq_len=256,          # 256 image tokens prefix
    enc_is_stub=True,
    cross_attention=False,    # prefix-LM style, not cross-attn
    source="arXiv:2407.07726",
)
