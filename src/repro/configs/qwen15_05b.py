"""qwen1.5-0.5b [dense]: QKV bias, full MHA. [hf:Qwen/Qwen1.5-0.5B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    kind="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151_936,
    mlp_variant="swiglu",
    rope=True,
    qkv_bias=True,
    norm="rmsnorm",
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)
