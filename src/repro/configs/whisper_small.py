"""whisper-small [audio]: enc-dec ASR backbone. [arXiv:2212.04356]

12L (x2: encoder+decoder) d_model=768 12H (kv=12) d_ff=3072 vocab=51865.
Conv/mel frontend is a STUB per the assignment carve-out: input_specs()
supplies precomputed frame embeddings (1500, 768).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    kind="audio",
    num_layers=12,            # decoder layers
    d_model=768,
    num_heads=12,
    num_kv_heads=12,          # full MHA
    d_ff=3072,
    vocab_size=51_865,
    mlp_variant="gelu",
    rope=False,               # whisper uses learned/sinusoidal positions
    norm="layernorm",
    tie_embeddings=True,
    enc_num_layers=12,
    enc_seq_len=1500,         # 30s audio -> 1500 frames
    enc_is_stub=True,
    cross_attention=True,
    max_seq_len=32_768,       # backbone exercised beyond the 448 deploy cap
    source="arXiv:2212.04356",
)
