"""llama4-scout-17b-a16e [moe]: 16 experts top-1, MoE every layer,
early-fusion text backbone. [hf:meta-llama/Llama-4-Scout-17B-16E]

~100B total params, ~17B active with top-1 routing.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    kind="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    mlp_variant="swiglu",
    rope=True,
    norm="rmsnorm",
    tie_embeddings=False,
    moe_num_experts=16,
    moe_top_k=1,
    moe_every=1,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
