"""The observability sink: tracer + one JSONL metrics stream + manifest.

One :class:`Observability` object per run directory. It owns

* a :class:`~repro.obs.trace.Tracer` exported to ``trace.json``
  (Chrome trace / Perfetto),
* ONE ``metrics.jsonl`` stream (a :class:`~repro.train.metrics.
  MetricLogger`) that every record kind shares — train rows, theory
  gauges, comms attribution, serving latency — so bound-vs-actual for
  a round is a single grep,
* a ``manifest.json`` (config hash, git SHA, mesh, backend) written at
  construction,
* an optional ``jax.profiler`` trace in ``jax_profile/`` so the device
  timeline lines up with the host spans.

Instrumented call sites hold ``NULL_OBS`` by default — every method is
a no-op costing one attribute lookup — and are handed a real sink via
``make_obs(trace_dir, ...)``.
"""
from __future__ import annotations

from contextlib import contextmanager, nullcontext
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Optional

from repro.obs.manifest import write_manifest
from repro.obs.trace import Tracer

# NOTE: repro.train.metrics is imported lazily inside
# Observability.__init__ — a top-level import would cycle
# (train/__init__ -> trainer -> obs.sink -> train.metrics ->
# train/__init__) whenever the import starts from repro.train.


@dataclass
class ObsConfig:
    trace_dir: Optional[str] = None     # None = observability off
    profile: bool = False               # jax.profiler passthrough
    window: int = 100                   # MetricLogger smoothing window
    console_every: int = 0              # 0 = JSONL only, no console


class _NullObs:
    """The disabled sink — safe to call everywhere, records nothing."""
    enabled = False
    tracer = None
    metrics = None

    def span(self, name: str, **args: Any):
        return nullcontext(self)

    def instant(self, name: str, **args: Any) -> None:
        pass

    def counter(self, name: str, **values: Any) -> None:
        pass

    def emit(self, kind: str, step: int, **fields: Any) -> None:
        pass

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


NULL_OBS = _NullObs()


def _jsonable(v: Any) -> Any:
    if hasattr(v, "tolist"):
        return v.tolist()
    if hasattr(v, "__float__") and not isinstance(v, (int, bool, float)):
        return float(v)
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return v


class Observability:
    enabled = True

    def __init__(self, cfg: ObsConfig, run_name: str = "run",
                 config: Any = None, extra: Optional[dict] = None):
        assert cfg.trace_dir, "Observability needs a trace_dir; " \
            "use NULL_OBS / make_obs(None) for the disabled sink"
        self.cfg = cfg
        self.dir = Path(cfg.trace_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.tracer = Tracer(annotate=cfg.profile)
        from repro.train.metrics import MetricLogger
        self.metrics = MetricLogger(str(self.dir / "metrics.jsonl"),
                                    console_every=cfg.console_every,
                                    window=cfg.window)
        self.manifest_path = write_manifest(
            str(self.dir), config=config,
            extra={"run": run_name, **(extra or {})})
        self._profiling = False
        if cfg.profile:
            try:
                import jax
                jax.profiler.start_trace(str(self.dir / "jax_profile"))
                self._profiling = True
            except Exception:  # noqa: BLE001 — profiling is best-effort
                self._profiling = False
        self._closed = False

    # -- tracer passthrough -------------------------------------------------
    @contextmanager
    def span(self, name: str, **args: Any):
        with self.tracer.span(name, **args):
            yield self

    def instant(self, name: str, **args: Any) -> None:
        self.tracer.instant(name, **args)

    def counter(self, name: str, **values: Any) -> None:
        self.tracer.counter(name, **values)

    # -- telemetry ----------------------------------------------------------
    def emit(self, kind: str, step: int, **fields: Any) -> None:
        """One JSONL record tagged ``kind`` into the shared stream."""
        self.metrics.log(step, kind=kind,
                         **{k: _jsonable(v) for k, v in fields.items()})

    # -- lifecycle ----------------------------------------------------------
    def flush(self) -> None:
        """Export the Chrome trace collected so far (full rewrite)."""
        self.tracer.export(str(self.dir / "trace.json"))

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self.flush()
        if self._profiling:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception:  # noqa: BLE001
                pass
            self._profiling = False
        self.metrics.close()

    def __enter__(self) -> "Observability":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def make_obs(trace_dir: Optional[str], profile: bool = False,
             run_name: str = "run", config: Any = None,
             extra: Optional[dict] = None, window: int = 100,
             console_every: int = 0):
    """The one constructor call sites use: ``None`` → ``NULL_OBS``."""
    if not trace_dir:
        return NULL_OBS
    return Observability(
        ObsConfig(trace_dir=trace_dir, profile=profile, window=window,
                  console_every=console_every),
        run_name=run_name, config=config, extra=extra)


__all__ = ["NULL_OBS", "ObsConfig", "Observability", "make_obs"]
