"""Run manifests (DESIGN.md §13).

Every JSONL/trace directory gets a ``manifest.json`` recording enough
to reproduce and attribute the run: a stable hash of the run config,
the git SHA, the device mesh (count/kinds/backend), platform, and the
caller's extras (arch, mode, CLI argv). Written at run *start* so even
a crashed run is attributable.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import platform
import subprocess
import sys
import time
from pathlib import Path
from typing import Any, Optional


def _jsonable(obj: Any) -> Any:
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _jsonable(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if hasattr(obj, "tolist"):
        return obj.tolist()
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def config_hash(config: Any) -> str:
    """Stable sha256 over the JSON form of a config (dataclasses,
    dicts, and nests thereof)."""
    blob = json.dumps(_jsonable(config), sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def git_sha(cwd: Optional[str] = None) -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=cwd, capture_output=True,
            text=True, timeout=5)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.TimeoutExpired):
        pass
    return "unknown"


def mesh_info() -> dict:
    try:
        import jax
        devs = jax.devices()
        return {"backend": jax.default_backend(),
                "device_count": len(devs),
                "device_kinds": sorted({d.device_kind for d in devs})}
    except Exception:  # noqa: BLE001 — manifest must not require jax
        return {"backend": "unavailable", "device_count": 0,
                "device_kinds": []}


def write_manifest(out_dir: str, config: Any = None,
                   extra: Optional[dict] = None) -> str:
    """Write ``out_dir/manifest.json``; returns its path."""
    doc = {
        "unix_ts": int(time.time()),
        "config_hash": config_hash(config) if config is not None else None,
        "config": _jsonable(config) if config is not None else None,
        "git_sha": git_sha(),
        "mesh": mesh_info(),
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "argv": list(sys.argv),
    }
    if extra:
        doc.update(_jsonable(extra))
    p = Path(out_dir)
    p.mkdir(parents=True, exist_ok=True)
    path = p / "manifest.json"
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return str(path)


__all__ = ["config_hash", "git_sha", "mesh_info", "write_manifest"]
