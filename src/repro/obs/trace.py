"""Span-based tracer with zero-dep Chrome-trace export (DESIGN.md §13).

The span hierarchy mirrors the paper's two timescales:

  training:  run > round > {interval, consensus_event, aggregation}
  serving:   run > {prefill, decode_step, admission}

Spans are recorded host-side (``time.perf_counter``-clocked, ts/dur in
microseconds) into a flat event list and exported as Chrome trace JSON
— open ``trace.json`` in ``chrome://tracing`` or https://ui.perfetto.dev.
No external dependencies.

Optional ``jax.profiler`` passthrough: when profiling is enabled every
host span also enters a ``jax.profiler.TraceAnnotation`` so the XLA
device timeline lines up with the host spans in the same Perfetto view.
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from pathlib import Path
from typing import Any, Optional

# Chrome trace event phases used here: X = complete span, i = instant,
# C = counter, M = metadata (process/thread names)
_PID = 1


class Tracer:
    """Collects spans/instants/counters; exports Chrome trace JSON.

    ``annotate=True`` additionally wraps every span in a
    ``jax.profiler.TraceAnnotation`` so host spans appear on the device
    profile when a ``jax.profiler.trace`` is active.
    """

    def __init__(self, annotate: bool = False):
        self._events: list[dict] = []
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._tids: dict[int, int] = {}
        self._annotate = annotate
        self._depth: dict[int, int] = {}   # per-thread open-span depth

    # ------------------------------------------------------------------
    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            if ident not in self._tids:
                self._tids[ident] = len(self._tids)
            return self._tids[ident]

    @staticmethod
    def _clean(args: dict) -> dict:
        out = {}
        for k, v in args.items():
            if hasattr(v, "tolist"):
                v = v.tolist()
            elif hasattr(v, "__float__") and not isinstance(v, (int, bool)):
                v = float(v)
            out[k] = v
        return out

    # ------------------------------------------------------------------
    @contextmanager
    def span(self, name: str, cat: str = "span", **args: Any):
        """One complete ('X') event; nests by call structure."""
        tid = self._tid()
        self._depth[tid] = self._depth.get(tid, 0) + 1
        ts = self._now_us()
        ann = None
        if self._annotate:
            try:
                import jax
                ann = jax.profiler.TraceAnnotation(name)
                ann.__enter__()
            except Exception:  # noqa: BLE001 — profiling is best-effort
                ann = None
        try:
            yield self
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            dur = self._now_us() - ts
            self._depth[tid] -= 1
            with self._lock:
                self._events.append({
                    "name": name, "cat": cat, "ph": "X", "pid": _PID,
                    "tid": tid, "ts": ts, "dur": dur,
                    "args": self._clean(args)})

    def instant(self, name: str, cat: str = "event", **args: Any) -> None:
        tid = self._tid()   # resolve BEFORE locking (the lock is not
        with self._lock:    # reentrant; _tid takes it too)
            self._events.append({
                "name": name, "cat": cat, "ph": "i", "s": "t",
                "pid": _PID, "tid": tid, "ts": self._now_us(),
                "args": self._clean(args)})

    def counter(self, name: str, **values: float) -> None:
        """One 'C' sample — renders as a stacked counter track."""
        with self._lock:
            self._events.append({
                "name": name, "ph": "C", "pid": _PID,
                "ts": self._now_us(),
                "args": {k: float(v) for k, v in values.items()}})

    # ------------------------------------------------------------------
    def export(self, path: str, process_name: str = "repro") -> str:
        """Write the Chrome trace JSON (idempotent full rewrite)."""
        with self._lock:
            events = list(self._events)
            tids = dict(self._tids)
        meta = [{"name": "process_name", "ph": "M", "pid": _PID,
                 "args": {"name": process_name}}]
        for ident, tid in tids.items():
            meta.append({"name": "thread_name", "ph": "M", "pid": _PID,
                         "tid": tid, "args": {"name": f"host-{tid}"}})
        doc = {"traceEvents": meta + events, "displayTimeUnit": "ms"}
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = str(p) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        Path(tmp).replace(p)
        return str(p)

    @property
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)


def validate_chrome_trace(doc: dict) -> list[str]:
    """Schema check for an exported trace — returns a list of problems
    (empty = valid). Used by tests and the CI obs-smoke job."""
    problems = []
    if "traceEvents" not in doc:
        return ["missing traceEvents"]
    for i, ev in enumerate(doc["traceEvents"]):
        for key in ("name", "ph", "pid"):
            if key not in ev:
                problems.append(f"event {i} missing {key!r}")
        if ev.get("ph") == "X":
            if "ts" not in ev or "dur" not in ev:
                problems.append(f"span {i} ({ev.get('name')}) missing "
                                "ts/dur")
            elif ev["dur"] < 0:
                problems.append(f"span {i} negative dur")
    return problems


def profiler_trace(trace_dir: Optional[str]):
    """Best-effort ``jax.profiler.trace`` context (no-op fallback)."""
    from contextlib import nullcontext
    if not trace_dir:
        return nullcontext()
    try:
        import jax
        return jax.profiler.trace(str(Path(trace_dir) / "jax_profile"))
    except Exception:  # noqa: BLE001 — profiling must never kill a run
        return nullcontext()


__all__ = ["Tracer", "validate_chrome_trace", "profiler_trace"]
