"""Theory-bound telemetry: jitted aux probes + bound gauges.

Two halves (DESIGN.md §13):

* **Probes** — jitted, read-only functions over the (stacked) device
  parameters that compute the measured quantities the paper's analysis
  talks about: per-cluster consensus divergence Υ_c (Definition 2),
  per-cluster mean-squared consensus error (Definition 3), the
  post-mixing residual max_i‖w_i − w̄_c‖ that Lemma 1 bounds, the
  cluster dispersion A^(t), and parameter/gradient norms. Probes never
  feed back into training — an instrumented run is bitwise-identical
  to an uninstrumented one (asserted in ``tests/test_obs.py``).

* **Gauges** — host-side evaluations of ``core/theory.py`` (``sigma_t``,
  Proposition-1 ``dispersion_bound``, Lemma 1) for the same round, so
  bound-vs-actual lands in ONE JSONL record per round.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.core.theory import (
    ProblemConstants, dispersion_bound, lemma1_bound, sigma_t)


# ---------------------------------------------------------------------------
# jitted probes
# ---------------------------------------------------------------------------

def make_divergence_probe(num_clusters: int, cluster_size: int,
                          varrho) -> Callable:
    """Jitted probe over a params pytree whose leaves carry a leading
    device axis I = N*s (simulation fleet, scale-mode replica stack, or
    the §12 flat (R, P) carrier — an array is a one-leaf pytree).

    Returns ``{upsilon (N,), consensus_err (N,), mix_residual (N,),
    dispersion (), param_norm ()}``; everything is computed on device
    and drained once per round by the caller.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import consensus as cns

    N, s = num_clusters, cluster_size
    v = jnp.asarray(np.asarray(varrho), jnp.float32)

    @jax.jit
    def probe(params):
        ups, errs = [], []
        sq = jnp.zeros((N, s), jnp.float32)
        disp = jnp.float32(0.0)
        pn = jnp.float32(0.0)
        for leaf in jax.tree.leaves(params):
            z = leaf.reshape(N, s, -1).astype(jnp.float32)
            ups.append(cns.divergence_upsilon(z))
            errs.append(cns.consensus_error(z))
            e = z - z.mean(axis=1, keepdims=True)
            sq = sq + jnp.sum(e * e, axis=-1)
            means = z.mean(axis=1)
            gmean = jnp.einsum("c,cm->m", v, means)
            disp = disp + jnp.sum(v * jnp.sum((means - gmean) ** 2,
                                              axis=-1))
            pn = pn + jnp.sum(z * z)
        return {
            "upsilon": jnp.max(jnp.stack(ups), axis=0),
            "consensus_err": jnp.sum(jnp.stack(errs), axis=0),
            "mix_residual": jnp.sqrt(jnp.max(sq, axis=1)),
            "dispersion": disp,
            "param_norm": jnp.sqrt(pn),
        }

    return probe


def make_sim_grad_probe(model, x, y) -> Callable:
    """Jitted ‖∇F(ŵ)‖ over the full federated dataset (sim mode)."""
    import jax
    import jax.numpy as jnp

    fx = jnp.asarray(x).reshape(-1, np.asarray(x).shape[-1])
    fy = jnp.asarray(y).reshape(-1)

    @jax.jit
    def probe(global_params):
        g = jax.grad(model.loss)(global_params, fx, fy)
        return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                            for l in jax.tree.leaves(g)))

    return probe


def make_scale_grad_probe(model, dtype) -> Callable:
    """Jitted ‖∇loss(ŵ; batch)‖ for scale mode — fed a dedicated probe
    batch stream so train/eval data draws are untouched."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def probe(global_params, batch):
        g = jax.grad(lambda p: model.loss(p, batch, dtype=dtype,
                                          remat=False))(global_params)
        return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                            for l in jax.tree.leaves(g)))

    return probe


# ---------------------------------------------------------------------------
# theory gauges
# ---------------------------------------------------------------------------

def default_constants(varrho_min: float) -> ProblemConstants:
    """Unit-scale placeholder constants — the gauges are *relative*
    instruments unless the caller estimates (μ, β, σ, δ) for the task
    (``core/theory.py`` has the estimators)."""
    return ProblemConstants(mu=1.0, beta=1.0, sigma=1.0, delta=1.0,
                            varrho_min=float(varrho_min))


def sigma_t_general(beta: float, eta_fn: Callable[[int], float],
                    t: int, t_prev_agg: int) -> float:
    """Proposition-1 Σ_t for an arbitrary step-size sequence —
    identical recurrence to :func:`repro.core.theory.sigma_t`, which
    covers only η_j = γ/(j+α) (parity asserted in tests)."""
    total = 0.0
    for ell in range(t_prev_agg, t):
        prod = 1.0
        for j in range(ell + 1, t):
            prod *= 1.0 + 2.0 * eta_fn(j) * beta
        total += beta * eta_fn(ell) * prod
    return total


@dataclass
class TheoryGauges:
    """Per-round bound evaluations for the telemetry stream.

    Exactly one of (``gamma``, ``alpha``) — the paper's decaying
    schedule η_t = γ/(t+α) — or ``lr`` (constant step size, scale mode)
    drives the η sequence. ``phi`` sets the Remark-1 consensus target
    ε^(t) = η_t·φ used as Proposition 1's ε₀.
    """
    constants: ProblemConstants
    tau: int
    model_dim: int
    phi: float = 1.0
    gamma: Optional[float] = None
    alpha: Optional[float] = None
    lr: Optional[float] = None

    def __post_init__(self):
        decaying = self.gamma is not None and self.alpha is not None
        assert decaying != (self.lr is not None), \
            "pass gamma+alpha (decaying schedule) XOR lr (constant)"

    def eta(self, t: int) -> float:
        if self.lr is not None:
            return float(self.lr)
        return self.gamma / (t + self.alpha)

    def sigma(self, t: int, t_prev_agg: int) -> float:
        if self.lr is not None:
            return sigma_t_general(self.constants.beta,
                                   lambda j: self.lr, t, t_prev_agg)
        return sigma_t(self.constants, self.gamma, self.alpha, self.tau,
                       t, t_prev_agg)

    def round_gauges(self, t: int, t_prev_agg: int) -> dict:
        """``{sigma_t, dispersion_bound, eps0}`` for round ``t`` whose
        last aggregation was at ``t_prev_agg``."""
        k = self.constants
        eps0 = self.eta(t) * self.phi
        if self.lr is not None:
            s = self.sigma(t, t_prev_agg)
            disp = (12.0 / k.varrho_min) * s ** 2 * (
                k.sigma ** 2 / k.beta ** 2 + k.delta ** 2 / k.beta ** 2
                + eps0 ** 2)
        else:
            s = self.sigma(t, t_prev_agg)
            disp = dispersion_bound(k, self.gamma, self.alpha, self.tau,
                                    t, t_prev_agg, eps0)
        return {"sigma_t": float(s), "dispersion_bound": float(disp),
                "eps0": float(eps0)}

    def lemma1(self, lambdas, gammas, cluster_size,
               upsilons) -> np.ndarray:
        """Per-cluster Lemma-1 bounds λ_c^Γ_c · s_c · Υ_c · M on the
        post-mixing residual, from the *measured* pre-mixing Υ_c."""
        lam = np.asarray(lambdas, float)
        gam = np.asarray(gammas, int)
        ups = np.asarray(upsilons, float)
        sizes = np.broadcast_to(np.asarray(cluster_size), lam.shape)
        return np.array([
            lemma1_bound(lam[c], int(gam[c]), int(sizes[c]), ups[c],
                         self.model_dim)
            for c in range(lam.shape[0])])


__all__ = [
    "TheoryGauges", "default_constants", "make_divergence_probe",
    "make_scale_grad_probe", "make_sim_grad_probe", "sigma_t_general",
]
