"""repro.obs — two-timescale observability (DESIGN.md §13).

Three pieces, one run directory:

* :mod:`~repro.obs.trace` — a span tracer whose hierarchy mirrors the
  paper's timescales (``run > round > {interval, consensus_event,
  aggregation}`` for training; ``run > {prefill, decode_step,
  admission}`` for serving), exported as zero-dep Chrome-trace JSON
  with an optional ``jax.profiler`` passthrough.
* :mod:`~repro.obs.telemetry` — jit-safe aux-metric probes (per-cluster
  consensus divergence, post-mixing residual, dispersion, grad norms)
  plus host-side ``core/theory.py`` bound gauges (``sigma_t``,
  Proposition 1, Lemma 1) so bound-vs-actual is one JSONL stream.
* :mod:`~repro.obs.manifest` — the run manifest (config hash, git SHA,
  mesh, backend) written next to every JSONL/trace.

``make_obs(trace_dir)`` builds the whole sink; ``NULL_OBS`` is the
free disabled default every instrumented call site holds.
"""
from repro.obs.sink import NULL_OBS, Observability, ObsConfig, make_obs
from repro.obs.trace import Tracer, profiler_trace, validate_chrome_trace
from repro.obs.manifest import (
    config_hash, git_sha, mesh_info, write_manifest)
from repro.obs.telemetry import (
    TheoryGauges, default_constants, make_divergence_probe,
    make_scale_grad_probe, make_sim_grad_probe, sigma_t_general)

__all__ = [
    "NULL_OBS", "ObsConfig", "Observability", "TheoryGauges", "Tracer",
    "config_hash", "default_constants", "git_sha",
    "make_divergence_probe", "make_obs", "make_scale_grad_probe",
    "make_sim_grad_probe", "mesh_info", "profiler_trace",
    "sigma_t_general", "validate_chrome_trace", "write_manifest",
]
