"""Per-level sampled aggregation as weight matrices (DESIGN.md §9).

Every tier of the fog hierarchy is expressed as ONE weight matrix, the
multi-level generalization of :mod:`repro.netsim.faults` (which states
the flat eq. (7) as a single per-device weight matrix):

* **rep extraction** ``A: (N, s)`` — row c carries the within-cluster
  average weights of the devices sampled from cluster c (each sampled
  device gets ``1 / counts_c``); rows sum to 1, a dark cluster's row
  is 0. This is the per-cluster-normalized cousin of
  :func:`repro.netsim.faults.aggregation_weights`.
* **tier l >= 1** ``G_l: (P_l, P_{l-1})`` — row p carries the
  base-mass weights of the live (tier >= 2: *sampled* live) children
  of parent p, renormalized to sum to 1; a parent whose whole subtree
  is dark has an all-zero row. Churned subtrees renormalize exactly
  like netsim's dark clusters: live children keep their full base
  mass, the dark mass is redistributed proportionally.

An aggregation event of depth d composes bottom-up to one **(I, I)
device matrix** ``M``: device i's post-event model is
``sum_j M[i, j] w_j``. Live rows (devices that hear the broadcast of a
live subtree) sum to 1; every other row is the identity row e_i —
hold-your-parameters, the same contract as
:func:`repro.core.mixing.masked_consensus_matrix`. The fixed (I, I)
shape is what lets the scale-mode jitted step stay compiled once while
the aggregation depth varies per interval (DESIGN.md §9).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import HierarchyConfig
from repro.hierarchy.tree import AggregationTree


# ---------------------------------------------------------------------------
# event calendar
# ---------------------------------------------------------------------------

def interval_depth(t: int, taus: tuple[int, ...]) -> int:
    """Deepest aggregation tier firing at iteration t (0 = none).

    Periods nest (``HierarchyConfig`` validates divisibility), so the
    firing tiers at any t are exactly 1..depth — a deeper aggregation
    always composes with every shallower one below it.
    """
    depth = 0
    for l, tau in enumerate(taus, start=1):
        if t > 0 and t % tau == 0:
            depth = l
    return depth


# ---------------------------------------------------------------------------
# per-level weight matrices (host side — numpy, like netsim.faults)
# ---------------------------------------------------------------------------

def rep_matrix(picks: np.ndarray, counts: np.ndarray,
               cluster_size: int) -> np.ndarray:
    """(N, k) availability-aware picks -> (N, s) rep-extraction weights.

    Row c averages the ``counts_c`` sampled devices of cluster c (the
    within-cluster mean of eq. (7) with multi-sampling); dark clusters
    get an all-zero row. Unlike
    :func:`repro.netsim.faults.aggregation_weights` the rows are
    normalized per cluster — cross-cluster weighting happens one tier
    up, in the G matrices.
    """
    N, _ = picks.shape
    A = np.zeros((N, cluster_size))
    for c in range(N):
        if counts[c]:
            A[c, picks[c, :counts[c]]] = 1.0 / counts[c]
    return A


def live_levels(tree: AggregationTree, device_up: np.ndarray
                ) -> list[np.ndarray]:
    """Per-level subtree liveness: ``live[l][p]`` is True iff node p at
    level l has at least one available device in its subtree."""
    up = np.asarray(device_up, bool).reshape(tree.num_clusters,
                                             tree.cluster_size)
    live = [up.any(axis=1)]
    for l in range(tree.levels - 1):
        nxt = np.zeros(tree.node_counts[l + 1], bool)
        np.logical_or.at(nxt, tree.parent[l], live[l])
        live.append(nxt)
    return live


def sample_children(rng: np.random.Generator, live_child: np.ndarray,
                    parent_map: np.ndarray, num_parents: int,
                    k: int) -> list[np.ndarray]:
    """Per parent: min(k, live) children drawn uniformly WITHOUT
    replacement among its live ones (k = 0 -> all live children)."""
    out = []
    for p in range(num_parents):
        ch = np.flatnonzero((parent_map == p) & live_child)
        kc = len(ch) if k == 0 else min(k, len(ch))
        out.append(np.sort(rng.choice(ch, size=kc, replace=False))
                   if kc else np.empty(0, np.int64))
    return out


def child_matrix(tree: AggregationTree, level: int,
                 sampled: list[np.ndarray]) -> np.ndarray:
    """(P_level, P_{level-1}) tier weights over the sampled children.

    Each parent's row renormalizes the sampled children's BASE subtree
    masses to sum to 1 (dark/unsampled mass is redistributed
    proportionally — the multi-level analogue of
    :func:`repro.netsim.faults.renormalized_varrho`); parents with no
    sampled live child get an all-zero row.
    """
    G = np.zeros((tree.node_counts[level], tree.node_counts[level - 1]))
    base = tree.mass[level - 1]
    for p, ch in enumerate(sampled):
        if len(ch):
            G[p, ch] = base[ch] / base[ch].sum()
    return G


# ---------------------------------------------------------------------------
# the composed aggregation event
# ---------------------------------------------------------------------------

@dataclass
class HierarchyEvent:
    """One multi-level aggregation event, fully resolved on the host.

    ``level_weights`` holds ``(A, G_1, ..., G_depth)``;
    ``device_matrix`` their (I, I) composition with hold-rows for
    devices that must not receive the broadcast; ``global_weights``
    the root's (I,) source weights — set only when the root fired.
    ``uplinks_by_level[l]`` counts the models actually entering tier
    l's aggregates: sampled devices at tier 1, sampled child nodes at
    tiers >= 2.
    """
    t: int
    depth: int
    picks: np.ndarray
    counts: np.ndarray
    level_weights: tuple[np.ndarray, ...]
    device_matrix: np.ndarray
    global_weights: Optional[np.ndarray]
    uplinks_by_level: dict[int, int]

    @property
    def total_uplinks(self) -> int:
        return sum(self.uplinks_by_level.values())


def build_event(rng: np.random.Generator, tree: AggregationTree,
                cfg: HierarchyConfig, t: int, device_up: np.ndarray,
                receive_offline: bool = False) -> Optional[HierarchyEvent]:
    """Resolve iteration t's aggregation event (None when no tier fires).

    ``device_up``: (N, s) availability — sampling draws only among
    available devices and dark subtrees renormalize away.
    ``receive_offline``: scale mode broadcasts to every replica in a
    live subtree (replicas are physical shards); simulation mode keeps
    offline devices' hold-your-parameters rows.
    """
    depth = interval_depth(t, cfg.taus)
    if depth == 0:
        return None
    from repro.netsim.faults import availability_sample

    up = np.asarray(device_up, bool)
    N, s, I = tree.num_clusters, tree.cluster_size, tree.num_devices
    picks, counts = availability_sample(rng, up, k=cfg.sample[0])
    A = rep_matrix(picks, counts, s)
    live = live_levels(tree, up)

    # tier 1 aggregates ALL its live child clusters (the cross-cluster
    # sampling of eq. (7) is the device sampling already inside A)
    sampled1 = [np.flatnonzero((tree.parent[0] == p) & live[0])
                for p in range(tree.node_counts[1])]
    Gs = [child_matrix(tree, 1, sampled1)]
    uplinks = {1: int(counts.sum())}
    for l in range(2, depth + 1):
        sampled = sample_children(rng, live[l - 1], tree.parent[l - 1],
                                  tree.node_counts[l], cfg.sample[l - 1])
        uplinks[l] = int(sum(len(c) for c in sampled))
        Gs.append(child_matrix(tree, l, sampled))

    # compose top-down weights over clusters, then through A to devices
    W = Gs[0]
    for G in Gs[1:]:
        W = G @ W                               # (P_depth, N)
    S = (W[:, :, None] * A[None, :, :]).reshape(W.shape[0], I)

    anc = tree.device_ancestors(depth)          # (I,)
    up_flat = up.reshape(I)
    sub_live = S.sum(axis=1) > 0.0
    recv = sub_live[anc] & (receive_offline | up_flat)
    M = np.where(recv[:, None], S[anc], np.eye(I))

    return HierarchyEvent(
        t=t, depth=depth, picks=picks, counts=counts,
        level_weights=(A, *Gs),
        device_matrix=M.astype(np.float32),
        global_weights=(S[0].astype(np.float32)
                        if depth == cfg.levels - 1 else None),
        uplinks_by_level=uplinks)


# ---------------------------------------------------------------------------
# jitted appliers
# ---------------------------------------------------------------------------

def apply_device_matrix_pytree(params, M: jax.Array):
    """params leaves (I, ...) -> (I, ...): one einsum per leaf against
    the composed (I, I) event matrix. Hold-rows (e_i) are built into M,
    so the application is unconditional — the fixed shape keeps a
    jitted step compiled once across aggregation depths."""
    def one(leaf):
        I = leaf.shape[0]
        z = leaf.reshape(I, -1)
        out = jnp.einsum("ij,jm->im", M.astype(z.dtype), z,
                         preferred_element_type=z.dtype)
        return out.reshape(leaf.shape).astype(leaf.dtype)
    return jax.tree.map(one, params)


def global_from_weights(params, gw: jax.Array):
    """Root model from its (I,) source weights: leaves (I, ...) -> (...)."""
    def one(leaf):
        I = leaf.shape[0]
        g = jnp.einsum("i,im->m", gw.astype(leaf.dtype),
                       leaf.reshape(I, -1))
        return g.reshape(leaf.shape[1:]).astype(leaf.dtype)
    return jax.tree.map(one, params)


__all__ = [
    "HierarchyEvent", "apply_device_matrix_pytree", "build_event",
    "child_matrix", "global_from_weights", "interval_depth",
    "live_levels", "rep_matrix", "sample_children",
]
