"""Named hierarchy presets (``--hierarchy`` registry, DESIGN.md §9).

Mirrors :mod:`repro.netsim.scenarios`: a name resolves to a frozen
:class:`~repro.configs.base.HierarchyConfig`, parameterized by the base
aggregation period tau (tier periods are fixed multiples of it, so the
same preset serves any trainer cadence). ``flat`` is the identity
preset — plain two-timescale TT-HF, routed through the historical code
path bit-for-bit.

    from repro.hierarchy import presets
    hier = presets.get("fog3", tau=20)
    TTHFTrainer(model, data, topo, algo, hierarchy=hier)
"""
from __future__ import annotations

from repro.configs.base import HierarchyConfig

# name -> (levels, per-tier period multiples of tau, per-tier fan-in)
_SPECS: dict[str, tuple[int, tuple[int, ...], tuple[int, ...]]] = {
    # today's TT-HF: one aggregation tier = the global server
    "flat": (2, (1,), (1,)),
    # one fog tier: edge nodes aggregate every tau, the root every 2tau
    "fog3": (3, (1, 2), (1, 0)),
    # two fog tiers: tau / 2tau / 4tau
    "fog4": (4, (1, 2, 4), (1, 0, 0)),
    # fog tier + cluster-sampling at the root: the root samples 2 edge
    # nodes per event instead of hearing all of them
    "fog3_sampled": (3, (1, 2), (1, 2)),
}


def names() -> tuple[str, ...]:
    return tuple(_SPECS)


def get(name: str, tau: int = 20, **overrides) -> HierarchyConfig:
    """Resolve a preset name at a concrete base period ``tau``."""
    if name not in _SPECS:
        raise KeyError(
            f"unknown hierarchy preset {name!r}; choose from "
            f"{sorted(_SPECS)}")
    levels, mults, sample = _SPECS[name]
    cfg = dict(levels=levels, taus=tuple(m * tau for m in mults),
               sample=sample)
    cfg.update(overrides)
    return HierarchyConfig(**cfg)


__all__ = ["get", "names"]
