"""repro.hierarchy — multi-stage fog aggregation trees (DESIGN.md §9).

Generalizes TT-HF's two timescales to a configurable L-level
aggregation hierarchy: level 0 is per-cluster D2D consensus (the
unchanged ``core/mixing.py`` engine), levels 1..L-1 are parent-node
aggregations over child subtrees — each tier with its own period and
sampling fan-in — and the root is the global model. Tree construction
(:mod:`tree`), per-level weight-matrix aggregation (:mod:`aggregate`),
and a named-preset registry (:mod:`presets`).
"""
from repro.hierarchy.aggregate import (
    HierarchyEvent, apply_device_matrix_pytree, build_event,
    child_matrix, global_from_weights, interval_depth, live_levels,
    rep_matrix, sample_children,
)
from repro.hierarchy.tree import AggregationTree, build_tree
from repro.hierarchy import presets

__all__ = [
    "AggregationTree", "HierarchyEvent", "apply_device_matrix_pytree",
    "build_event", "build_tree", "child_matrix", "global_from_weights",
    "interval_depth", "live_levels", "presets", "rep_matrix",
    "sample_children",
]
