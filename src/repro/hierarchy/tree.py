"""Aggregation-tree construction (DESIGN.md §9).

Turns a :class:`~repro.configs.base.HierarchyConfig` plus the cluster
tier of a :class:`~repro.configs.base.TopologyConfig` into an explicit
:class:`AggregationTree`: per-level parent maps over contiguous blocks
(matching the scale-mode cluster == contiguous-replica-block
convention) and per-level subtree *mass* — the fraction of all devices
under each node, which generalizes the paper's cluster weights
varrho_c = s_c / I to every tier (``mass[0]`` IS varrho).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.configs.base import HierarchyConfig


def _auto_branching(num_clusters: int, levels: int) -> tuple[int, ...]:
    """Balance the intermediate fan-ins: each tier's branching factor is
    the divisor of the remaining node count closest to the geometric
    target ``remaining ** (1 / tiers_left)`` (and >= 2, so every tier
    actually coarsens)."""
    branching = []
    remaining = num_clusters
    for tier in range(levels - 2):
        tiers_left = (levels - 1) - tier
        target = remaining ** (1.0 / tiers_left)
        divisors = [d for d in range(2, remaining + 1) if remaining % d == 0]
        if not divisors:
            raise ValueError(
                f"cannot branch {remaining} nodes at tier {tier + 1} "
                f"(num_clusters={num_clusters}, levels={levels}): no "
                f"divisor >= 2 — pick num_clusters with enough factors")
        b = min(divisors, key=lambda d: abs(d - target))
        branching.append(b)
        remaining //= b
    return tuple(branching)


@dataclass
class AggregationTree:
    """The resolved L-level tree over N clusters of s devices.

    ``node_counts[l]`` — nodes at level l (node_counts[0] = N,
    node_counts[-1] = 1, the root).
    ``parent[l]`` — (node_counts[l],) int array mapping each level-l
    node to its level-(l+1) parent, for l = 0..L-2.
    ``mass[l]`` — (node_counts[l],) device-mass fraction of each
    subtree; sums to 1 at every level, and ``mass[0]`` equals the
    paper's varrho.
    """
    levels: int
    num_clusters: int
    cluster_size: int
    branching: tuple[int, ...]
    node_counts: tuple[int, ...]
    parent: tuple[np.ndarray, ...]
    mass: tuple[np.ndarray, ...]
    _cluster_anc: dict[int, np.ndarray] = field(default_factory=dict,
                                                repr=False)

    @property
    def num_devices(self) -> int:
        return self.num_clusters * self.cluster_size

    def children(self, level: int, node: int) -> np.ndarray:
        """Level-(level-1) children of one level-``level`` node."""
        return np.flatnonzero(self.parent[level - 1] == node)

    def ancestors(self, level: int) -> np.ndarray:
        """(N,) level-``level`` ancestor of every cluster (level 0 is
        the identity map)."""
        anc = self._cluster_anc.get(level)
        if anc is None:
            anc = np.arange(self.num_clusters)
            for l in range(level):
                anc = self.parent[l][anc]
            self._cluster_anc[level] = anc
        return anc

    def device_ancestors(self, level: int) -> np.ndarray:
        """(I,) level-``level`` ancestor of every device (devices are
        ordered cluster-major, matching the trainers' leading axis)."""
        return np.repeat(self.ancestors(level), self.cluster_size)


def build_tree(cfg: HierarchyConfig, num_clusters: int,
               cluster_size: int) -> AggregationTree:
    """Resolve the tree shape for a concrete cluster tier.

    Intermediate tiers group *contiguous* runs of child nodes (the
    scale-mode cluster == contiguous-replica-block convention carries
    up the tree); the root absorbs every remaining top-tier node.
    """
    branching = cfg.branching or _auto_branching(num_clusters, cfg.levels)
    if len(branching) != max(cfg.levels - 2, 0):
        raise ValueError(
            f"branching must cover the {cfg.levels - 2} intermediate "
            f"tiers, got {branching}")

    node_counts = [num_clusters]
    for b in branching:
        if node_counts[-1] % b:
            raise ValueError(
                f"branching {branching} does not divide {num_clusters} "
                f"clusters evenly (stuck at {node_counts[-1]} % {b})")
        node_counts.append(node_counts[-1] // b)
    node_counts.append(1)                      # the root
    if node_counts[-2] < 1:
        raise ValueError(f"tree over-coarsened: {node_counts}")

    parent = []
    for l in range(cfg.levels - 1):
        n_child, n_parent = node_counts[l], node_counts[l + 1]
        group = n_child // n_parent
        parent.append(np.repeat(np.arange(n_parent), group))

    # mass: uniform over equal clusters, summed up the tree
    mass = [np.full((num_clusters,), 1.0 / num_clusters)]
    for l in range(cfg.levels - 1):
        m = np.zeros(node_counts[l + 1])
        np.add.at(m, parent[l], mass[l])
        mass.append(m)

    return AggregationTree(
        levels=cfg.levels, num_clusters=num_clusters,
        cluster_size=cluster_size, branching=tuple(branching),
        node_counts=tuple(node_counts), parent=tuple(parent),
        mass=tuple(np.asarray(m) for m in mass))


__all__ = ["AggregationTree", "build_tree"]
