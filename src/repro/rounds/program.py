"""Declarative round programs (DESIGN.md §10).

The paper's Algorithm 1 — and its generalizations in Hosseinalipour et
al. 2020 (multi-stage fog) and Parasnis et al. 2023 (time-varying D2D)
— is one *schedule*: per iteration t, resolve who takes an SGD step,
which consensus matrices mix, which aggregation operator fires, and
what to bill. This module states that schedule as data:

* :class:`RoundProgram` — the frozen scenario declaration (which
  dynamics, which hierarchy). Trainers and ``launch/train.py`` build
  ONE program and hand it to a
  :class:`~repro.rounds.resolver.RoundResolver`, instead of threading
  per-scenario knobs through per-scenario loops.
* :class:`RoundEvent` / :class:`ScaleRoundEvent` — one resolved round:
  the device-up mask, the consensus spec (V/λ/active sizes), the
  aggregation operator in the existing weight/device-matrix forms, and
  a :class:`Billing` record.
* :class:`Billing` — the single ledger adapter. Every path that used
  to call :class:`~repro.core.energy.CommLedger` directly (six call
  sites across the two trainers) now assembles one ``Billing`` and
  ``charge()``s it, so sim and scale mode cannot diverge on pricing.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.configs.base import DynamicsConfig, HierarchyConfig


@dataclass(frozen=True)
class RoundProgram:
    """What should happen each round, declaratively.

    ``dynamics``: an optional :class:`DynamicsConfig` — a static (or
    absent) config declares the idealized paper setting and resolves to
    the exact historical code path. ``hierarchy``: an optional
    :class:`HierarchyConfig` — a flat (L = 2) config IS two-timescale
    TT-HF and is likewise ignored. The program is frozen/hashable so it
    can ride in configs and jit static args.
    """
    dynamics: Optional[DynamicsConfig] = None
    hierarchy: Optional[HierarchyConfig] = None

    @property
    def is_dynamic(self) -> bool:
        return self.dynamics is not None and not self.dynamics.is_static

    @property
    def is_hierarchical(self) -> bool:
        return self.hierarchy is not None and not self.hierarchy.is_flat


@dataclass
class Billing:
    """One round's communication bill — the single
    :class:`~repro.core.energy.CommLedger` adapter.

    ``consensus_gammas`` may be None: simulation mode computes the
    Remark-1 adaptive round counts at event time, so the trainer passes
    the realized ``gamma_used`` to :meth:`charge`. ``consensus_repeats``
    covers scale mode, where one interval carries ``tau //
    consensus_every`` identical events. ``uplinks_by_level`` is None
    when nothing was transmitted (e.g. an all-dark simulation fleet
    skips the aggregation — no uplinks, no broadcast); a flat
    aggregation is simply ``{1: n}``.
    """
    local_devices: int = 0
    consensus_gammas: Optional[np.ndarray] = None
    consensus_edges: Optional[np.ndarray] = None
    consensus_tail: Optional[np.ndarray] = None
    consensus_repeats: int = 1
    uplinks_by_level: Optional[dict] = None
    uplink_delay_mults: Optional[np.ndarray] = None

    def charge(self, ledger, gamma_used: Optional[np.ndarray] = None):
        """Apply this bill to a ledger (the one home for pricing).

        One ``charge`` = one attribution event (``ledger.next_event``);
        consensus repeats replay ``record_consensus`` per repeat so the
        per-cluster attribution rows keep their cluster index (totals
        are identical to the concatenated form they replace).
        """
        ledger.next_event()
        if self.local_devices:
            ledger.record_local_step(self.local_devices)
        if self.consensus_edges is not None and self.consensus_repeats:
            g = (self.consensus_gammas if self.consensus_gammas is not None
                 else gamma_used)
            assert g is not None, \
                "adaptive consensus billing needs the realized gamma_used"
            for _ in range(self.consensus_repeats):
                ledger.record_consensus(
                    list(g), list(self.consensus_edges),
                    tail_mult_per_cluster=(
                        list(self.consensus_tail)
                        if self.consensus_tail is not None else None))
        if self.uplinks_by_level is not None:
            ledger.record_hierarchy_event(
                self.uplinks_by_level,
                uplink_delay_mults=self.uplink_delay_mults)


@dataclass
class ConsensusSpec:
    """One consensus event's inputs. ``V is None`` declares the static
    base topology (the trainer mixes with its build-time matrices);
    otherwise V/λ/active sizes come from the event's rebuilt active
    subgraph and clusters with no live edge are forced to Γ = 0."""
    edges: np.ndarray                        # (N,) live-edge counts
    V: Optional[np.ndarray] = None           # (N, s, s) event matrices
    lambdas: Optional[np.ndarray] = None     # (N,) component contractions
    active_sizes: Optional[np.ndarray] = None  # (N,) active device counts
    device_up: Optional[np.ndarray] = None   # (N, s) bool

    @property
    def dynamic(self) -> bool:
        return self.V is not None


@dataclass
class AggregationSpec:
    """One aggregation event as the existing operator forms.

    kind:
      * ``static`` — the historical jit-sampled eq. (7) (``full``
        selects full participation); the trainer draws inside the
        jitted aggregate with the round's ``k_agg`` key;
      * ``weights`` — one (N, s) per-device weight matrix
        (``netsim.faults`` builders), broadcast masked by
        ``device_up``;
      * ``matrix`` — the composed (I, I) hierarchy device matrix,
        with the root's (I,) source weights when the root fired.
    """
    kind: str
    full: bool = False
    weights: Optional[np.ndarray] = None
    device_up: Optional[np.ndarray] = None
    device_matrix: Optional[np.ndarray] = None
    global_weights: Optional[np.ndarray] = None


@dataclass
class RoundEvent:
    """One resolved simulation round (iteration ``t``).

    ``billing.local_devices`` is 0 here: the trainer bills the local
    SGD steps of the whole scanned span (which ends at ``t``) itself.
    """
    t: int
    active_devices: int
    device_up: Optional[np.ndarray]          # (N, s) bool; None = all up
    consensus: Optional[ConsensusSpec]
    aggregation: Optional[AggregationSpec]
    billing: Billing = field(default_factory=Billing)


@dataclass
class ScaleRoundEvent:
    """One resolved scale-mode interval: the jitted step's aggregation
    argument (picks / weight matrix / device matrix — whatever form the
    step was built for), the optional per-interval consensus-matrix
    refresh, whether the served global model should snapshot after the
    step (a live hierarchy root event), and the interval's full bill."""
    interval: int
    agg: Any
    refresh: Optional[Any]
    root_served: bool
    billing: Billing


__all__ = [
    "AggregationSpec", "Billing", "ConsensusSpec", "RoundEvent",
    "RoundProgram", "ScaleRoundEvent",
]
