"""The round-program resolver (DESIGN.md §10).

:class:`RoundResolver` compiles a declarative
:class:`~repro.rounds.program.RoundProgram` against a concrete
:class:`~repro.core.topology.Network` into per-round events: it
composes the static topology, an optional
:class:`~repro.netsim.dynamics.TimeVaryingNetwork`, and an optional
:class:`~repro.hierarchy.tree.AggregationTree`, and per iteration (sim
mode) or per interval (scale mode) emits who is up, which consensus
matrices mix, which aggregation operator fires, and one
:class:`~repro.rounds.program.Billing` record.

The resolver also knows the event *calendar* ahead of time
(:meth:`span_end`), which is what lets the simulation trainer chunk
the τ local-SGD iterations between events through one jitted
``lax.scan`` instead of dispatching per iteration.

Everything here is host-side numpy (plus the deterministic
``k_agg``-seeded generators the pre-engine loops used); the jitted
trainers consume the specs unchanged, so resolved trajectories are
bit-for-bit the historical ones (asserted in ``tests/test_rounds.py``).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.obs.sink import NULL_OBS
from repro.rounds.program import (
    AggregationSpec, Billing, ConsensusSpec, RoundEvent, RoundProgram,
    ScaleRoundEvent)


def host_rng(key) -> np.random.Generator:
    """The pre-engine loops' host-side generator: one numpy Generator
    seeded deterministically from a JAX key (sampling among *available*
    devices and down the fog tree is host work; the JAX key schedule
    stays untouched)."""
    import jax
    return np.random.default_rng(
        int(jax.random.randint(key, (), 0, 2**31 - 1)))


class RoundResolver:
    """Per-round event resolution for both execution modes.

    Build with :meth:`for_sim` (a :class:`~repro.configs.base.
    TTHFConfig` drives the calendar, Remark-1 adaptive Γ stays a
    trainer-side computation) or :meth:`for_scale` (a
    :class:`~repro.core.distributed.TTHFScaleConfig` plus the step's
    :class:`~repro.core.mixing.MixingPlan` for per-interval matrix
    refreshes).
    """

    def __init__(self, net, program: RoundProgram, *,
                 algo=None, scale=None, plan=None,
                 topo_weights: str = "metropolis"):
        assert (algo is None) != (scale is None), \
            "exactly one of algo (sim) / scale (scale mode) drives the calendar"
        self.net = net
        self.program = program
        self.algo = algo
        self.scale = scale
        self.plan = plan
        self.dynamics = program.dynamics
        self.hierarchy = program.hierarchy if program.is_hierarchical else None
        self.tvnet = None
        if program.is_dynamic:
            from repro.netsim.dynamics import TimeVaryingNetwork
            self.tvnet = TimeVaryingNetwork(net, program.dynamics,
                                            weights=topo_weights)
        self.tree = None
        if self.hierarchy is not None:
            from repro.hierarchy import build_tree
            self.tree = build_tree(self.hierarchy, net.num_clusters,
                                   net.cluster_size)
        self._edges = net.num_d2d_edges()
        # observability sink (repro.obs): trainers hand in the run's
        # sink; resolution spans/counters are free no-ops by default
        self.obs = NULL_OBS

    # ------------------------------------------------------------------
    @classmethod
    def for_sim(cls, net, algo, program: RoundProgram,
                topo_weights: str = "metropolis") -> "RoundResolver":
        if program.is_hierarchical:
            h = program.hierarchy
            assert algo.mode == "tthf" and not algo.full_participation, \
                "hierarchical aggregation implies sampled tthf mode"
            assert h.taus[0] == algo.tau, \
                f"tier-1 period {h.taus[0]} must equal tau={algo.tau}"
            assert h.sample[0] == algo.sample_per_cluster, \
                "tier-1 fan-in must equal sample_per_cluster"
        return cls(net, program, algo=algo, topo_weights=topo_weights)

    @classmethod
    def for_scale(cls, net, scale, program: RoundProgram,
                  plan=None) -> "RoundResolver":
        # tau / fan-in cross-validation already ran in
        # make_tthf_train_step (the step and the resolver must agree)
        return cls(net, program, scale=scale, plan=plan)

    # ------------------------------------------------------------------
    # the simulation calendar: event boundaries are known ahead of time
    # ------------------------------------------------------------------

    def is_event(self, t: int, eval_every: int) -> bool:
        """Does iteration t carry a consensus, aggregation or eval?"""
        return (self.algo.is_consensus_step(t)
                or self.algo.is_aggregation_step(t)
                or (eval_every > 0 and t % eval_every == 0))

    def span_end(self, t: int, t_last: int, eval_every: int) -> int:
        """The first boundary iteration in [t, t_last]: the next
        consensus/aggregation/eval event, or t_last itself. Every
        iteration strictly before it is pure local SGD — the scanned
        span of the sim hot loop."""
        u = t
        while u < t_last and not self.is_event(u, eval_every):
            u += 1
        return u

    # ------------------------------------------------------------------
    # simulation mode: one event per boundary iteration
    # ------------------------------------------------------------------

    def resolve(self, t: int, k_agg) -> RoundEvent:
        """Resolve iteration ``t``'s events. ``k_agg`` is the round's
        aggregation key from the trainer's (unchanged) key schedule —
        static sampling consumes it inside the jitted aggregate, the
        dynamic/hierarchical paths seed their host generators from it.
        """
        algo = self.algo
        net = self.net
        with self.obs.span("resolve", t=t):
            snap = (self.tvnet.snapshot(t)
                    if self.tvnet is not None else None)
            device_up = snap.device_up if snap is not None else None
            active = (int(snap.device_up.sum()) if snap is not None
                      else net.num_devices)
            billing = Billing()

            consensus = None
            if algo.is_consensus_step(t):
                consensus = self._consensus_spec(snap)
                billing.consensus_edges = consensus.edges
                if snap is not None:
                    from repro.netsim import faults
                    billing.consensus_tail = faults.consensus_tail_mult(
                        snap.delay_mult, snap.device_up, snap.adj)

            aggregation = None
            if algo.is_aggregation_step(t):
                aggregation = self._sim_aggregation(t, k_agg, snap,
                                                    billing)

        self.obs.counter("resolver", active_devices=active,
                         consensus=int(consensus is not None),
                         aggregation=int(aggregation is not None))
        return RoundEvent(t=t, active_devices=active, device_up=device_up,
                          consensus=consensus, aggregation=aggregation,
                          billing=billing)

    def _consensus_spec(self, snap) -> ConsensusSpec:
        if snap is None:
            return ConsensusSpec(edges=self._edges)
        return ConsensusSpec(edges=snap.num_active_edges(), V=snap.V,
                             lambdas=snap.lambdas,
                             active_sizes=snap.active_per_cluster,
                             device_up=snap.device_up)

    def _sim_aggregation(self, t, k_agg, snap,
                         billing: Billing) -> Optional[AggregationSpec]:
        from repro.netsim import faults

        algo = self.algo
        net = self.net
        N, s = net.num_clusters, net.cluster_size

        if self.tree is not None:
            from repro.hierarchy import build_event
            rng = host_rng(k_agg)
            up = (snap.device_up if snap is not None
                  else np.ones((N, s), bool))
            ev = build_event(rng, self.tree, self.hierarchy, t, up,
                             receive_offline=False)
            if ev is None or ev.total_uplinks == 0:
                # an all-dark fleet skips the event: no uplinks, no
                # broadcast, every model (and the global one) stays put
                return None
            billing.uplinks_by_level = dict(ev.uplinks_by_level)
            if snap is not None:
                billing.uplink_delay_mults = faults.uplink_tail_mults(
                    snap.delay_mult, ev.picks, ev.counts)
            return AggregationSpec(kind="matrix",
                                   device_matrix=ev.device_matrix,
                                   global_weights=ev.global_weights)

        full = algo.full_participation or algo.mode != "tthf"
        if snap is None:
            n_up = (net.num_devices if full
                    else N * algo.sample_per_cluster)
            billing.uplinks_by_level = {1: n_up}
            return AggregationSpec(kind="static", full=full)

        if full:
            weights = faults.full_participation_weights(
                snap.device_up, np.asarray(net.varrho))
            n_up = int(snap.device_up.sum())
            mults = snap.delay_mult[snap.device_up]
        else:
            # availability-aware cluster sampling: the jax key seeds a
            # host-side draw among available devices
            rng = host_rng(k_agg)
            picks, counts = faults.availability_sample(
                rng, snap.device_up, k=algo.sample_per_cluster)
            weights = faults.aggregation_weights(
                picks, counts, snap.varrho, s)
            n_up = int(counts.sum())
            mults = faults.uplink_tail_mults(
                snap.delay_mult, picks, counts)
        if n_up == 0:
            return None
        billing.uplinks_by_level = {1: n_up}
        billing.uplink_delay_mults = mults
        return AggregationSpec(kind="weights", weights=weights,
                               device_up=snap.device_up)

    # ------------------------------------------------------------------
    # scale mode: one event per aggregation interval
    # ------------------------------------------------------------------

    def resolve_interval(self, interval: int, kp) -> ScaleRoundEvent:
        """Resolve interval ``interval`` (0-based): the step's
        aggregation argument, the optional consensus-matrix refresh,
        and the interval's full bill (local steps × τ, the interval's
        ``τ // consensus_every`` consensus events, the uplinks)."""
        with self.obs.span("resolve", interval=interval):
            ev = self._resolve_interval(interval, kp)
        self.obs.counter(
            "resolver",
            active_devices=ev.billing.local_devices // max(
                self.scale.tau, 1),
            refresh=int(ev.refresh is not None),
            root_served=int(ev.root_served))
        return ev

    def _resolve_interval(self, interval: int, kp) -> ScaleRoundEvent:
        import jax.numpy as jnp

        from repro.core import sampling as smp
        from repro.netsim import faults

        scale = self.scale
        net = self.net
        N, s = scale.num_clusters, scale.cluster_size
        tau, k = scale.tau, scale.sample_per_cluster
        events = (tau // scale.consensus_every
                  if scale.consensus_every else 0)
        snap = (self.tvnet.snapshot(interval + 1)
                if self.tvnet is not None else None)
        refresh = None
        if snap is not None and self.plan is not None:
            from repro.core.mixing import refresh_matrices
            refresh = refresh_matrices(self.plan, snap.V)

        root_served = False
        mults = None
        up_level: Optional[dict] = None
        if self.tree is not None:
            from repro.hierarchy import build_event
            rng = host_rng(kp)
            up = (snap.device_up if snap is not None
                  else np.ones((N, s), bool))
            # tier-1 period == tau, so every interval fires depth >= 1;
            # scale mode broadcasts into live subtrees regardless of
            # churn (replicas are physical shards)
            ev = build_event(rng, self.tree, self.hierarchy,
                             (interval + 1) * tau, up,
                             receive_offline=True)
            agg = jnp.asarray(ev.device_matrix)
            root_served = (ev.global_weights is not None
                           and bool(ev.total_uplinks))
            if ev.total_uplinks:
                up_level = dict(ev.uplinks_by_level)
                if snap is not None:
                    mults = faults.uplink_tail_mults(
                        snap.delay_mult, ev.picks, ev.counts)
        elif snap is not None:
            rng = host_rng(kp)
            picks_np, counts = faults.availability_sample(
                rng, snap.device_up, k=k)
            if refresh is not None:
                # the refreshable step aggregates with the full (N, s)
                # weight matrix, so EVERY sampled replica the ledger
                # bills actually enters the aggregate
                agg = jnp.asarray(faults.aggregation_weights(
                    picks_np, counts, snap.varrho, s), jnp.float32)
            else:
                # star/local sync: the picks argument is unused inside
                agg = jnp.asarray(
                    np.where(counts > 0, picks_np[:, 0], 0), jnp.int32)
            up_level = {1: int(counts.sum())}
            mults = faults.uplink_tail_mults(
                snap.delay_mult, picks_np, counts)
        elif k > 1:
            # static multi-sampling through the same (N, s) weight form
            # as the dynamic path: all k picks enter the aggregate and
            # the ledger bills the N * k uplinks actually transmitted
            picks_np = np.asarray(smp.sample_devices_multi(kp, N, s, k))
            counts = np.full((N,), k, np.int64)
            agg = jnp.asarray(faults.aggregation_weights(
                picks_np, counts, np.asarray(net.varrho), s), jnp.float32)
            up_level = {1: N * k}
        else:
            agg = smp.sample_devices(kp, N, s)   # the historical draw
            up_level = {1: N}

        if snap is not None:
            gammas = np.where(snap.num_active_edges() > 0,
                              scale.gamma_d2d, 0)
            edges = snap.num_active_edges()
            tail = faults.consensus_tail_mult(
                snap.delay_mult, snap.device_up, snap.adj)
            local = int(snap.device_up.sum()) * tau
        else:
            gammas = np.full((N,), scale.gamma_d2d)
            edges = self._edges
            tail = None
            local = scale.replicas * tau

        billing = Billing(local_devices=local, consensus_gammas=gammas,
                          consensus_edges=edges, consensus_tail=tail,
                          consensus_repeats=events,
                          uplinks_by_level=up_level,
                          uplink_delay_mults=mults)
        return ScaleRoundEvent(interval=interval, agg=agg, refresh=refresh,
                               root_served=root_served, billing=billing)


__all__ = ["RoundResolver", "host_rng"]
