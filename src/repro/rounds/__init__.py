"""repro.rounds — the declarative round-program engine (DESIGN.md §10).

One schedule drives every execution scenario: a frozen
:class:`RoundProgram` declares the scenario (optional netsim dynamics,
optional fog hierarchy), a :class:`RoundResolver` compiles it against a
concrete network into per-round events (device-up mask, consensus spec,
aggregation operator, one :class:`Billing` record), and both trainers
run ONE loop over those events — with the τ local-SGD iterations
between events chunked through a single jitted ``lax.scan`` in
simulation mode.
"""
from repro.rounds.program import (
    AggregationSpec, Billing, ConsensusSpec, RoundEvent, RoundProgram,
    ScaleRoundEvent)
from repro.rounds.resolver import RoundResolver, host_rng

__all__ = [
    "AggregationSpec", "Billing", "ConsensusSpec", "RoundEvent",
    "RoundProgram", "RoundResolver", "ScaleRoundEvent", "host_rng",
]
