"""Minimal sharding-aware pytree checkpointing (orbax is not available
offline). Arrays are gathered to host, stored in a single .npz with the
tree structure in a JSON sidecar entry; restore rebuilds the tree and
(optionally) re-shards via device_put."""
from __future__ import annotations

import io
import json
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def _npz_path(path: str) -> str:
    """The path np.savez actually writes: it silently appends ``.npz``
    when the suffix is missing. Save and restore both normalize through
    here, so an extensionless path round-trips instead of raising
    FileNotFoundError on restore."""
    return path if str(path).endswith(".npz") else str(path) + ".npz"


def save_pytree(path: str, tree) -> None:
    flat, treedef = _flatten_with_paths(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(flat)}
    # store the structure via flatten/unflatten of an index tree
    idx_tree = jax.tree.unflatten(treedef, list(range(len(flat))))
    arrays["__index__"] = np.frombuffer(
        json.dumps(_to_jsonable(idx_tree)).encode(), dtype=np.uint8)
    np.savez(_npz_path(path), **arrays)


def _to_jsonable(t):
    if isinstance(t, dict):
        return {"__d__": {k: _to_jsonable(v) for k, v in t.items()}}
    if isinstance(t, (list, tuple)):
        return {"__l__": [_to_jsonable(v) for v in t],
                "__t__": isinstance(t, tuple)}
    return t


def _from_jsonable(t, leaves):
    if isinstance(t, dict) and "__d__" in t:
        return {k: _from_jsonable(v, leaves) for k, v in t["__d__"].items()}
    if isinstance(t, dict) and "__l__" in t:
        seq = [_from_jsonable(v, leaves) for v in t["__l__"]]
        return tuple(seq) if t.get("__t__") else seq
    return leaves[t]


def restore_pytree(path: str, shardings=None):
    data = np.load(_npz_path(path), allow_pickle=False)
    idx = json.loads(bytes(data["__index__"].tobytes()).decode())
    leaves = {}
    for k in data.files:
        if k.startswith("leaf_"):
            leaves[int(k[5:])] = data[k]
    tree = _from_jsonable(idx, leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


def save_train_state(path: str, params, opt_state, step: int,
                     extra: Optional[dict] = None) -> None:
    save_pytree(path, {"params": params, "opt_state": opt_state,
                       "step": np.asarray(step),
                       "extra": extra or {}})


def restore_train_state(path: str, shardings=None):
    t = restore_pytree(path, shardings)
    return t["params"], t["opt_state"], int(t["step"]), t.get("extra", {})
