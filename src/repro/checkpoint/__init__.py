from repro.checkpoint.ckpt import save_pytree, restore_pytree, \
    save_train_state, restore_train_state

__all__ = ["save_pytree", "restore_pytree", "save_train_state",
           "restore_train_state"]
