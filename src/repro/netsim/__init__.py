"""repro.netsim — time-varying network dynamics (DESIGN.md §8).

Event-driven churn / link-failure / straggler simulation layered
between ``core/topology.py`` and the trainers: seeded event streams
(:mod:`events`), per-event consensus-matrix rebuilds
(:mod:`dynamics`), availability-aware sampling and straggler pricing
(:mod:`faults`), and a named-scenario registry (:mod:`scenarios`).
"""
from repro.netsim.dynamics import (
    NetworkSnapshot, TimeVaryingNetwork, check_masked_assumption2,
    component_spectral_radius, connected_components,
    masked_cluster_weights,
)
from repro.netsim.events import EventStream, NetworkEvent
from repro.netsim.faults import (
    aggregation_weights, availability_sample, consensus_tail_mult,
    full_participation_weights, renormalized_varrho, uplink_tail_mults,
    weighted_global_pytree,
)
from repro.netsim import scenarios

__all__ = [
    "EventStream", "NetworkEvent", "NetworkSnapshot",
    "TimeVaryingNetwork", "aggregation_weights", "availability_sample",
    "check_masked_assumption2", "component_spectral_radius",
    "connected_components", "consensus_tail_mult",
    "full_participation_weights", "masked_cluster_weights",
    "renormalized_varrho", "scenarios", "uplink_tail_mults",
    "weighted_global_pytree",
]
