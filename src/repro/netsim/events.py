"""Seeded, deterministic network-event streams (DESIGN.md §8).

An :class:`EventStream` turns a :class:`~repro.configs.base.
DynamicsConfig` into a sequence of :class:`NetworkEvent` records indexed
by the training iteration ``t``:

* **link state** — every BASE D2D edge carries an independent 2-state
  Markov chain (up/down) advanced once per iteration;
* **device availability** — every device carries a churn Markov chain
  (up/down), composed with the deterministic flash-crowd window;
* **straggler delay** — a fixed straggler subset (drawn once at
  construction) receives a fresh ``1 + LogNormal(mu, sigma)`` delay
  multiplier each iteration; everyone else is 1.0.

Determinism: the stream owns a single ``numpy`` generator seeded from
``cfg.seed``, and events are generated strictly in ``t`` order and
cached — ``at(t)`` is a pure function of ``(cfg, topology shape, t)``
no matter how callers interleave their queries. The stream never
touches JAX PRNG keys, so enabling dynamics cannot perturb the
trainers' existing sampling streams.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import DynamicsConfig


@dataclass(frozen=True)
class NetworkEvent:
    """The network's state at one iteration.

    ``link_up``: (N, s, s) bool, symmetric — Markov state of the base
    edges (True everywhere a static network would be).
    ``device_up``: (N, s) bool — churn AND flash-crowd availability.
    ``delay_mult``: (N, s) float >= 1 — straggler tail multiplier on
    any communication this device takes part in at this iteration.
    """
    t: int
    link_up: np.ndarray
    device_up: np.ndarray
    delay_mult: np.ndarray

    @property
    def all_up(self) -> bool:
        return bool(self.link_up.all() and self.device_up.all())


class EventStream:
    """Deterministic per-iteration event source for one topology.

    ``base_adj``: (N, s, s) bool — only base edges carry link chains.
    ``at(t)`` serves any ``t >= 0`` (t=0 is the all-up initial state);
    events are cached, so repeated/interleaved queries are cheap and
    reproducible.
    """

    def __init__(self, cfg: DynamicsConfig, base_adj: np.ndarray):
        self.cfg = cfg
        self.base_adj = np.asarray(base_adj, bool)
        self.N, self.s, _ = self.base_adj.shape
        self._rng = np.random.default_rng(cfg.seed)
        # straggler membership is a device trait, not an event: draw once
        n_stragglers = int(round(cfg.straggler_frac * self.N * self.s))
        flat = self._rng.permutation(self.N * self.s)[:n_stragglers]
        self.straggler_mask = np.zeros((self.N, self.s), bool)
        self.straggler_mask.reshape(-1)[flat] = True
        # flash-crowd membership: the same deterministic subset each window
        n_flash = int(round(cfg.flash_drop_frac * self.N * self.s))
        flat = self._rng.permutation(self.N * self.s)[:n_flash]
        self.flash_mask = np.zeros((self.N, self.s), bool)
        self.flash_mask.reshape(-1)[flat] = True

        # the churn Markov chain's own state (flash overlay excluded)
        self._churn_up = np.ones((self.N, self.s), bool)
        self._events: list[NetworkEvent] = [NetworkEvent(
            t=0,
            link_up=np.ones_like(self.base_adj),
            device_up=self._device_up(0, self._churn_up),
            delay_mult=np.ones((self.N, self.s)),
        )]

    # ------------------------------------------------------------------
    def at(self, t: int) -> NetworkEvent:
        if t < 0:
            raise ValueError(f"event index must be >= 0, got {t}")
        while len(self._events) <= t:
            self._advance()
        return self._events[t]

    def _advance(self) -> None:
        cfg = self.cfg
        prev = self._events[-1]
        t = prev.t + 1
        rng = self._rng

        # --- link Markov chains (upper-triangle state, mirrored; anything
        # off the base graph reads as "up" so static streams stay all-True)
        link_up = prev.link_up.copy()
        if cfg.p_link_fail > 0.0:
            iu = np.triu(np.ones((self.s, self.s), bool), 1)[None]
            edges = self.base_adj & np.broadcast_to(iu, self.base_adj.shape)
            u = rng.random(self.base_adj.shape)
            stay_up = prev.link_up & edges & (u >= cfg.p_link_fail)
            come_up = ~prev.link_up & edges & (u < cfg.p_link_recover)
            ut = stay_up | come_up
            link_up = ut | ut.transpose(0, 2, 1) | ~self.base_adj

        # --- device churn Markov chains (flash overlay applied on top)
        if cfg.p_device_drop > 0.0:
            u = rng.random((self.N, self.s))
            drop = self._churn_up & (u < cfg.p_device_drop)
            ret = ~self._churn_up & (u < cfg.p_device_return)
            self._churn_up = self._churn_up & ~drop | ret
        device_up = self._device_up(t, self._churn_up)

        # --- straggler tail draws (fresh each iteration)
        delay_mult = np.ones((self.N, self.s))
        if self.straggler_mask.any():
            tail = rng.lognormal(cfg.straggler_mu, cfg.straggler_sigma,
                                 size=(self.N, self.s))
            delay_mult = np.where(self.straggler_mask, 1.0 + tail, 1.0)

        self._events.append(NetworkEvent(
            t=t, link_up=link_up, device_up=device_up,
            delay_mult=delay_mult))

    def _in_flash(self, t: int) -> bool:
        cfg = self.cfg
        return (cfg.flash_duration > 0
                and cfg.flash_at <= t < cfg.flash_at + cfg.flash_duration)

    def _device_up(self, t: int, churn_up: np.ndarray) -> np.ndarray:
        if self._in_flash(t):
            return churn_up & ~self.flash_mask
        return churn_up.copy()


__all__ = ["EventStream", "NetworkEvent"]
