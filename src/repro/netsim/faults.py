"""Availability-aware sampling and straggler pricing (DESIGN.md §8).

The sampling layer of eq. (7) assumes every device answers the server.
Under churn it must not: the server can only sample among *available*
devices, and a fully-dark cluster contributes nothing — its weight is
renormalized away. Rather than thread index juggling through the jitted
aggregation, everything is expressed as one per-device **aggregation
weight matrix** ``w`` with ``w.sum() == 1`` (or 0 when the whole fleet
is dark):

    w_hat = sum_{c,i} w[c, i] * z[c, i]

which keeps the jitted side a single einsum
(:func:`weighted_global_pytree`) and makes unbiasedness auditable: for
uniform sampling among availables, ``E[w_hat]`` is the
varrho'-weighted mean of the *available* devices' cluster means.

Straggler pricing: communication involving a straggling device pays its
tail multiplier. A D2D round completes when the slowest ACTIVE member
finishes (max over the cluster); an uplink pays the sampled device's
own multiplier. Both feed :class:`~repro.core.energy.CommLedger`.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# availability-aware cluster sampling (host side — numpy)
# ---------------------------------------------------------------------------

def renormalized_varrho(device_up: np.ndarray,
                        base_varrho: np.ndarray) -> np.ndarray:
    """(N, s) availability + base varrho -> (N,) cluster weights.

    Clusters keep their paper weight varrho_c = s_c / I while they
    have ANY available device; a fully-dark cluster's weight is zeroed
    and the remainder renormalized to sum to 1. With everyone up this
    is exactly the base weighting. All-dark fleet: returns the base
    weights unchanged (the caller should skip the aggregation — there
    is nobody to sample).
    """
    counts = np.asarray(device_up, bool).sum(axis=1)
    base = np.asarray(base_varrho, np.float64)
    live = counts > 0
    mass = base[live].sum()
    if mass == 0:
        return base.copy()
    return np.where(live, base, 0.0) / mass


def availability_sample(rng: np.random.Generator, device_up: np.ndarray,
                        k: int = 1) -> tuple[np.ndarray, np.ndarray]:
    """Sample min(k, available_c) devices per cluster, uniformly
    WITHOUT replacement among the available ones.

    Returns ``(picks, counts)``: picks is (N, k) int32 (entries beyond
    counts[c] are -1), counts is (N,) int — how many were actually
    sampled (0 for a dark cluster).
    """
    up = np.asarray(device_up, bool)
    N, s = up.shape
    picks = np.full((N, k), -1, np.int32)
    counts = np.zeros(N, np.int64)
    for c in range(N):
        avail = np.flatnonzero(up[c])
        kc = min(k, len(avail))
        if kc:
            picks[c, :kc] = rng.choice(avail, size=kc, replace=False)
        counts[c] = kc
    return picks, counts


def aggregation_weights(picks: np.ndarray, counts: np.ndarray,
                        varrho: np.ndarray, cluster_size: int) -> np.ndarray:
    """(N, k) picks -> (N, s) per-device aggregation weights.

    Each sampled device in cluster c carries varrho'_c / counts_c (the
    within-cluster average of the k representatives, eq. (7) with
    multi-sampling); dark clusters carry 0 and the remaining weights
    are renormalized to sum to 1.
    """
    N, k = picks.shape
    w = np.zeros((N, cluster_size))
    live = counts > 0
    mass = varrho[live].sum()
    if mass == 0:
        return w
    for c in range(N):
        if counts[c]:
            w[c, picks[c, :counts[c]]] = varrho[c] / (counts[c] * mass)
    return w


def full_participation_weights(device_up: np.ndarray,
                               varrho: np.ndarray) -> np.ndarray:
    """Full-participation aggregation over the AVAILABLE devices only."""
    up = np.asarray(device_up, float)
    counts = up.sum(axis=1)
    w = np.zeros_like(up)
    live = counts > 0
    mass = varrho[live].sum()
    if mass == 0:
        return w
    w[live] = (up[live] * (varrho[live] / (counts[live] * mass))[:, None])
    return w


def weighted_global_pytree(params, weights: jax.Array, num_clusters: int):
    """Aggregate leaves (I, ...) with per-device weights (N, s).

    The jitted counterpart of the host-side weight builders above:
    w_hat = sum_{c,i} w[c,i] z[c,i].
    """
    def one(leaf):
        I = leaf.shape[0]
        s = I // num_clusters
        z = leaf.reshape(num_clusters, s, -1)
        g = jnp.einsum("cs,csm->m", weights.astype(z.dtype), z)
        return g.reshape(leaf.shape[1:])
    return jax.tree.map(one, params)


# ---------------------------------------------------------------------------
# straggler tail latency
# ---------------------------------------------------------------------------

def consensus_tail_mult(delay_mult: np.ndarray, device_up: np.ndarray,
                        adj_active: np.ndarray) -> np.ndarray:
    """(N,) per-cluster D2D-round tail multiplier.

    A round is as slow as the slowest device that actually exchanges
    messages (active AND has at least one active edge); clusters with
    no exchanging devices pay the baseline 1.0.
    """
    exchanging = np.asarray(device_up, bool) & (adj_active.sum(-1) > 0)
    mult = np.where(exchanging, delay_mult, 1.0)
    return mult.max(axis=1)


def uplink_tail_mults(delay_mult: np.ndarray, picks: np.ndarray,
                      counts: np.ndarray) -> np.ndarray:
    """Flat array of the sampled devices' own uplink multipliers."""
    out = []
    for c in range(picks.shape[0]):
        for j in range(counts[c]):
            out.append(delay_mult[c, picks[c, j]])
    return np.asarray(out) if out else np.ones((0,))


__all__ = [
    "aggregation_weights", "availability_sample", "consensus_tail_mult",
    "full_participation_weights", "renormalized_varrho",
    "uplink_tail_mults", "weighted_global_pytree",
]
