"""Named dynamics scenarios (DESIGN.md §8).

A tiny registry turning a scenario name into a frozen
:class:`~repro.configs.base.DynamicsConfig`. Every existing experiment
becomes a family: same model, data, topology and schedules — different
network weather. ``static`` is the identity scenario and reproduces the
historical (pre-netsim) trajectories bit-for-bit.

    from repro.netsim import scenarios
    dyn = scenarios.get("markov_links", seed=3)
    TTHFTrainer(model, data, topo, algo, dynamics=dyn)
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import DynamicsConfig

SCENARIOS: dict[str, DynamicsConfig] = {
    # the idealized paper setting — no events, byte-identical trajectories
    "static": DynamicsConfig(name="static"),
    # links flap on a 2-state Markov chain (arXiv:2303.08988 regime):
    # ~20% of edges down in steady state, mean outage ~3 iterations
    "markov_links": DynamicsConfig(
        name="markov_links", p_link_fail=0.08, p_link_recover=0.35),
    # devices churn in and out; ~14% dark in steady state, and their
    # parameters freeze until they return
    "device_churn": DynamicsConfig(
        name="device_churn", p_device_drop=0.05, p_device_return=0.30),
    # 20% of devices have a heavy lognormal delay tail (median ~3.7x)
    "stragglers": DynamicsConfig(
        name="stragglers", straggler_frac=0.20,
        straggler_mu=1.0, straggler_sigma=0.5),
    # half the fleet vanishes for iterations [30, 50) and returns at
    # once — the mass-departure / mass-arrival stress test
    "flash_crowd": DynamicsConfig(
        name="flash_crowd", flash_at=30, flash_duration=20,
        flash_drop_frac=0.5),
}


def names() -> tuple[str, ...]:
    return tuple(SCENARIOS)


def get(name: str, seed: int = 0, **overrides) -> DynamicsConfig:
    """Resolve a scenario name; ``seed``/field overrides go through
    ``dataclasses.replace`` so configs stay frozen."""
    if name not in SCENARIOS:
        raise KeyError(
            f"unknown scenario {name!r}; choose from {sorted(SCENARIOS)}")
    return dataclasses.replace(SCENARIOS[name], seed=seed, **overrides)


__all__ = ["SCENARIOS", "get", "names"]
