"""Time-varying consensus topology (DESIGN.md §8).

:class:`TimeVaryingNetwork` sits between ``core/topology.py`` (the base
graphs tuned at build time) and the trainers. At each iteration it
masks the base adjacency with the live edge/device set from the
:class:`~repro.netsim.events.EventStream` and rebuilds every cluster's
consensus matrix *on the active subgraph* so the Assumption-2 contract
holds per event:

* a dropped device is isolated — its row of ``V`` is the identity row
  ``e_i``, so a consensus step leaves its parameters untouched;
* active devices get fresh Metropolis (or Laplacian) weights over the
  *active* edges only — they mix exclusively among themselves;
* ``lambdas`` are recomputed per event as the max contraction factor
  over the connected components of the active subgraph, so the
  Remark-1 adaptive-gamma rule sees degraded connectivity and responds.
  A disconnected active subgraph degrades gracefully: consensus reaches
  agreement *within* each component (singleton components — including
  every dropped device — contribute a factor of 0).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import DynamicsConfig
from repro.core.topology import (
    Network, laplacian_weights, metropolis_weights, spectral_radius)
from repro.netsim.events import EventStream, NetworkEvent
from repro.netsim.faults import renormalized_varrho


# ---------------------------------------------------------------------------
# active-subgraph helpers
# ---------------------------------------------------------------------------

def connected_components(adj: np.ndarray) -> list[np.ndarray]:
    """Index arrays of the components of one (s, s) adjacency."""
    s = adj.shape[0]
    unseen = set(range(s))
    comps = []
    while unseen:
        start = unseen.pop()
        comp, frontier = {start}, [start]
        while frontier:
            i = frontier.pop()
            for j in np.flatnonzero(adj[i]):
                if j in unseen:
                    unseen.discard(j)
                    comp.add(j)
                    frontier.append(j)
        comps.append(np.array(sorted(comp)))
    return comps


def component_spectral_radius(v: np.ndarray, adj: np.ndarray) -> float:
    """Max over components of rho(V|_comp - 11^T/|comp|).

    This is the per-event contraction factor: each consensus round
    contracts the disagreement *within* every component by at least
    this much (singletons contribute 0 — nothing to contract). Always
    < 1, unlike the global rho which pins at 1 when disconnected.
    """
    worst = 0.0
    for comp in connected_components(adj):
        if len(comp) < 2:
            continue
        sub = v[np.ix_(comp, comp)]
        worst = max(worst, spectral_radius(sub))
    return worst


def masked_cluster_weights(adj_active: np.ndarray, device_up: np.ndarray,
                           scheme: str = "metropolis") -> np.ndarray:
    """Consensus weights for one cluster's ACTIVE subgraph.

    ``adj_active`` must already exclude edges incident to a down
    device. Down devices have degree 0, so both schemes naturally give
    them the identity row (hold-your-parameters semantics).
    """
    a = adj_active & device_up[:, None] & device_up[None, :]
    if scheme == "metropolis":
        return metropolis_weights(a)
    if scheme == "laplacian":
        return laplacian_weights(a)
    raise ValueError(f"unknown weight scheme {scheme!r}")


def check_masked_assumption2(v: np.ndarray, adj_active: np.ndarray,
                             device_up: np.ndarray,
                             atol: float = 1e-9,
                             component_rho: float | None = None) -> None:
    """Assumption 2 relaxed to the active subgraph (DESIGN.md §8).

    (i) sparsity matches the active edges, (ii) rows sum to 1,
    (iii) symmetric, (iv) every *component's* contraction factor < 1,
    (v) down-device rows are exactly e_i.

    ``component_rho``: pass a precomputed
    :func:`component_spectral_radius` to avoid re-running the
    eigendecomposition (the per-event hot loop does).
    """
    s = v.shape[0]
    a = adj_active & device_up[:, None] & device_up[None, :]
    offdiag = ~np.eye(s, dtype=bool)
    assert np.all(np.abs(v[offdiag & ~a]) < atol), "sparsity violated"
    assert np.allclose(v.sum(1), 1.0, atol=atol), "rows must sum to 1"
    assert np.allclose(v, v.T, atol=atol), "V must be symmetric"
    if component_rho is None:
        component_rho = component_spectral_radius(v, a)
    assert component_rho < 1.0 - 1e-12, \
        "component contraction must be < 1"
    for i in np.flatnonzero(~device_up):
        want = np.zeros(s)
        want[i] = 1.0
        assert np.allclose(v[i], want, atol=atol), \
            f"down device {i} must hold its parameters"


# ---------------------------------------------------------------------------
# snapshots
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class NetworkSnapshot:
    """The consensus network at one iteration.

    V/adj/lambdas mirror :class:`~repro.core.topology.Network` but are
    recomputed on the active subgraph; ``varrho`` is renormalized over
    the available devices (a fully-dark cluster gets weight 0).
    """
    t: int
    V: np.ndarray             # (N, s, s) float32
    adj: np.ndarray           # (N, s, s) bool — active edges
    device_up: np.ndarray     # (N, s) bool
    lambdas: np.ndarray       # (N,) component-wise contraction factors
    delay_mult: np.ndarray    # (N, s) straggler multipliers
    varrho: np.ndarray        # (N,) availability-renormalized weights

    @property
    def active_per_cluster(self) -> np.ndarray:
        return self.device_up.sum(axis=1)

    def num_active_edges(self) -> np.ndarray:
        return self.adj.sum((1, 2)) // 2


class TimeVaryingNetwork:
    """A :class:`Network` animated by an :class:`EventStream`.

    ``snapshot(t)`` is deterministic in ``(base network, cfg, t)`` and
    cached per iteration; trainers typically query it only at consensus
    and aggregation steps (the stream still advances its chains through
    the skipped iterations, so the sample path does not depend on the
    event calendar).
    """

    def __init__(self, base: Network, cfg: DynamicsConfig,
                 weights: str = "metropolis"):
        self.base = base
        self.cfg = cfg
        self.weights = weights
        self.events = EventStream(cfg, base.adj)
        self._cache: dict[int, NetworkSnapshot] = {}

    def snapshot(self, t: int) -> NetworkSnapshot:
        snap = self._cache.get(t)
        if snap is None:
            snap = self._build(self.events.at(t))
            self._cache.clear()         # trainers walk forward; keep 1
            self._cache[t] = snap
        return snap

    def _build(self, ev: NetworkEvent) -> NetworkSnapshot:
        base = self.base
        up = ev.device_up
        adj = (base.adj & ev.link_up
               & up[:, :, None] & up[:, None, :])
        V = np.empty_like(base.V, np.float32)
        lambdas = np.empty((base.num_clusters,))
        for c in range(base.num_clusters):
            v = masked_cluster_weights(adj[c], up[c], self.weights)
            lam = component_spectral_radius(v, adj[c])
            check_masked_assumption2(v, adj[c], up[c], component_rho=lam)
            V[c] = v.astype(np.float32)
            lambdas[c] = lam
        varrho = renormalized_varrho(up, base.varrho)
        return NetworkSnapshot(
            t=ev.t, V=V, adj=adj, device_up=up, lambdas=lambdas,
            delay_mult=ev.delay_mult, varrho=varrho.astype(np.float32))


__all__ = [
    "NetworkSnapshot", "TimeVaryingNetwork", "check_masked_assumption2",
    "component_spectral_radius", "connected_components",
    "masked_cluster_weights",
]
