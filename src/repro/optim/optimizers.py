"""Minimal pure-JAX optimizers (optax is not available offline).

API mirrors optax: ``opt.init(params) -> state``;
``opt.update(grads, state, params, lr) -> (updates, state)`` where
``updates`` are to be *added* to params. ``lr`` is passed per call so the
paper's decaying schedule stays outside the optimizer state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]
    name: str = "opt"


def _zeros_like_tree(params):
    return jax.tree.map(jnp.zeros_like, params)


def sgd(weight_decay: float = 0.0) -> Optimizer:
    """Plain SGD — the paper's optimizer (eq. 9)."""
    def init(params):
        return ()

    def update(grads, state, params, lr):
        def u(g, p):
            g = g.astype(p.dtype)
            if weight_decay:
                g = g + jnp.asarray(weight_decay, p.dtype) * p
            # lr cast to param dtype: an f32 scalar would promote the
            # whole product to f32 (a full-param-sized temp)
            return jnp.asarray(-lr, p.dtype) * g
        return jax.tree.map(u, grads, params), state

    return Optimizer(init, update, "sgd")


def momentum(beta: float = 0.9, weight_decay: float = 0.0,
             nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"m": _zeros_like_tree(params)}

    def update(grads, state, params, lr):
        def step(g, m, p):
            g = g + weight_decay * p if weight_decay else g
            m_new = beta * m + g
            d = g + beta * m_new if nesterov else m_new
            return (-lr * d).astype(p.dtype), m_new
        flat = jax.tree.map(step, grads, state["m"], params)
        updates = jax.tree.map(lambda x: x[0], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda x: x[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m}

    return Optimizer(init, update, "momentum")


def adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"m": _zeros_like_tree(params),
                "v": _zeros_like_tree(params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def step(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g32
            v_new = b2 * v + (1 - b2) * (g32 * g32)
            upd = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (-lr * upd).astype(p.dtype), m_new, v_new

        flat = jax.tree.map(step, grads, state["m"], state["v"], params)
        is3 = lambda x: isinstance(x, tuple)
        updates = jax.tree.map(lambda x: x[0], flat, is_leaf=is3)
        m = jax.tree.map(lambda x: x[1], flat, is_leaf=is3)
        v = jax.tree.map(lambda x: x[2], flat, is_leaf=is3)
        return updates, {"m": m, "v": v, "count": count}

    return Optimizer(init, update, "adamw")


def make_optimizer(name: str, *, momentum_beta: float = 0.9,
                   weight_decay: float = 0.0) -> Optimizer:
    if name == "sgd":
        return sgd(weight_decay)
    if name == "momentum":
        return momentum(momentum_beta, weight_decay)
    if name == "adamw":
        return adamw(weight_decay=weight_decay)
    raise ValueError(f"unknown optimizer {name!r}")


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)
