"""Step-size schedules. ``paper_schedule`` is the paper's
eta_t = gamma / (t + alpha) (Theorem 2), validated by
``core.theory.check_theorem2_conditions``."""
from __future__ import annotations

import jax.numpy as jnp


def paper_schedule(gamma: float, alpha: float):
    """eta_t = gamma / (t + alpha)  (Proposition 1 / Theorem 2)."""
    def eta(t):
        return gamma / (jnp.asarray(t, jnp.float32) + alpha)
    return eta


def constant(lr: float):
    def eta(t):
        return jnp.full((), lr, jnp.float32)
    return eta


def cosine(peak: float, total_steps: int, floor: float = 0.0):
    def eta(t):
        frac = jnp.clip(jnp.asarray(t, jnp.float32) / total_steps, 0.0, 1.0)
        return floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
    return eta


def warmup_cosine(peak: float, warmup: int, total_steps: int,
                  floor: float = 0.0):
    cos = cosine(peak, max(total_steps - warmup, 1), floor)
    def eta(t):
        t = jnp.asarray(t, jnp.float32)
        w = peak * t / jnp.maximum(warmup, 1)
        return jnp.where(t < warmup, w, cos(t - warmup))
    return eta
