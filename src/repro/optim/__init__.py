from repro.optim.optimizers import (
    Optimizer, sgd, momentum, adamw, make_optimizer, apply_updates,
)
from repro.optim.schedules import (
    paper_schedule, constant, cosine, warmup_cosine,
)

__all__ = [
    "Optimizer", "sgd", "momentum", "adamw", "make_optimizer",
    "apply_updates",
    "paper_schedule", "constant", "cosine", "warmup_cosine",
]
