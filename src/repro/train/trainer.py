"""Scale-mode trainer: the TT-HF interval loop with evaluation,
checkpointing, and metric logging — the production loop around
`core.distributed.make_tthf_train_step`.

Handles: data sharding per replica, interval batching
(tau x R x b x T), periodic held-out eval of the *global* (sampled)
model, checkpoint save/resume, and the communication ledger (uplink /
consensus event accounting mirroring the paper's cost model).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_train_state, save_train_state
from repro.configs.base import DynamicsConfig, HierarchyConfig, ModelConfig
from repro.core.distributed import (
    TTHFScaleConfig, make_tthf_train_step, stack_replicas)
from repro.core.energy import CommLedger
from repro.core.mixing import build_mixing_plan, refresh_matrices
from repro.data.tokens import synthetic_token_batches
from repro.models import ModelApi, build_model
from repro.train.metrics import MetricLogger

# the only dtypes the microstep math supports; anything else (a typo'd
# "float16") used to silently coerce to bfloat16
_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclass
class TrainerConfig:
    batch_per_replica: int = 4
    seq_len: int = 256
    intervals: int = 10
    eval_every: int = 5
    eval_batches: int = 2
    ckpt_every: int = 0             # 0 = off
    ckpt_dir: str = "checkpoints"
    log_path: Optional[str] = None
    dtype: str = "float32"
    seed: int = 0

    def __post_init__(self):
        if self.dtype not in _DTYPES:
            raise ValueError(
                f"unknown dtype {self.dtype!r}; expected one of "
                f"{sorted(_DTYPES)}")


class ScaleTrainer:
    def __init__(self, cfg: ModelConfig, scale: TTHFScaleConfig,
                 tcfg: TrainerConfig, sync: str = "tthf",
                 dynamics: Optional[DynamicsConfig] = None,
                 hierarchy: Optional[HierarchyConfig] = None):
        self.cfg = cfg
        self.scale = scale
        self.tcfg = tcfg
        self.model: ModelApi = build_model(cfg)
        dtype = _DTYPES[tcfg.dtype]
        # multi-stage fog hierarchy: a flat (L = 2) config IS TT-HF and
        # takes the historical code path bit-for-bit
        self.hierarchy = None
        self.tree = None
        if hierarchy is not None and not hierarchy.is_flat:
            from repro.hierarchy import build_tree
            assert sync == "tthf", "hierarchy implies tthf sync"
            self.hierarchy = hierarchy
            self.tree = build_tree(hierarchy, scale.num_clusters,
                                   scale.cluster_size)
        # netsim dynamics: the event stream ticks once per aggregation
        # interval; each interval's consensus matrices are refreshed on
        # the active subgraph and fed to the (once-traced) step
        self.tvnet = None
        self._plan = None
        dynamic = dynamics is not None and not dynamics.is_static
        # only a tthf step carries consensus matrices to refresh
        refreshable = dynamic and sync == "tthf"
        step, self.net = make_tthf_train_step(
            self.model, scale, dtype=dtype, sync=sync,
            refreshable=refreshable, hierarchy=hierarchy)
        if dynamic:
            from repro.netsim.dynamics import TimeVaryingNetwork
            self.tvnet = TimeVaryingNetwork(self.net, dynamics)
        if refreshable:
            self._plan = build_mixing_plan(
                self.net, scale.gamma_d2d, backend=scale.consensus_mode)
        self._step = jax.jit(step)
        self._eval_loss = jax.jit(
            lambda p, b: self.model.loss(p, b, dtype=dtype, remat=False))
        self.ledger = CommLedger()
        self.metrics = MetricLogger(tcfg.log_path)
        self.key = jax.random.PRNGKey(tcfg.seed)
        self._make_gens()
        # resume fidelity: batches drawn so far from every train
        # generator (identical across replicas) and from the eval
        # stream — persisted so restore-and-continue replays neither
        self._train_draws = 0
        self._eval_draws = 0
        self.params = None
        # hierarchical runs: the SERVED global model — materialized
        # only when the root tier fires (between root events replicas
        # under different fog nodes legitimately disagree)
        self._global = None
        self.interval = 0

    def _make_gens(self):
        tcfg, cfg = self.tcfg, self.cfg
        self._gens = [synthetic_token_batches(
            tcfg.batch_per_replica, tcfg.seq_len, cfg.vocab_size,
            seed=tcfg.seed, shard_id=r)
            for r in range(self.scale.replicas)]
        self._eval_gen = synthetic_token_batches(
            tcfg.batch_per_replica, tcfg.seq_len, cfg.vocab_size,
            seed=tcfg.seed + 10_000, shard_id=99)

    # ------------------------------------------------------------------
    def init(self):
        init_params = self.model.init(jax.random.PRNGKey(self.tcfg.seed))
        self.params = stack_replicas(init_params, self.scale.replicas)
        self._global = init_params
        return self

    def _interval_batch(self):
        tau, R = self.scale.tau, self.scale.replicas
        mbs = [[next(g) for _ in range(tau)] for g in self._gens]
        self._train_draws += tau
        return {k: jnp.asarray(np.stack(
            [[mbs[r][t][k] for r in range(R)] for t in range(tau)]))
            for k in ("tokens", "labels")}

    def _global_params(self):
        """The served global model. Flat runs: replica 0's copy —
        identical to all others right after the interval's aggregation
        (asserted in tests). Hierarchical runs: the root-tier snapshot
        (the initial broadcast until the root first fires — replicas
        under different fog nodes disagree between root events)."""
        if self.tree is not None:
            return self._global
        return jax.tree.map(lambda l: l[0], self.params)

    def evaluate(self) -> float:
        g = self._global_params()
        losses = []
        for _ in range(self.tcfg.eval_batches):
            b = next(self._eval_gen)
            self._eval_draws += 1
            losses.append(float(self._eval_loss(
                g, {k: jnp.asarray(v) for k, v in b.items()})))
        return float(np.mean(losses))

    def _dynamic_interval(self, batch, kp, events: int):
        """One interval under netsim dynamics: per-aggregation-round W
        refresh on the active subgraph, availability-aware sampling as
        one (N, s) weight matrix, and straggler-aware ledger records."""
        from repro.netsim import faults

        snap = self.tvnet.snapshot(self.interval + 1)
        refresh = (refresh_matrices(self._plan, snap.V)
                   if self._plan is not None else None)
        rng = np.random.default_rng(
            int(jax.random.randint(kp, (), 0, 2**31 - 1)))
        picks_np, counts = faults.availability_sample(
            rng, snap.device_up, k=self.scale.sample_per_cluster)
        if refresh is not None:
            # the refreshable step aggregates with the full (N, s)
            # weight matrix, so EVERY sampled replica the ledger bills
            # actually enters the aggregate (sample_per_cluster > 1)
            # and a dark cluster's devices carry exact weight 0
            agg_w = jnp.asarray(faults.aggregation_weights(
                picks_np, counts, snap.varrho, self.scale.cluster_size),
                jnp.float32)
            self.params, loss = self._step(
                self.params, batch, agg_w, jnp.asarray(self.interval),
                refresh)
        else:
            # star/local sync: the picks argument is unused inside
            picks = jnp.asarray(np.where(counts > 0, picks_np[:, 0], 0),
                                jnp.int32)
            self.params, loss = self._step(
                self.params, batch, picks, jnp.asarray(self.interval))
        self.ledger.record_aggregation(
            int(counts.sum()),
            uplink_delay_mults=faults.uplink_tail_mults(
                snap.delay_mult, picks_np, counts))
        self._record_interval_comms(snap, events)
        return loss

    def _hierarchical_interval(self, batch, kp, events: int):
        """One interval of the multi-stage fog hierarchy: the host
        resolves the event's per-level weight matrices and feeds their
        composed (R, R) device matrix to the once-compiled step."""
        from repro.hierarchy import build_event
        from repro.netsim import faults

        snap = None
        refresh = None
        if self.tvnet is not None:
            snap = self.tvnet.snapshot(self.interval + 1)
            refresh = (refresh_matrices(self._plan, snap.V)
                       if self._plan is not None else None)
            device_up = snap.device_up
        else:
            device_up = np.ones((self.scale.num_clusters,
                                 self.scale.cluster_size), bool)
        rng = np.random.default_rng(
            int(jax.random.randint(kp, (), 0, 2**31 - 1)))
        # tier-1 period == tau, so every interval fires depth >= 1
        ev = build_event(rng, self.tree, self.hierarchy,
                         (self.interval + 1) * self.scale.tau, device_up,
                         receive_offline=True)
        agg_m = jnp.asarray(ev.device_matrix)
        args = (self.params, batch, agg_m, jnp.asarray(self.interval))
        if refresh is not None:
            self.params, loss = self._step(*args, refresh)
        else:
            self.params, loss = self._step(*args)
        if ev.global_weights is not None and ev.total_uplinks:
            # a live root event just broadcast the root model to every
            # replica — snapshot it as the served global model
            self._global = jax.tree.map(lambda l: l[0], self.params)
        if ev.total_uplinks:
            self.ledger.record_hierarchy_event(
                ev.uplinks_by_level,
                uplink_delay_mults=(faults.uplink_tail_mults(
                    snap.delay_mult, ev.picks, ev.counts)
                    if snap is not None else None))
        if snap is not None:
            self._record_interval_comms(snap, events)
        else:
            self.ledger.record_consensus(
                [self.scale.gamma_d2d] * self.net.num_clusters * events,
                list(self.net.num_d2d_edges()) * events)
            self.ledger.record_local_step(
                self.scale.replicas * self.scale.tau)
        return loss

    def _record_interval_comms(self, snap, events: int):
        """Consensus + local-step ledger records for one dynamic
        interval (no active edges -> nothing is exchanged there)."""
        from repro.netsim import faults

        gammas = np.where(snap.num_active_edges() > 0,
                          self.scale.gamma_d2d, 0)
        self.ledger.record_consensus(
            list(gammas) * events,
            list(snap.num_active_edges()) * events,
            tail_mult_per_cluster=list(faults.consensus_tail_mult(
                snap.delay_mult, snap.device_up, snap.adj)) * events)
        self.ledger.record_local_step(
            int(snap.device_up.sum()) * self.scale.tau)

    def save(self, path: Optional[str] = None):
        p = path or str(Path(self.tcfg.ckpt_dir)
                        / f"interval_{self.interval:06d}.npz")
        Path(p).parent.mkdir(parents=True, exist_ok=True)
        # resume fidelity: the PRNG key, the comm ledger, and the data
        # stream positions all travel with the params — a restored run
        # continues exactly where an uninterrupted one would be
        extra = {
            "key": np.asarray(self.key),
            "train_draws": np.asarray(self._train_draws),
            "eval_draws": np.asarray(self._eval_draws),
            "ledger": {k: np.asarray(v) for k, v in
                       dataclasses.asdict(self.ledger).items()
                       if not isinstance(v, dict)},
            "uplinks_by_level": {
                str(k): np.asarray(v)
                for k, v in self.ledger.uplinks_by_level.items()},
        }
        if self.tree is not None:
            extra["global"] = self._global   # the served root snapshot
        save_train_state(p, self.params, (), self.interval, extra=extra)
        return p

    def restore(self, path: str):
        self.params, _, self.interval, extra = restore_train_state(path)
        if self.tree is not None:
            # the served root snapshot (pre-hierarchy checkpoints lack
            # it: fall back to replica 0, exact from the next root on)
            self._global = extra.get(
                "global", jax.tree.map(lambda l: l[0], self.params))
        if "key" in extra:
            self.key = jnp.asarray(extra["key"])
            self._train_draws = int(extra["train_draws"])
            self._eval_draws = int(extra["eval_draws"])
            for k, v in extra["ledger"].items():
                setattr(self.ledger, k, type(getattr(self.ledger, k))(v))
            self.ledger.uplinks_by_level = {
                int(k): int(v)
                for k, v in extra.get("uplinks_by_level", {}).items()}
            # fast-forward FRESH data streams past the consumed batches
            # (a reused trainer's generators may already be advanced;
            # the rng positions are only reachable by drawing, so resume
            # cost grows with training progress — fine at checkpointing
            # cadence, not for epoch-scale skips)
            self._make_gens()
            for _ in range(self._train_draws):
                for g in self._gens:
                    next(g)
            for _ in range(self._eval_draws):
                next(self._eval_gen)
        return self

    # ------------------------------------------------------------------
    def run(self, intervals: Optional[int] = None):
        if self.params is None:
            self.init()
        n = intervals if intervals is not None else self.tcfg.intervals
        events = (self.scale.tau // self.scale.consensus_every
                  if self.scale.consensus_every else 0)
        for _ in range(n):
            batch = self._interval_batch()
            self.key, kp = jax.random.split(self.key)
            if self.tree is not None:
                loss = self._hierarchical_interval(batch, kp, events)
            elif self.tvnet is None:
                picks = jax.random.randint(
                    kp, (self.net.num_clusters,), 0,
                    self.scale.cluster_size)
                self.params, loss = self._step(
                    self.params, batch, picks, jnp.asarray(self.interval))
                self.ledger.record_aggregation(self.net.num_clusters)
                self.ledger.record_consensus(
                    [self.scale.gamma_d2d] * self.net.num_clusters * events,
                    list(self.net.num_d2d_edges()) * events)
                self.ledger.record_local_step(
                    self.scale.replicas * self.scale.tau)
            else:
                loss = self._dynamic_interval(batch, kp, events)
            self.interval += 1
            logs = {"train_loss": float(loss),
                    "uplinks": self.ledger.uplinks,
                    "d2d_msgs": self.ledger.d2d_msgs}
            if self.tcfg.eval_every and \
                    self.interval % self.tcfg.eval_every == 0:
                logs["eval_loss"] = self.evaluate()
            self.metrics.log(self.interval, **logs)
            if self.tcfg.ckpt_every and \
                    self.interval % self.tcfg.ckpt_every == 0:
                self.save()
        return self
