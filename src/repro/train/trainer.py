"""Scale-mode trainer: the TT-HF interval loop with evaluation,
checkpointing, and metric logging — the production loop around
`core.distributed.make_tthf_train_step`.

Handles: data sharding per replica, interval batching
(tau x R x b x T), periodic held-out eval of the *global* (sampled)
model, checkpoint save/resume, and the communication ledger (uplink /
consensus event accounting mirroring the paper's cost model).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_train_state, save_train_state
from repro.configs.base import ModelConfig
from repro.core.distributed import (
    TTHFScaleConfig, make_tthf_train_step, stack_replicas)
from repro.core.energy import CommLedger
from repro.data.tokens import synthetic_token_batches
from repro.models import ModelApi, build_model
from repro.train.metrics import MetricLogger


@dataclass
class TrainerConfig:
    batch_per_replica: int = 4
    seq_len: int = 256
    intervals: int = 10
    eval_every: int = 5
    eval_batches: int = 2
    ckpt_every: int = 0             # 0 = off
    ckpt_dir: str = "checkpoints"
    log_path: Optional[str] = None
    dtype: str = "float32"
    seed: int = 0


class ScaleTrainer:
    def __init__(self, cfg: ModelConfig, scale: TTHFScaleConfig,
                 tcfg: TrainerConfig, sync: str = "tthf"):
        self.cfg = cfg
        self.scale = scale
        self.tcfg = tcfg
        self.model: ModelApi = build_model(cfg)
        dtype = jnp.float32 if tcfg.dtype == "float32" else jnp.bfloat16
        step, self.net = make_tthf_train_step(
            self.model, scale, dtype=dtype, sync=sync)
        self._step = jax.jit(step)
        self._eval_loss = jax.jit(
            lambda p, b: self.model.loss(p, b, dtype=dtype, remat=False))
        self.ledger = CommLedger()
        self.metrics = MetricLogger(tcfg.log_path)
        self.key = jax.random.PRNGKey(tcfg.seed)
        self._gens = [synthetic_token_batches(
            tcfg.batch_per_replica, tcfg.seq_len, cfg.vocab_size,
            seed=tcfg.seed, shard_id=r) for r in range(scale.replicas)]
        self._eval_gen = synthetic_token_batches(
            tcfg.batch_per_replica, tcfg.seq_len, cfg.vocab_size,
            seed=tcfg.seed + 10_000, shard_id=99)
        self.params = None
        self.interval = 0

    # ------------------------------------------------------------------
    def init(self):
        self.params = stack_replicas(
            self.model.init(jax.random.PRNGKey(self.tcfg.seed)),
            self.scale.replicas)
        return self

    def _interval_batch(self):
        tau, R = self.scale.tau, self.scale.replicas
        mbs = [[next(g) for _ in range(tau)] for g in self._gens]
        return {k: jnp.asarray(np.stack(
            [[mbs[r][t][k] for r in range(R)] for t in range(tau)]))
            for k in ("tokens", "labels")}

    def _global_params(self):
        """Replica 0's copy — identical to all others right after the
        interval's aggregation (asserted in tests)."""
        return jax.tree.map(lambda l: l[0], self.params)

    def evaluate(self) -> float:
        g = self._global_params()
        losses = []
        for _ in range(self.tcfg.eval_batches):
            b = next(self._eval_gen)
            losses.append(float(self._eval_loss(
                g, {k: jnp.asarray(v) for k, v in b.items()})))
        return float(np.mean(losses))

    def save(self, path: Optional[str] = None):
        p = path or str(Path(self.tcfg.ckpt_dir)
                        / f"interval_{self.interval:06d}.npz")
        Path(p).parent.mkdir(parents=True, exist_ok=True)
        save_train_state(p, self.params, (), self.interval)
        return p

    def restore(self, path: str):
        self.params, _, self.interval, _ = restore_train_state(path)
        return self

    # ------------------------------------------------------------------
    def run(self, intervals: Optional[int] = None):
        if self.params is None:
            self.init()
        n = intervals if intervals is not None else self.tcfg.intervals
        events = (self.scale.tau // self.scale.consensus_every
                  if self.scale.consensus_every else 0)
        for _ in range(n):
            batch = self._interval_batch()
            self.key, kp = jax.random.split(self.key)
            picks = jax.random.randint(
                kp, (self.net.num_clusters,), 0, self.scale.cluster_size)
            self.params, loss = self._step(
                self.params, batch, picks, jnp.asarray(self.interval))
            self.interval += 1
            self.ledger.record_aggregation(self.net.num_clusters)
            self.ledger.record_consensus(
                [self.scale.gamma_d2d] * self.net.num_clusters * events,
                list(self.net.num_d2d_edges()) * events)
            self.ledger.record_local_step(
                self.scale.replicas * self.scale.tau)
            logs = {"train_loss": float(loss),
                    "uplinks": self.ledger.uplinks,
                    "d2d_msgs": self.ledger.d2d_msgs}
            if self.tcfg.eval_every and \
                    self.interval % self.tcfg.eval_every == 0:
                logs["eval_loss"] = self.evaluate()
            self.metrics.log(self.interval, **logs)
            if self.tcfg.ckpt_every and \
                    self.interval % self.tcfg.ckpt_every == 0:
                self.save()
        return self
