"""Scale-mode trainer: the TT-HF interval loop with evaluation,
checkpointing, and metric logging — the production loop around
`core.distributed.make_tthf_train_step`.

Handles: data sharding per replica, interval batching
(tau x R x b x T), periodic held-out eval of the *global* (sampled)
model, checkpoint save/resume, and the communication ledger. Every
scenario — static, netsim dynamics, fog hierarchy, compositions —
runs through ONE ``_interval``: the
:class:`~repro.rounds.resolver.RoundResolver` turns the declarative
:class:`~repro.rounds.program.RoundProgram` into the step's
aggregation argument, the optional consensus-matrix refresh, and one
:class:`~repro.rounds.program.Billing` record (DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_train_state, save_train_state
from repro.configs.base import DynamicsConfig, HierarchyConfig, ModelConfig
from repro.core.distributed import (
    TTHFScaleConfig, make_tthf_train_step, stack_replicas)
from repro.core.energy import CommLedger
from repro.core.mixing import build_mixing_plan
from repro.data.tokens import synthetic_token_batches
from repro.models import ModelApi, build_model
from repro.obs.sink import make_obs
from repro.rounds import RoundProgram, RoundResolver
from repro.train.metrics import MetricLogger
from repro.train.prefetch import PrefetchLoader

# the only dtypes the microstep math supports; anything else (a typo'd
# "float16") used to silently coerce to bfloat16
_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclass
class TrainerConfig:
    batch_per_replica: int = 4
    seq_len: int = 256
    intervals: int = 10
    eval_every: int = 5
    eval_batches: int = 2
    ckpt_every: int = 0             # 0 = off
    ckpt_dir: str = "checkpoints"
    log_path: Optional[str] = None
    dtype: str = "float32"
    seed: int = 0
    # raw-speed knobs (DESIGN.md §12) — all preserve trajectories
    # bitwise; flip off to A/B against the straight-line path
    donate: bool = True             # donate params+batch buffers to the
                                    # jitted step (halves peak param HBM)
    fused_interval: bool = False    # flat (R, P) param carrier + fused
                                    # SGD+consensus block-ends
    prefetch: bool = True           # build/transfer interval k+1's
                                    # batch while interval k computes
    # observability (repro.obs, DESIGN.md §13): a trace dir turns on
    # the span tracer + theory-bound telemetry stream + run manifest;
    # profile additionally wraps the run in jax.profiler.trace
    trace_dir: Optional[str] = None
    profile: bool = False

    def __post_init__(self):
        if self.dtype not in _DTYPES:
            raise ValueError(
                f"unknown dtype {self.dtype!r}; expected one of "
                f"{sorted(_DTYPES)}")


class ScaleTrainer:
    def __init__(self, cfg: ModelConfig, scale: TTHFScaleConfig,
                 tcfg: TrainerConfig, sync: str = "tthf",
                 dynamics: Optional[DynamicsConfig] = None,
                 hierarchy: Optional[HierarchyConfig] = None,
                 program: Optional[RoundProgram] = None):
        self.cfg = cfg
        self.scale = scale
        self.tcfg = tcfg
        self.model: ModelApi = build_model(cfg)
        dtype = _DTYPES[tcfg.dtype]
        # the declarative round program (DESIGN.md §10): a static (or
        # absent) dynamics config and a flat (L = 2) hierarchy resolve
        # to the exact historical code path bit-for-bit; the
        # ``dynamics``/``hierarchy`` kwargs are sugar for a program
        if program is None:
            program = RoundProgram(dynamics=dynamics, hierarchy=hierarchy)
        else:
            assert dynamics is None and hierarchy is None, \
                "pass either program= or the dynamics=/hierarchy= sugar " \
                "kwargs, not both (the kwargs would be silently ignored)"
        self.program = program
        if program.is_hierarchical:
            assert sync == "tthf", "hierarchy implies tthf sync"
        # only a tthf step carries consensus matrices to refresh; the
        # event stream ticks once per aggregation interval and each
        # interval's matrices are fed to the (once-traced) step
        refreshable = program.is_dynamic and sync == "tthf"
        step, self.net = make_tthf_train_step(
            self.model, scale, dtype=dtype, sync=sync,
            refreshable=refreshable, hierarchy=program.hierarchy,
            fused_interval=tcfg.fused_interval)
        # fused-interval runs carry self.params as the step's flat
        # (R, P) buffer; the spec unflattens at eval/checkpoint/serving
        # boundaries (checkpoints stay in the pytree format either way)
        self._spec = getattr(step, "spec", None)
        self._plan = None
        if refreshable:
            self._plan = build_mixing_plan(
                self.net, scale.gamma_d2d, backend=scale.consensus_mode)
        self._resolver = RoundResolver.for_scale(self.net, scale, program,
                                                 plan=self._plan)
        self.hierarchy = self._resolver.hierarchy
        self.tree = self._resolver.tree
        self.tvnet = self._resolver.tvnet
        # donation contract (DESIGN.md §12): once a step is dispatched,
        # the params (and batch) buffers passed in belong to XLA — the
        # trainer rebinds self.params to the output before anyone reads
        # it, and every consumer (eval/save/serving) goes through that
        # rebound value. Holders of pre-step references must copy.
        # The int32 batch can never alias the f32 outputs, so donating
        # it only frees its buffer for scratch — silence the per-compile
        # "not usable" nag about exactly that.
        if tcfg.donate:
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
        self._step = jax.jit(
            step, donate_argnums=(0, 1) if tcfg.donate else ())
        self._eval_loss = jax.jit(
            lambda p, b: self.model.loss(p, b, dtype=dtype, remat=False))
        self.ledger = CommLedger()
        self.metrics = MetricLogger(tcfg.log_path)
        # observability sink (NULL_OBS when trace_dir unset): spans,
        # theory-bound telemetry, manifest. Probes are built lazily at
        # init() (they need the materialized params) and are read-only
        # — instrumented trajectories are bitwise the uninstrumented
        # ones (tests/test_obs.py).
        self.obs = make_obs(
            tcfg.trace_dir, profile=tcfg.profile, run_name="train-scale",
            config={"model": cfg, "scale": scale, "trainer": tcfg},
            extra={"arch": cfg.name, "sync": sync})
        self._resolver.obs = self.obs
        self._obs_probe = None
        self._obs_grad_probe = None
        self._obs_gauges = None
        self._obs_gen = None        # dedicated grad-probe batch stream
        self.key = jax.random.PRNGKey(tcfg.seed)
        self._make_gens()
        # resume fidelity: batches drawn so far from every train
        # generator (identical across replicas) and from the eval
        # stream — persisted so restore-and-continue replays neither
        self._train_draws = 0
        self._eval_draws = 0
        self.params = None
        # hierarchical runs: the SERVED global model — materialized
        # only when the root tier fires (between root events replicas
        # under different fog nodes legitimately disagree)
        self._global = None
        self.interval = 0

    def _make_gens(self, train_start: int = 0, eval_start: int = 0):
        """(Re)build the token streams, optionally already seeked past
        the first ``train_start``/``eval_start`` draws — restore uses
        this for O(1) resume instead of replaying consumed batches."""
        tcfg, cfg = self.tcfg, self.cfg
        self._gens = [synthetic_token_batches(
            tcfg.batch_per_replica, tcfg.seq_len, cfg.vocab_size,
            seed=tcfg.seed, shard_id=r, start=train_start)
            for r in range(self.scale.replicas)]
        self._eval_gen = synthetic_token_batches(
            tcfg.batch_per_replica, tcfg.seq_len, cfg.vocab_size,
            seed=tcfg.seed + 10_000, shard_id=99, start=eval_start)

    # ------------------------------------------------------------------
    def init(self):
        init_params = self.model.init(jax.random.PRNGKey(self.tcfg.seed))
        self.params = stack_replicas(init_params, self.scale.replicas)
        if self._spec is not None:
            self.params = self._spec.flatten(self.params)
        self._global = init_params
        return self

    def _build_interval_batch(self):
        """Pure batch build — no draw accounting (the prefetch worker
        calls this off-thread; draws are counted at consumption)."""
        tau, R = self.scale.tau, self.scale.replicas
        mbs = [[next(g) for _ in range(tau)] for g in self._gens]
        return {k: jnp.asarray(np.stack(
            [[mbs[r][t][k] for r in range(R)] for t in range(tau)]))
            for k in ("tokens", "labels")}

    def _interval_batch(self):
        batch = self._build_interval_batch()
        self._train_draws += self.scale.tau
        return batch

    def _replica0(self):
        """Replica 0's per-replica param pytree (either carrier)."""
        if self._spec is not None:
            return self._spec.unflatten_one(self.params[0])
        return jax.tree.map(lambda l: l[0], self.params)

    def _global_params(self):
        """The served global model. Flat runs: replica 0's copy —
        identical to all others right after the interval's aggregation
        (asserted in tests). Hierarchical runs: the root-tier snapshot
        (the initial broadcast until the root first fires — replicas
        under different fog nodes disagree between root events)."""
        if self.tree is not None:
            return self._global
        return self._replica0()

    def evaluate(self) -> float:
        g = self._global_params()
        losses = []
        for _ in range(self.tcfg.eval_batches):
            b = next(self._eval_gen)
            self._eval_draws += 1
            losses.append(float(self._eval_loss(
                g, {k: jnp.asarray(v) for k, v in b.items()})))
        return float(np.mean(losses))

    # ------------------------------------------------------------------
    # observability (DESIGN.md §13)
    # ------------------------------------------------------------------
    def _ensure_obs(self):
        from repro.obs.telemetry import (
            TheoryGauges, default_constants, make_divergence_probe,
            make_scale_grad_probe)

        if self._obs_probe is not None:
            return
        self._obs_probe = make_divergence_probe(
            self.scale.num_clusters, self.scale.cluster_size,
            self.net.varrho)
        self._obs_grad_probe = make_scale_grad_probe(
            self.model, _DTYPES[self.tcfg.dtype])
        # a dedicated probe stream: grad-norm batches never touch the
        # train/eval draws, so the data trajectory is unchanged
        self._obs_gen = synthetic_token_batches(
            self.tcfg.batch_per_replica, self.tcfg.seq_len,
            self.cfg.vocab_size, seed=self.tcfg.seed + 20_000,
            shard_id=98)
        model_dim = int(sum(np.prod(l.shape) for l in
                            jax.tree.leaves(self._replica0())))
        self._obs_gauges = TheoryGauges(
            constants=default_constants(float(np.min(self.net.varrho))),
            tau=self.scale.tau, model_dim=model_dim, lr=self.scale.lr)

    def _emit_interval_telemetry(self, loss, ledger_mark):
        """One fenced drain per interval: block on the step's loss, run
        the jitted probe over the (donated-output) params, and emit
        measured divergence + theory gauges + comms attribution into
        the shared JSONL stream. ``self.interval`` is still the 0-based
        index of the interval that just ran."""
        obs = self.obs
        jax.block_until_ready(loss)
        aux = {k: np.asarray(v)
               for k, v in self._obs_probe(self.params).items()}
        tau = self.scale.tau
        t = (self.interval + 1) * tau
        rec = {"train_loss": float(loss), **aux}
        rec.update(self._obs_gauges.round_gauges(t, t - tau))
        if self.scale.consensus_every:
            N = self.scale.num_clusters
            rec["gamma_used"] = np.full((N,), self.scale.gamma_d2d)
            rec["lemma1_bound"] = self._obs_gauges.lemma1(
                self.net.lambdas, rec["gamma_used"],
                self.scale.cluster_size, aux["upsilon"])
        obs.emit("round", self.interval + 1, **rec)
        rows = self.ledger.attribution_since(ledger_mark)
        if rows:
            up_lv, d2d_cl = {}, {}
            ups = msgs = rounds = 0
            for r in rows:
                if r["kind"] == "uplink":
                    ups += r["n"]
                    up_lv[r["level"]] = up_lv.get(r["level"], 0) + r["n"]
                elif r["kind"] == "consensus":
                    msgs += r["msgs"]
                    rounds += r["rounds"]
                    c = r["cluster"]
                    d2d_cl[c] = d2d_cl.get(c, 0) + r["msgs"]
            obs.emit("comm", self.interval + 1, uplinks=ups,
                     uplinks_by_level=up_lv, d2d_msgs=msgs,
                     d2d_rounds=rounds, d2d_msgs_by_cluster=d2d_cl,
                     event=self.ledger._event_idx)
        obs.counter("ledger", uplinks=self.ledger.uplinks,
                    d2d_msgs=self.ledger.d2d_msgs,
                    local_steps=self.ledger.local_steps)

    def _interval(self, batch, kp):
        """ONE interval for every scenario: the resolver supplies the
        step's aggregation argument (picks / (N, s) weight matrix /
        composed (R, R) device matrix — whichever form the step was
        built for), the optional per-aggregation-round consensus-matrix
        refresh, and the interval's full bill."""
        obs = self.obs
        ledger_mark = len(self.ledger.events)
        ev = self._resolver.resolve_interval(self.interval, kp)
        args = (self.params, batch, ev.agg, jnp.asarray(self.interval))
        with obs.span("interval", interval=self.interval,
                      tau=self.scale.tau):
            if ev.refresh is not None:
                self.params, loss = self._step(*args, ev.refresh)
            else:
                self.params, loss = self._step(*args)
            if obs.enabled:
                jax.block_until_ready(loss)
        if ev.root_served:
            # a live root event just broadcast the root model to every
            # replica — snapshot it as the served global model
            self._global = self._replica0()
        ev.billing.charge(self.ledger)
        if obs.enabled:
            # the jitted interval folds its consensus/aggregation
            # events into one dispatch — mark them as instants so the
            # trace still shows the two timescales
            if ev.billing.consensus_repeats and \
                    ev.billing.consensus_edges is not None:
                obs.instant("consensus_event", interval=self.interval,
                            repeats=ev.billing.consensus_repeats)
            if ev.billing.uplinks_by_level:
                obs.instant("aggregation", interval=self.interval,
                            uplinks_by_level=ev.billing.uplinks_by_level,
                            root_served=ev.root_served)
            self._emit_interval_telemetry(loss, ledger_mark)
        return loss

    def save(self, path: Optional[str] = None):
        p = path or str(Path(self.tcfg.ckpt_dir)
                        / f"interval_{self.interval:06d}.npz")
        Path(p).parent.mkdir(parents=True, exist_ok=True)
        # resume fidelity: the PRNG key, the comm ledger, and the data
        # stream positions all travel with the params — a restored run
        # continues exactly where an uninterrupted one would be
        extra = {
            "key": np.asarray(self.key),
            "train_draws": np.asarray(self._train_draws),
            "eval_draws": np.asarray(self._eval_draws),
            "ledger": {k: np.asarray(v) for k, v in
                       dataclasses.asdict(self.ledger).items()
                       if not isinstance(v, (dict, list))},
            "uplinks_by_level": {
                str(k): np.asarray(v)
                for k, v in self.ledger.uplinks_by_level.items()},
        }
        if self.tree is not None:
            extra["global"] = self._global   # the served root snapshot
        # checkpoints always hold the pytree form — fused and straight
        # runs read each other's checkpoints freely
        params = (self._spec.unflatten(self.params)
                  if self._spec is not None else self.params)
        save_train_state(p, params, (), self.interval, extra=extra)
        return p

    def restore(self, path: str):
        self.params, _, self.interval, extra = restore_train_state(path)
        if self._spec is not None:
            self.params = self._spec.flatten(self.params)
        if self.tree is not None:
            # the served root snapshot (pre-hierarchy checkpoints lack
            # it: fall back to replica 0, exact from the next root on)
            self._global = extra.get("global", self._replica0())
        if "key" in extra:
            self.key = jnp.asarray(extra["key"])
            self._train_draws = int(extra["train_draws"])
            self._eval_draws = int(extra["eval_draws"])
            for k, v in extra["ledger"].items():
                setattr(self.ledger, k, type(getattr(self.ledger, k))(v))
            self.ledger.uplinks_by_level = {
                int(k): int(v)
                for k, v in extra.get("uplinks_by_level", {}).items()}
            # rebuild FRESH data streams already seeked past the
            # consumed batches (a reused trainer's generators may have
            # advanced). The seek is O(1) — the streams are
            # offset-addressable — so resume cost no longer grows with
            # training progress.
            self._make_gens(train_start=self._train_draws,
                            eval_start=self._eval_draws)
        return self

    # ------------------------------------------------------------------
    def run(self, intervals: Optional[int] = None):
        if self.params is None:
            self.init()
        obs = self.obs
        if obs.enabled:
            self._ensure_obs()
        n = intervals if intervals is not None else self.tcfg.intervals
        loader = None
        if self.tcfg.prefetch and n > 1:
            # interval k+1's batch builds/transfers while k computes;
            # draws are counted HERE per consumed batch, so a mid-run
            # checkpoint never includes the in-flight prefetched batch
            loader = PrefetchLoader(self._build_interval_batch, depth=1)
        try:
            with obs.span("run", intervals=n, tau=self.scale.tau,
                          replicas=self.scale.replicas):
                for _ in range(n):
                    with obs.span("round", interval=self.interval):
                        if loader is not None:
                            batch = loader.get()
                            self._train_draws += self.scale.tau
                        else:
                            batch = self._interval_batch()
                        self.key, kp = jax.random.split(self.key)
                        loss = self._interval(batch, kp)
                        self.interval += 1
                        logs = {"train_loss": float(loss),
                                "uplinks": self.ledger.uplinks,
                                "d2d_msgs": self.ledger.d2d_msgs}
                        if self.tcfg.eval_every and \
                                self.interval % self.tcfg.eval_every == 0:
                            with obs.span("eval", interval=self.interval):
                                logs["eval_loss"] = self.evaluate()
                            if obs.enabled:
                                b = {k: jnp.asarray(v) for k, v in
                                     next(self._obs_gen).items()}
                                logs["grad_norm"] = float(
                                    self._obs_grad_probe(
                                        self._global_params(), b))
                                obs.emit("eval", self.interval, **logs)
                        self.metrics.log(self.interval, **logs)
                        if self.tcfg.ckpt_every and \
                                self.interval % self.tcfg.ckpt_every == 0:
                            self.save()
        finally:
            if loader is not None:
                loader.close()
            obs.flush()
        return self

    def close(self):
        """Flush + close the metric and observability sinks (exports
        the Chrome trace when a trace dir is set)."""
        self.metrics.close()
        self.obs.close()
