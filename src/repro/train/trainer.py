"""Scale-mode trainer: the TT-HF interval loop with evaluation,
checkpointing, and metric logging — the production loop around
`core.distributed.make_tthf_train_step`.

Handles: data sharding per replica, interval batching
(tau x R x b x T), periodic held-out eval of the *global* (sampled)
model, checkpoint save/resume, and the communication ledger (uplink /
consensus event accounting mirroring the paper's cost model).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_train_state, save_train_state
from repro.configs.base import DynamicsConfig, ModelConfig
from repro.core.distributed import (
    TTHFScaleConfig, make_tthf_train_step, stack_replicas)
from repro.core.energy import CommLedger
from repro.core.mixing import build_mixing_plan, refresh_matrices
from repro.data.tokens import synthetic_token_batches
from repro.models import ModelApi, build_model
from repro.train.metrics import MetricLogger


@dataclass
class TrainerConfig:
    batch_per_replica: int = 4
    seq_len: int = 256
    intervals: int = 10
    eval_every: int = 5
    eval_batches: int = 2
    ckpt_every: int = 0             # 0 = off
    ckpt_dir: str = "checkpoints"
    log_path: Optional[str] = None
    dtype: str = "float32"
    seed: int = 0


class ScaleTrainer:
    def __init__(self, cfg: ModelConfig, scale: TTHFScaleConfig,
                 tcfg: TrainerConfig, sync: str = "tthf",
                 dynamics: Optional[DynamicsConfig] = None):
        self.cfg = cfg
        self.scale = scale
        self.tcfg = tcfg
        self.model: ModelApi = build_model(cfg)
        dtype = jnp.float32 if tcfg.dtype == "float32" else jnp.bfloat16
        # netsim dynamics: the event stream ticks once per aggregation
        # interval; each interval's consensus matrices are refreshed on
        # the active subgraph and fed to the (once-traced) step
        self.tvnet = None
        self._plan = None
        dynamic = dynamics is not None and not dynamics.is_static
        # only a tthf step carries consensus matrices to refresh
        refreshable = dynamic and sync == "tthf"
        step, self.net = make_tthf_train_step(
            self.model, scale, dtype=dtype, sync=sync,
            refreshable=refreshable)
        if dynamic:
            from repro.netsim.dynamics import TimeVaryingNetwork
            self.tvnet = TimeVaryingNetwork(self.net, dynamics)
        if refreshable:
            self._plan = build_mixing_plan(
                self.net, scale.gamma_d2d, backend=scale.consensus_mode)
        self._step = jax.jit(step)
        self._eval_loss = jax.jit(
            lambda p, b: self.model.loss(p, b, dtype=dtype, remat=False))
        self.ledger = CommLedger()
        self.metrics = MetricLogger(tcfg.log_path)
        self.key = jax.random.PRNGKey(tcfg.seed)
        self._gens = [synthetic_token_batches(
            tcfg.batch_per_replica, tcfg.seq_len, cfg.vocab_size,
            seed=tcfg.seed, shard_id=r) for r in range(scale.replicas)]
        self._eval_gen = synthetic_token_batches(
            tcfg.batch_per_replica, tcfg.seq_len, cfg.vocab_size,
            seed=tcfg.seed + 10_000, shard_id=99)
        self.params = None
        self.interval = 0

    # ------------------------------------------------------------------
    def init(self):
        self.params = stack_replicas(
            self.model.init(jax.random.PRNGKey(self.tcfg.seed)),
            self.scale.replicas)
        return self

    def _interval_batch(self):
        tau, R = self.scale.tau, self.scale.replicas
        mbs = [[next(g) for _ in range(tau)] for g in self._gens]
        return {k: jnp.asarray(np.stack(
            [[mbs[r][t][k] for r in range(R)] for t in range(tau)]))
            for k in ("tokens", "labels")}

    def _global_params(self):
        """Replica 0's copy — identical to all others right after the
        interval's aggregation (asserted in tests)."""
        return jax.tree.map(lambda l: l[0], self.params)

    def evaluate(self) -> float:
        g = self._global_params()
        losses = []
        for _ in range(self.tcfg.eval_batches):
            b = next(self._eval_gen)
            losses.append(float(self._eval_loss(
                g, {k: jnp.asarray(v) for k, v in b.items()})))
        return float(np.mean(losses))

    def _dynamic_interval(self, batch, kp, events: int):
        """One interval under netsim dynamics: per-aggregation-round W
        refresh on the active subgraph, availability-aware picks, and
        straggler-aware ledger records."""
        from repro.netsim import faults

        snap = self.tvnet.snapshot(self.interval + 1)
        refresh = (refresh_matrices(self._plan, snap.V)
                   if self._plan is not None else None)
        rng = np.random.default_rng(
            int(jax.random.randint(kp, (), 0, 2**31 - 1)))
        picks_np, counts = faults.availability_sample(
            rng, snap.device_up, k=self.scale.sample_per_cluster)
        # the jitted aggregation takes one representative per cluster;
        # a dark cluster's substitute pick carries weight 0 through the
        # event's renormalized varrho, matching the sim path
        picks = jnp.asarray(np.where(counts > 0, picks_np[:, 0], 0),
                            jnp.int32)
        args = (self.params, batch, picks, jnp.asarray(self.interval))
        if refresh is not None:
            self.params, loss = self._step(
                *args, refresh, jnp.asarray(snap.varrho, jnp.float32))
        else:
            self.params, loss = self._step(*args)
        self.ledger.record_aggregation(
            int(counts.sum()),
            uplink_delay_mults=faults.uplink_tail_mults(
                snap.delay_mult, picks_np, counts))
        # no active edges -> nothing is exchanged: bill 0 rounds there
        gammas = np.where(snap.num_active_edges() > 0,
                          self.scale.gamma_d2d, 0)
        self.ledger.record_consensus(
            list(gammas) * events,
            list(snap.num_active_edges()) * events,
            tail_mult_per_cluster=list(faults.consensus_tail_mult(
                snap.delay_mult, snap.device_up, snap.adj)) * events)
        self.ledger.record_local_step(
            int(snap.device_up.sum()) * self.scale.tau)
        return loss

    def save(self, path: Optional[str] = None):
        p = path or str(Path(self.tcfg.ckpt_dir)
                        / f"interval_{self.interval:06d}.npz")
        Path(p).parent.mkdir(parents=True, exist_ok=True)
        save_train_state(p, self.params, (), self.interval)
        return p

    def restore(self, path: str):
        self.params, _, self.interval, _ = restore_train_state(path)
        return self

    # ------------------------------------------------------------------
    def run(self, intervals: Optional[int] = None):
        if self.params is None:
            self.init()
        n = intervals if intervals is not None else self.tcfg.intervals
        events = (self.scale.tau // self.scale.consensus_every
                  if self.scale.consensus_every else 0)
        for _ in range(n):
            batch = self._interval_batch()
            self.key, kp = jax.random.split(self.key)
            if self.tvnet is None:
                picks = jax.random.randint(
                    kp, (self.net.num_clusters,), 0,
                    self.scale.cluster_size)
                self.params, loss = self._step(
                    self.params, batch, picks, jnp.asarray(self.interval))
                self.ledger.record_aggregation(self.net.num_clusters)
                self.ledger.record_consensus(
                    [self.scale.gamma_d2d] * self.net.num_clusters * events,
                    list(self.net.num_d2d_edges()) * events)
                self.ledger.record_local_step(
                    self.scale.replicas * self.scale.tau)
            else:
                loss = self._dynamic_interval(batch, kp, events)
            self.interval += 1
            logs = {"train_loss": float(loss),
                    "uplinks": self.ledger.uplinks,
                    "d2d_msgs": self.ledger.d2d_msgs}
            if self.tcfg.eval_every and \
                    self.interval % self.tcfg.eval_every == 0:
                logs["eval_loss"] = self.evaluate()
            self.metrics.log(self.interval, **logs)
            if self.tcfg.ckpt_every and \
                    self.interval % self.tcfg.ckpt_every == 0:
                self.save()
        return self
