"""Double-buffered host pipeline for interval batches (DESIGN.md §12).

The synchronous trainer loop serializes three phases per interval:
build the (tau, R, b, T) batch on host (python generators + np.stack),
transfer it to the devices, then run the jitted interval step. The
step dominates, so the host work can hide entirely under it:
:class:`PrefetchLoader` runs the build+transfer in a daemon thread and
keeps up to ``depth`` ready batches in a bounded queue — interval
k+1's batch materializes while interval k computes.

Determinism contract (asserted in ``tests/test_fused_interval.py``):
the worker calls the SAME build function the synchronous path uses, on
the same generators, strictly in order, from one thread — a prefetched
run consumes byte-identical batches in the identical order. Draw
accounting stays with the CONSUMER (``ScaleTrainer.run`` counts draws
per batch it pops), so checkpoints never include batches that were
prefetched but not yet trained on; a restore rebuilds the generators at
the consumed position and simply discards the in-flight batch.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

import jax

_SENTINEL = object()


class PrefetchLoader:
    """Pulls batches from ``build`` in a background thread.

    build:  zero-arg callable returning the next batch (or raising
            ``StopIteration`` to end the stream).
    depth:  max batches in flight (1 = classic double buffering: one
            batch computing, one building).
    put:    optional device-placement callable applied to each built
            batch IN THE WORKER (e.g. ``jax.device_put`` to the batch
            sharding) so the H2D transfer also overlaps compute; the
            default commits to the default device.

    Use as a context manager or call :meth:`close` — the worker is a
    daemon thread either way, so an abandoned loader cannot hang
    interpreter exit.
    """

    def __init__(self, build: Callable[[], object], depth: int = 1,
                 put: Optional[Callable[[object], object]] = None):
        assert depth >= 1
        self._build = build
        self._put = jax.device_put if put is None else put
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._worker, name="interval-prefetch", daemon=True)
        self._thread.start()

    def _worker(self):
        try:
            while not self._stop.is_set():
                try:
                    batch = self._put(self._build())
                except StopIteration:
                    break
                # bounded put that stays responsive to close()
                while not self._stop.is_set():
                    try:
                        self._q.put(batch, timeout=0.05)
                        break
                    except queue.Full:
                        continue
                else:
                    return
        except BaseException as e:        # surfaced on the next get()
            self._err = e
        finally:
            while True:                   # wake any blocked consumer
                try:
                    self._q.put_nowait(_SENTINEL)
                    break
                except queue.Full:
                    try:
                        self._q.get_nowait()
                    except queue.Empty:
                        pass

    def get(self):
        """Next batch, in build order. Raises the worker's exception if
        it died, ``StopIteration`` when the stream ended."""
        item = self._q.get()
        if item is _SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        """Stop the worker and drop any prefetched batches."""
        self._stop.set()
        self._thread.join(timeout=5.0)
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
