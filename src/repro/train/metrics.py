"""Structured metric logging: in-memory ring + JSONL sink + console.

No external deps (no tensorboard/wandb offline) — JSONL is greppable
and loads straight into numpy/pandas.
"""
from __future__ import annotations

import json
import time
from collections import deque
from pathlib import Path
from typing import Any, Optional


class MetricLogger:
    """Usable bare or as a context manager (closes the JSONL handle);
    ``window`` sizes the smoothing ring. This is also the JSONL sink
    behind :class:`repro.obs.sink.Observability` — every record kind
    (train / theory / comm / serve) shares one stream."""

    def __init__(self, out_path: Optional[str] = None,
                 console_every: int = 1, window: int = 100):
        self.out = Path(out_path) if out_path else None
        if self.out:
            self.out.parent.mkdir(parents=True, exist_ok=True)
            self._fh = self.out.open("a")
        else:
            self._fh = None
        self.console_every = console_every
        self.window = int(window)
        self._recent: dict[str, deque] = {}
        self._t0 = time.time()
        self._n = 0

    def log(self, step: int, **metrics: Any) -> None:
        rec = {"step": int(step), "wall_s": round(time.time() - self._t0, 2)}
        for k, v in metrics.items():
            v = float(v) if hasattr(v, "__float__") else v
            rec[k] = v
            if isinstance(v, float):
                self._recent.setdefault(
                    k, deque(maxlen=self.window)).append(v)
        if self._fh:
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()
        self._n += 1
        if self.console_every and self._n % self.console_every == 0:
            kv = " ".join(f"{k}={v:.4f}" if isinstance(v, float)
                          else f"{k}={v}" for k, v in rec.items()
                          if k not in ("wall_s",))
            print(f"[{rec['wall_s']:8.1f}s] {kv}", flush=True)

    def smoothed(self, key: str) -> float:
        vals = self._recent.get(key)
        return sum(vals) / len(vals) if vals else float("nan")

    def close(self) -> None:
        if self._fh:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "MetricLogger":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
