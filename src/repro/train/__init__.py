from repro.train.trainer import ScaleTrainer, TrainerConfig
from repro.train.metrics import MetricLogger
from repro.train.prefetch import PrefetchLoader

__all__ = ["ScaleTrainer", "TrainerConfig", "MetricLogger",
           "PrefetchLoader"]
