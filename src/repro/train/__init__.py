from repro.train.trainer import ScaleTrainer, TrainerConfig
from repro.train.metrics import MetricLogger

__all__ = ["ScaleTrainer", "TrainerConfig", "MetricLogger"]
