from repro.data.synth import fashion_synth, FederatedDataset
from repro.data.partition import partition_noniid_labels, partition_iid
from repro.data.tokens import synthetic_token_batches, lm_batch_spec

__all__ = [
    "fashion_synth", "FederatedDataset",
    "partition_noniid_labels", "partition_iid",
    "synthetic_token_batches", "lm_batch_spec",
]
