"""Synthetic Fashion-MNIST analogue (offline container: no downloads).

``fashion_synth`` generates a 10-class, 784-dim image-like dataset from
class-conditional low-rank Gaussians + structured templates. It matches
Fashion-MNIST's shape/cardinality so the paper's experiment configs
(I=125 devices, 3-labels-per-device non-iid splits) transfer verbatim,
and is hard enough that a linear SVM does not saturate instantly.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class FederatedDataset:
    """Per-device data after partitioning.

    x: (I, D_i, m) float32 — padded per-device datasets
    y: (I, D_i) int32 — labels
    counts: (I,) int32 — true per-device counts (<= D_i pad size)
    """
    x: np.ndarray
    y: np.ndarray
    counts: np.ndarray
    num_classes: int

    @property
    def num_devices(self) -> int:
        return self.x.shape[0]

    @property
    def feature_dim(self) -> int:
        return self.x.shape[-1]


def fashion_synth(num_points: int = 70_000, dim: int = 784,
                  num_classes: int = 10, rank: int = 24,
                  noise: float = 0.35, seed: int = 0,
                  unit_norm: bool = False,
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Class-conditional low-rank Gaussian images.

    Each class c has a template mu_c (smooth random field) and a shared
    low-rank factor basis; samples are
    x = mu_c + B @ z + noise * eps, clipped to [0, 1] like pixel data.
    """
    rng = np.random.default_rng(seed)
    side = int(np.sqrt(dim))
    assert side * side == dim, "dim must be a perfect square"

    # smooth class templates: filtered random fields
    templates = []
    for c in range(num_classes):
        field = rng.normal(size=(side, side))
        # cheap smoothing: two passes of 3x3 box filter
        for _ in range(3):
            f = np.pad(field, 1, mode="edge")
            field = (
                f[:-2, :-2] + f[:-2, 1:-1] + f[:-2, 2:] +
                f[1:-1, :-2] + f[1:-1, 1:-1] + f[1:-1, 2:] +
                f[2:, :-2] + f[2:, 1:-1] + f[2:, 2:]) / 9.0
        field = (field - field.min()) / (np.ptp(field) + 1e-9)
        templates.append(field.reshape(-1))
    templates = np.stack(templates)          # (C, dim)

    basis = rng.normal(size=(dim, rank)) / np.sqrt(rank)
    y = rng.integers(0, num_classes, size=num_points).astype(np.int32)
    z = rng.normal(size=(num_points, rank)).astype(np.float32) * 0.5
    eps = rng.normal(size=(num_points, dim)).astype(np.float32)
    x = templates[y] + z @ basis.T.astype(np.float32) + noise * eps
    x = np.clip(x, 0.0, 1.0).astype(np.float32)
    if unit_norm:
        # unit-L2 rows: bounds the squared-hinge smoothness beta to O(1),
        # making the Theorem-2 parameter conditions exactly satisfiable
        x = x / (np.linalg.norm(x, axis=1, keepdims=True) + 1e-9)
    return x, y
