"""Synthetic LM token streams for scale-mode training and the dry-run.

Deterministic Zipf-ish token generator — no downloads, reproducible, and
shardable: device d / replica r draws from a disjoint seed stream, which
is exactly the non-iid `delta > 0` regime the paper studies.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def lm_batch_spec(batch: int, seq_len: int, vocab: int):
    """ShapeDtypeStructs for a causal-LM batch (tokens + labels)."""
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
    }


def synthetic_token_batches(batch: int, seq_len: int, vocab: int,
                            seed: int = 0, shard_id: int = 0,
                            start: int = 0):
    """Infinite iterator of {tokens, labels} numpy batches.

    Tokens follow a per-shard Zipf distribution with a shard-specific
    permutation of the vocabulary -> statistical heterogeneity across
    shards (gradient diversity delta > 0).

    The stream is *seekable*: ``start=k`` begins at the k-th batch of
    the ``start=0`` stream — identical sequences at any offset, so a
    checkpoint restore fast-forwards in O(1) instead of re-drawing
    every consumed batch. Each batch consumes exactly ``batch *
    (seq_len + 1)`` generator doubles (``Generator.choice`` with
    explicit probabilities draws one uniform per sample), so the seek
    is a single PCG64 ``advance`` past the permutation draw.
    """
    rng = np.random.default_rng(hash((seed, shard_id)) % (2**31))
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    perm = rng.permutation(vocab)
    draws = batch * (seq_len + 1)
    if start:
        try:
            rng.bit_generator.advance(start * draws)
        except AttributeError:      # a bit generator without advance:
            for _ in range(start):  # replay draws (correct, O(start))
                rng.choice(vocab, size=draws, p=probs)
    while True:
        flat = rng.choice(vocab, size=draws, p=probs)
        flat = perm[flat].reshape(batch, seq_len + 1).astype(np.int32)
        yield {"tokens": flat[:, :-1], "labels": flat[:, 1:]}
