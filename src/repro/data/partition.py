"""Federated partitioners (Sec. IV-A: non-iid, 3 labels per device)."""
from __future__ import annotations

import numpy as np

from repro.data.synth import FederatedDataset


def partition_noniid_labels(x: np.ndarray, y: np.ndarray, num_devices: int,
                            labels_per_device: int = 3, seed: int = 0,
                            points_per_device: int | None = None,
                            ) -> FederatedDataset:
    """Each device draws only from ``labels_per_device`` classes, with the
    class triplets rotated across devices (paper Sec. IV-A)."""
    rng = np.random.default_rng(seed)
    num_classes = int(y.max()) + 1
    by_class = [np.flatnonzero(y == c) for c in range(num_classes)]
    for idx in by_class:
        rng.shuffle(idx)
    ptrs = [0] * num_classes

    if points_per_device is None:
        points_per_device = len(y) // num_devices
    per_label = points_per_device // labels_per_device

    xs, ys = [], []
    for i in range(num_devices):
        labels = [(i + j) % num_classes for j in range(labels_per_device)]
        xi, yi = [], []
        for c in labels:
            idx = by_class[c]
            take = idx[np.mod(np.arange(ptrs[c], ptrs[c] + per_label),
                              len(idx))]
            ptrs[c] += per_label
            xi.append(x[take])
            yi.append(y[take])
        xi = np.concatenate(xi)
        yi = np.concatenate(yi)
        perm = rng.permutation(len(yi))
        xs.append(xi[perm])
        ys.append(yi[perm])

    D = min(len(v) for v in ys)
    xs = np.stack([v[:D] for v in xs]).astype(np.float32)
    ys = np.stack([v[:D] for v in ys]).astype(np.int32)
    counts = np.full((num_devices,), D, np.int32)
    return FederatedDataset(xs, ys, counts, num_classes)


def partition_iid(x: np.ndarray, y: np.ndarray, num_devices: int,
                  seed: int = 0,
                  points_per_device: int | None = None) -> FederatedDataset:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(y))
    x, y = x[perm], y[perm]
    if points_per_device is None:
        points_per_device = len(y) // num_devices
    D = points_per_device
    xs = np.stack([x[i * D:(i + 1) * D] for i in range(num_devices)])
    ys = np.stack([y[i * D:(i + 1) * D] for i in range(num_devices)])
    counts = np.full((num_devices,), D, np.int32)
    return FederatedDataset(xs.astype(np.float32), ys.astype(np.int32),
                            counts, int(y.max()) + 1)
