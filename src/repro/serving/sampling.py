"""The one token sampler shared by every serving path.

Both schedulers and the serve launcher previously hand-rolled this —
with a dtype skew: the greedy path cast to int32, the temperature path
returned ``jax.random.categorical``'s default integer dtype, so the
decode jit signature depended on the sampling mode. One function, one
dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(logits, *, temperature: float = 0.0, key=None):
    """Sample one token per slot from the last logit position.

    logits: (B, 1, V) (or (B, V)); returns (B, 1) int32. Greedy when
    ``temperature`` == 0, else categorical at ``temperature`` (``key``
    required).
    """
    last = logits[:, -1] if logits.ndim == 3 else logits
    if temperature > 0:
        if key is None:
            raise ValueError("temperature sampling requires a PRNG key")
        tok = jax.random.categorical(key, last / temperature)
    else:
        tok = jnp.argmax(last, axis=-1)
    return tok[:, None].astype(jnp.int32)
