"""Serving runtime: KV/state caches, prefill, and single-token decode
for every arch family.

Cache layout: one pytree per model whose leaves carry a leading
``layers`` (or ``groups``) axis, threaded through ``lax.scan`` together
with the layer parameters — the decode step is a single compact HLO
program regardless of depth.

Sliding-window archs (and the *sliding-window serving variant* used for
``long_500k`` on full-attention archs) keep a **ring buffer** of
``window`` positions: slot = pos % window, keys stored post-RoPE
(dot-product relative property keeps scores exact). SSM / RG-LRU archs
carry O(1) recurrent state — no KV growth at all.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mlp as mlpm
from repro.models import moe as moem
from repro.models import rglru as rgm
from repro.models import ssm as ssmm
from repro.models.common import apply_norm, sinusoidal_positions
from repro.models.transformer import _embed_tokens, _unembed


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def _attn_cache(cfg, batch, S, dtype):
    return attn.init_cache(cfg, batch, S, dtype)


def effective_window(cfg, serve_window: int = 0) -> int:
    """The serving attention window: the arch's own sliding window, the
    hybrid local-attention window, or a serving-variant override."""
    if cfg.kind == "hybrid":
        return cfg.attention_window
    if cfg.sliding_window:
        return cfg.sliding_window
    return serve_window


def cache_len_for(cfg, seq_len: int, serve_window: int = 0) -> int:
    w = effective_window(cfg, serve_window)
    return min(seq_len, w) if w else seq_len


def init_cache_tree(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16,
                    serve_window: int = 0, mesh=None, cache_rules=None):
    """Cache pytree for the whole model (all layers stacked).

    With ``mesh``, every leaf is placed via a ``NamedSharding`` resolved
    from its ``cache_logical_axes_tree`` logical axes under
    ``cache_rules`` (default ``serving.sharding.SERVE_CACHE_RULES`` —
    heads/experts sharded over ``model``, sequence as the fallback,
    slots over the replica axes), so the live batch starts sharded and
    every later splice/decode preserves that placement.
    """
    tree = _init_cache_tree(cfg, batch, seq_len, dtype, serve_window)
    if mesh is None:
        return tree
    from repro.serving.sharding import SERVE_CACHE_RULES
    rules = cache_rules or SERVE_CACHE_RULES
    axes = cache_logical_axes_tree(cfg)
    is_ax = lambda x: isinstance(x, tuple)  # noqa: E731
    flat, treedef = jax.tree_util.tree_flatten(tree)
    flat_ax = jax.tree_util.tree_flatten(axes, is_leaf=is_ax)[0]
    assert len(flat) == len(flat_ax)
    from jax.sharding import NamedSharding
    out = [jax.device_put(l, NamedSharding(
        mesh, rules.spec_for_shape(tuple(ax), tuple(l.shape), mesh)))
        for l, ax in zip(flat, flat_ax)]
    return jax.tree_util.tree_unflatten(treedef, out)


def _init_cache_tree(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16,
                     serve_window: int = 0):
    kind = cfg.kind
    S = cache_len_for(cfg, seq_len, serve_window)

    def stack(make_one, n):
        one = make_one()
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), one)

    if kind in ("dense", "vlm") or (kind == "moe" and cfg.moe_every == 1):
        return {"layers": stack(lambda: _attn_cache(cfg, batch, S, dtype),
                                cfg.num_layers)}
    if kind == "moe":
        n_groups = cfg.num_layers // cfg.moe_every
        def group():
            g = {f"dense_{i}": _attn_cache(cfg, batch, S, dtype)
                 for i in range(cfg.moe_every - 1)}
            g["moe"] = _attn_cache(cfg, batch, S, dtype)
            return g
        return {"groups": stack(group, n_groups)}
    if kind == "ssm":
        return {"layers": stack(
            lambda: ssmm.init_ssm_cache(cfg, batch, dtype), cfg.num_layers)}
    if kind == "hybrid":
        period = cfg.local_attn_every or 3
        n_groups = cfg.num_layers // period
        rem = cfg.num_layers - n_groups * period
        def group():
            g = {f"rec_{i}": rgm.init_rglru_cache(cfg, batch, dtype)
                 for i in range(period - 1)}
            g["attn"] = _attn_cache(cfg, batch, S, dtype)
            return g
        out = {}
        if n_groups:
            out["groups"] = stack(group, n_groups)
        if rem:
            out["tail"] = stack(
                lambda: rgm.init_rglru_cache(cfg, batch, dtype), rem)
        return out
    if kind in ("encdec", "audio"):
        def dec_layer():
            c = _attn_cache(cfg, batch, S, dtype)
            K, hd = cfg.num_kv_heads, cfg.head_dim
            c["cross_k"] = jnp.zeros((batch, cfg.enc_seq_len, K, hd), dtype)
            c["cross_v"] = jnp.zeros((batch, cfg.enc_seq_len, K, hd), dtype)
            return c
        return {"layers": stack(dec_layer, cfg.num_layers)}
    raise ValueError(kind)


def cache_logical_axes_tree(cfg, long_context: bool = False):
    """Logical axes matching init_cache_tree's structure."""
    kv = ("layers",) + attn.cache_logical_axes()["k"]
    kv_leaf = {"k": kv, "v": kv}

    def with_layers(d):
        return jax.tree.map(lambda a: ("layers",) + tuple(a), d,
                            is_leaf=lambda x: isinstance(x, tuple))

    kind = cfg.kind
    if kind in ("dense", "vlm") or (kind == "moe" and cfg.moe_every == 1):
        return {"layers": with_layers(attn.cache_logical_axes())}
    if kind == "moe":
        g = {f"dense_{i}": attn.cache_logical_axes()
             for i in range(cfg.moe_every - 1)}
        g["moe"] = attn.cache_logical_axes()
        return {"groups": with_layers(g)}
    if kind == "ssm":
        return {"layers": with_layers(ssmm.ssm_cache_logical_axes(cfg))}
    if kind == "hybrid":
        period = cfg.local_attn_every or 3
        rem = cfg.num_layers - (cfg.num_layers // period) * period
        g = {f"rec_{i}": rgm.rglru_cache_logical_axes(cfg)
             for i in range(period - 1)}
        g["attn"] = attn.cache_logical_axes()
        out = {}
        if (cfg.num_layers // period):
            out["groups"] = with_layers(g)
        if rem:
            out["tail"] = with_layers(rgm.rglru_cache_logical_axes(cfg))
        return out
    if kind in ("encdec", "audio"):
        d = attn.cache_logical_axes()
        d["cross_k"] = ("cache_batch", None, "cache_kv_heads", "head_dim")
        d["cross_v"] = ("cache_batch", None, "cache_kv_heads", "head_dim")
        return {"layers": with_layers(d)}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def _ring_fill(k_all, v_all, S, dtype, lengths=None):
    """Place the last S tokens of (B, T, K, hd) into ring slots t % S.

    With per-request ``lengths`` (B,), each row i keeps the last S of its
    own ``lengths[i]`` valid (right-aligned) tokens; ring slots that no
    valid token maps to are zeroed, so padded prefixes never enter the
    cache.
    """
    T = k_all.shape[1]
    if lengths is None:
        if T <= S:
            pad = S - T
            k = jnp.pad(k_all, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v_all, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return k.astype(dtype), v.astype(dtype)
        idx = T - S + jnp.arange(S)
        slots = idx % S
        k = jnp.zeros((k_all.shape[0], S) + k_all.shape[2:], dtype)
        v = jnp.zeros_like(k)
        k = k.at[:, slots].set(k_all[:, idx].astype(dtype))
        v = v.at[:, slots].set(v_all[:, idx].astype(dtype))
        return k, v
    # largest valid token index t with t ≡ s (mod S), per row
    s = jnp.arange(S)[None, :]                              # (1, S)
    t = s + S * ((lengths[:, None] - 1 - s) // S)           # (B, S)
    valid = t >= 0
    idx = jnp.clip(t, 0, T - 1)[..., None, None]
    k = jnp.where(valid[..., None, None],
                  jnp.take_along_axis(k_all, idx, axis=1), 0)
    v = jnp.where(valid[..., None, None],
                  jnp.take_along_axis(v_all, idx, axis=1), 0)
    return k.astype(dtype), v.astype(dtype)


def _conv_state_at(x_pre, lengths, K):
    """Per-row causal-conv trailing context at position ``lengths``.

    x_pre: (B, T, D) pre-activation conv inputs; returns (B, K-1, D) —
    row i holds inputs lengths[i]-K+1 .. lengths[i]-1, zero-padded on
    the left exactly like a fresh causal conv.
    """
    if K <= 1:
        return jnp.zeros_like(x_pre[:, :0])
    xp = jnp.concatenate([jnp.zeros_like(x_pre[:, : K - 1]), x_pre], axis=1)
    idx = lengths[:, None] + jnp.arange(K - 1)[None, :]     # (B, K-1)
    return jnp.take_along_axis(xp, idx[..., None], axis=1)


def _prefill_attn_layer(lp, cfg, x, *, mode, window, S, cache_dtype,
                        enc_out=None, prefix_len=None, lengths=None):
    """Dense-family layer forward that also emits its KV cache slice."""
    from repro.models.common import rope as rope_fn
    B, T, _ = x.shape
    h = apply_norm(cfg, lp["ln_attn"], x)
    # projections (duplicated from attention_block to capture K/V)
    from repro.dist.sharding import hint
    q = attn._project_q(lp["attn"], cfg, h)
    k, v = attn._project_kv(lp["attn"], cfg, h)
    q = hint(q, ("pod", "data"), None, "model", None, None)
    k = hint(k, ("pod", "data"), None, "model", None)
    v = hint(v, ("pod", "data"), None, "model", None)
    if cfg.rope:
        pos = jnp.arange(T)
        q = rope_fn(q.reshape(B, T, -1, cfg.head_dim), pos,
                    cfg.rope_theta).reshape(q.shape)
        k = rope_fn(k, pos, cfg.rope_theta)
    # pin the flash inputs AFTER rope: otherwise the cache output's
    # seq-sharding propagates backwards and every flash q-step
    # all-gathers the whole K/V (HC2 in EXPERIMENTS.md §Perf)
    q = hint(q, ("pod", "data"), None, "model", None, None)
    k = hint(k, ("pod", "data"), None, "model", None)
    v = hint(v, ("pod", "data"), None, "model", None)
    use_flash = T > 2048
    if use_flash:
        pair_mode = attn.PAIR_SCHEDULE and mode in ("causal", "sliding",
                                                    "prefix")
        qc = min(512, T)
        kc = qc if pair_mode else min(1024, T)
        pq, pk = (-T) % qc, (-T) % kc
        qq = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0))) if pq else q
        kk = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
        vv = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
        fa = attn.flash_attention_pairs if pair_mode else attn.flash_attention
        out = fa(qq, kk, vv, mode=mode, window=window,
                 prefix_len=prefix_len, q_chunk=qc,
                 k_chunk=kc, k_len=T if pk else None)[:, :T]
    else:
        out = attn.simple_attention(q, k, v, mode=mode, window=window,
                                    prefix_len=prefix_len)
    out = out.reshape(B, T, cfg.num_heads * cfg.head_dim)
    x = x + out @ lp["attn"]["wo"].astype(x.dtype)

    if enc_out is not None and "cross" in lp:
        h = apply_norm(cfg, lp["ln_cross"], x)
        h = attn.attention_block(lp["cross"], cfg, h, mode="full",
                                 kv_source=enc_out)
        x = x + h

    h = apply_norm(cfg, lp["ln_mlp"], x)
    if "moe" in lp:
        # pad tokens must not consume expert capacity or skew routing
        tmask = None if lengths is None else \
            jnp.arange(T)[None, :] < lengths[:, None]
        h, _ = moem.apply_moe(lp["moe"], cfg, h, token_mask=tmask)
    else:
        h = mlpm.apply_mlp(lp["mlp"], cfg, h)
    x = x + h

    ck, cv = _ring_fill(k, v, S, cache_dtype, lengths)
    cache = {"k": ck, "v": cv}
    if enc_out is not None and "cross" in lp:
        ek, ev = attn._project_kv(lp["cross"], cfg, enc_out)
        cache["cross_k"] = ek.astype(cache_dtype)
        cache["cross_v"] = ev.astype(cache_dtype)
    return x, cache


def _prefill_ssm_layer(lp, cfg, x, lengths=None):
    h = apply_norm(cfg, lp["ln"], x)
    b, T, d = h.shape
    d_in, H, P, S = ssmm._dims(cfg)
    proj = h @ lp["ssm"]["w_in"].astype(h.dtype)
    z, xs, Bm, Cm, dt_raw = ssmm._split_proj(cfg, proj)
    xs_pre, Bm_pre, Cm_pre = xs, Bm, Cm
    xs, cx = ssmm._causal_conv(xs, lp["ssm"]["conv_x"])
    Bm, cB = ssmm._causal_conv(Bm, lp["ssm"]["conv_B"])
    Cm, cC = ssmm._causal_conv(Cm, lp["ssm"]["conv_C"])
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + lp["ssm"]["dt_bias"].astype(jnp.float32))
    if lengths is not None:
        # dt = 0 on padded steps freezes the recurrence (decay exp(0)=1,
        # input contribution dt·B·x = 0) so h_fin is each row's state at
        # its own last valid token — exactly like the zero-padding
        # ssd_chunked itself applies for chunk alignment
        keep = (jnp.arange(T)[None, :] < lengths[:, None])[..., None]
        dt = jnp.where(keep, dt, 0.0)
        K = cfg.ssm_conv_width
        cx = _conv_state_at(xs_pre, lengths, K).astype(cx.dtype)
        cB = _conv_state_at(Bm_pre, lengths, K).astype(cB.dtype)
        cC = _conv_state_at(Cm_pre, lengths, K).astype(cC.dtype)
    A = -jnp.exp(lp["ssm"]["A_log"].astype(jnp.float32))
    y, h_fin = ssmm.ssd_chunked(xs.reshape(b, T, H, P), dt, dt * A, Bm, Cm,
                                chunk=cfg.ssm_chunk)
    y = y + xs.reshape(b, T, H, P) * lp["ssm"]["D"].astype(
        h.dtype)[None, None, :, None]
    y = y.reshape(b, T, d_in) * jax.nn.silu(z)
    x = x + y @ lp["ssm"]["w_out"].astype(h.dtype)
    # conv caches hold the last (K-1) *pre-activation* inputs
    cache = {"h": h_fin, "conv_x": cx, "conv_B": cB, "conv_C": cC}
    return x, cache


def _prefill_rec_layer(lp, cfg, x, lengths=None):
    dt = x.dtype
    h = apply_norm(cfg, lp["ln_rec"], x)
    ga = jax.nn.gelu(h @ lp["rec"]["w_gelu"].astype(dt), approximate=True)
    xb = h @ lp["rec"]["w_rec"].astype(dt)
    xb_pre = xb
    xb, conv_state = rgm._causal_conv(xb, lp["rec"]["conv"])
    a, beta = rgm._gates(lp["rec"], xb)
    b = beta * xb.astype(jnp.float32)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (ga.astype(jnp.float32) * hs).astype(dt)
    x = x + y @ lp["rec"]["w_out"].astype(dt)
    x = x + mlpm.apply_mlp(lp["mlp"], cfg,
                           apply_norm(cfg, lp["ln_mlp"], x))
    if lengths is None:
        cache = {"h": hs[:, -1], "conv": conv_state}
    else:
        # per-row recurrent state at each row's own last valid token
        last = jnp.clip(lengths - 1, 0)[:, None, None]
        h_last = jnp.take_along_axis(hs, last, axis=1)[:, 0]
        conv = _conv_state_at(xb_pre, lengths, cfg.rglru_conv_width)
        cache = {"h": h_last, "conv": conv.astype(conv_state.dtype)}
    return x, cache


def prefill(p, cfg, batch, *, dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16,
            serve_window: int = 0, remat: bool = True,
            cache_len: int | None = None, lengths=None):
    """Process the full prompt; return (last-token logits, cache, pos).

    batch: {"tokens": (B, T)} + frontend extras (patches/frames).
    ``cache_len``: total cache capacity to allocate (>= prompt length;
    defaults to the prompt length — pass the generation horizon).

    ``lengths``: optional (B,) int32 per-request prompt lengths for
    mixed-length batches. Prompts must then be RIGHT-padded (tokens
    [0, lengths[i]) real, the rest pad): real queries never attend to
    pad keys under the causal/sliding/prefix masks because every pad
    position sorts after them, recurrent state is frozen at each row's
    own last valid token, and pad positions never enter the KV cache.
    The returned logits are taken at each row's last valid token and
    ``pos`` is a per-slot (B,) vector (scalar when ``lengths`` is None).
    """
    kind = cfg.kind
    tokens = batch["tokens"]
    B, T = tokens.shape
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32).reshape(B)
    x = _embed_tokens(p, cfg, tokens, dtype)
    mode, window = "causal", 0
    if cfg.sliding_window:
        mode, window = "sliding", cfg.sliding_window
    elif serve_window and kind not in ("ssm", "hybrid"):
        mode, window = "sliding", serve_window

    prefix = None
    enc_out = None
    if kind == "vlm":
        patches = batch["patches"].astype(dtype)
        x = jnp.concatenate([patches, x], axis=1)
        mode = "prefix"
        prefix = cfg.enc_seq_len
    if kind in ("encdec", "audio"):
        frames = batch["frames"].astype(dtype)
        pos_e = sinusoidal_positions(frames.shape[1],
                                     cfg.d_model).astype(dtype)
        h = frames + pos_e[None]
        def enc_body(hh, lp):
            y = attn.attention_block(lp["attn"], cfg,
                                     apply_norm(cfg, lp["ln_attn"], hh),
                                     mode="full")
            hh = hh + y
            hh = hh + mlpm.apply_mlp(lp["mlp"], cfg,
                                     apply_norm(cfg, lp["ln_mlp"], hh))
            return hh, None
        h, _ = jax.lax.scan(lambda c, lp: enc_body(c, lp), h, p["enc_layers"])
        enc_out = apply_norm(cfg, p["enc_ln_final"], h)
        if not cfg.rope:
            dpos = sinusoidal_positions(T, cfg.d_model).astype(dtype)
            x = x + dpos[None]

    S = cache_len_for(cfg, max(cache_len or 0, x.shape[1]), serve_window)

    # valid length of the concatenated sequence (vlm prefixes count)
    lens_x = None
    if lengths is not None:
        lens_x = lengths + (cfg.enc_seq_len if kind == "vlm" else 0)

    def run_stack(x, stacked, body):
        fn = jax.checkpoint(body) if remat else body
        return jax.lax.scan(lambda c, lp: fn(lp, c), x, stacked)

    if kind in ("dense", "vlm") or (kind == "moe" and cfg.moe_every == 1):
        def body(lp, xx):
            m = "prefix" if kind == "vlm" else mode
            return _prefill_attn_layer(
                lp, cfg, xx, mode=m, window=window, S=S,
                cache_dtype=cache_dtype, lengths=lens_x)
        # prefix mode needs prefix_len plumbed through _mask_block;
        # handled via functools.partial on _mask defaults:
        if kind == "vlm":
            def body(lp, xx):  # noqa: F811 — vlm specialization
                return _prefill_vlm_layer(lp, cfg, xx, prefix, S,
                                          cache_dtype, lens_x)
        x, cache = run_stack(x, p["layers"], body)
        cache = {"layers": cache}
    elif kind == "moe":
        def body(lp, xx):
            caches = {}
            for i in range(cfg.moe_every - 1):
                xx, caches[f"dense_{i}"] = _prefill_attn_layer(
                    lp[f"dense_{i}"], cfg, xx, mode=mode, window=window,
                    S=S, cache_dtype=cache_dtype, lengths=lens_x)
            xx, caches["moe"] = _prefill_attn_layer(
                lp["moe"], cfg, xx, mode=mode, window=window, S=S,
                cache_dtype=cache_dtype, lengths=lens_x)
            return xx, caches
        x, cache = run_stack(x, p["groups"], body)
        cache = {"groups": cache}
    elif kind == "ssm":
        def body(lp, xx):
            return _prefill_ssm_layer(lp, cfg, xx, lens_x)
        x, cache = run_stack(x, p["layers"], body)
        cache = {"layers": cache}
    elif kind == "hybrid":
        period = cfg.local_attn_every or 3
        def body(lp, xx):
            caches = {}
            for i in range(period - 1):
                xx, caches[f"rec_{i}"] = _prefill_rec_layer(
                    lp[f"rec_{i}"], cfg, xx, lens_x)
            xx, caches["attn"] = _prefill_attn_layer(
                lp["attn"], cfg, xx, mode="sliding",
                window=cfg.attention_window, S=S, cache_dtype=cache_dtype,
                lengths=lens_x)
            return xx, caches
        cache = {}
        if "groups" in p:
            x, gcache = run_stack(x, p["groups"], body)
            cache["groups"] = gcache
        if "tail" in p:
            def tail_body(lp, xx):
                return _prefill_rec_layer(lp, cfg, xx, lens_x)
            x, tail_cache = run_stack(x, p["tail"], tail_body)
            cache["tail"] = tail_cache
    elif kind in ("encdec", "audio"):
        def body(lp, xx):
            return _prefill_attn_layer(lp, cfg, xx, mode="causal", window=0,
                                       S=S, cache_dtype=cache_dtype,
                                       enc_out=enc_out, lengths=lens_x)
        x, cache = run_stack(x, p["layers"], body)
        cache = {"layers": cache}
    else:
        raise ValueError(kind)

    x = apply_norm(cfg, p["ln_final"], x)
    if lens_x is None:
        logits = _unembed(p, cfg, x[:, -1:])
        total = T + (cfg.enc_seq_len if kind == "vlm" else 0)
        return logits, cache, jnp.asarray(total, jnp.int32)
    # per-slot: logits at each row's last valid token, (B,) positions
    last = jnp.clip(lens_x - 1, 0)[:, None, None]
    x_last = jnp.take_along_axis(x, last, axis=1)           # (B, 1, d)
    logits = _unembed(p, cfg, x_last)
    return logits, cache, lens_x


def _prefill_vlm_layer(lp, cfg, x, prefix, S, cache_dtype, lengths=None):
    return _prefill_attn_layer(lp, cfg, x, mode="prefix", window=0, S=S,
                               cache_dtype=cache_dtype, prefix_len=prefix,
                               lengths=lengths)


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def decode_step(p, cfg, token, cache, pos, *, dtype=jnp.bfloat16,
                serve_window: int = 0):
    """One-token generation step.

    token: (B, 1) int32; cache: tree from init_cache_tree/prefill;
    pos: int32 absolute position — a scalar (all slots aligned) or a
    ``(B,)`` vector of per-slot positions (continuous batching).
    Returns (logits, new_cache).
    """
    kind = cfg.kind
    B = token.shape[0]
    pos = jnp.asarray(pos, jnp.int32)   # scalar or (B,): rank picks the
    x = _embed_tokens(p, cfg, token, dtype)   # aligned vs per-slot path
    if kind in ("encdec", "audio") and not cfg.rope:
        # sinusoidal decoder position for each slot's current step
        d = cfg.d_model
        half = d // 2
        freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half)
                       / max(half - 1, 1))
        pos_b = jnp.broadcast_to(pos.reshape(-1), (B,))
        ang = pos_b.astype(jnp.float32)[:, None] * freq     # (B, half)
        dpos = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                               axis=-1)[:, None]            # (B, 1, d)
        x = x + dpos.astype(dtype)

    w = effective_window(cfg, serve_window)

    def attn_decode(lp, xx, c, *, cross=False):
        h = apply_norm(cfg, lp["ln_attn"], xx)
        ring = w if (c["k"].shape[1] == w and w) else 0
        out, c_new = attn.decode_attention(lp["attn"], cfg, h,
                                           {"k": c["k"], "v": c["v"]},
                                           pos, window=ring)
        xx = xx + out
        if cross and "cross" in lp:
            h = apply_norm(cfg, lp["ln_cross"], xx)
            kv = {"k": c["cross_k"], "v": c["cross_v"]}
            out, _ = attn.decode_attention(lp["cross"], cfg, h, {},
                                           pos, kv_source_cache=kv)
            xx = xx + out
        h = apply_norm(cfg, lp["ln_mlp"], xx)
        if "moe" in lp:
            h, _ = moem.apply_moe(lp["moe"], cfg, h)
        else:
            h = mlpm.apply_mlp(lp["mlp"], cfg, h)
        new = dict(c)
        new["k"], new["v"] = c_new["k"], c_new["v"]
        return xx + h, new

    def ssm_decode(lp, xx, c):
        h = apply_norm(cfg, lp["ln"], xx)
        y, c_new = ssmm.decode_ssm(lp["ssm"], cfg, h, c)
        return xx + y, c_new

    def rec_decode(lp, xx, c):
        h = apply_norm(cfg, lp["ln_rec"], xx)
        y, c_new = rgm.decode_rglru(lp["rec"], cfg, h, c)
        xx = xx + y
        xx = xx + mlpm.apply_mlp(lp["mlp"], cfg,
                                 apply_norm(cfg, lp["ln_mlp"], xx))
        return xx, c_new

    if kind in ("dense", "vlm") or (kind == "moe" and cfg.moe_every == 1):
        def body(xx, scanned):
            lp, c = scanned
            return attn_decode(lp, xx, c)
        x, new_cache = jax.lax.scan(
            lambda c, s: body(c, s), x, (p["layers"], cache["layers"]))
        new_cache = {"layers": new_cache}
    elif kind == "moe":
        def body(xx, scanned):
            lp, c = scanned
            new = {}
            for i in range(cfg.moe_every - 1):
                xx, new[f"dense_{i}"] = attn_decode(
                    lp[f"dense_{i}"], xx, c[f"dense_{i}"])
            xx, new["moe"] = attn_decode(lp["moe"], xx, c["moe"])
            return xx, new
        x, new_cache = jax.lax.scan(
            lambda c, s: body(c, s), x, (p["groups"], cache["groups"]))
        new_cache = {"groups": new_cache}
    elif kind == "ssm":
        def body(xx, scanned):
            lp, c = scanned
            return ssm_decode(lp, xx, c)
        x, new_cache = jax.lax.scan(
            lambda c, s: body(c, s), x, (p["layers"], cache["layers"]))
        new_cache = {"layers": new_cache}
    elif kind == "hybrid":
        period = cfg.local_attn_every or 3
        def body(xx, scanned):
            lp, c = scanned
            new = {}
            for i in range(period - 1):
                xx, new[f"rec_{i}"] = rec_decode(
                    lp[f"rec_{i}"], xx, c[f"rec_{i}"])
            xx, new["attn"] = attn_decode(lp["attn"], xx, c["attn"])
            return xx, new
        new_cache = {}
        if "groups" in p:
            x, gnew = jax.lax.scan(
                lambda c, s: body(c, s), x, (p["groups"], cache["groups"]))
            new_cache["groups"] = gnew
        if "tail" in p:
            def tail_body(xx, scanned):
                lp, c = scanned
                return rec_decode(lp, xx, c)
            x, tail_new = jax.lax.scan(
                lambda c, s: tail_body(c, s), x,
                (p["tail"], cache["tail"]))
            new_cache["tail"] = tail_new
    elif kind in ("encdec", "audio"):
        def body(xx, scanned):
            lp, c = scanned
            return attn_decode(lp, xx, c, cross=True)
        x, new_cache = jax.lax.scan(
            lambda c, s: body(c, s), x, (p["layers"], cache["layers"]))
        new_cache = {"layers": new_cache}
    else:
        raise ValueError(kind)

    x = apply_norm(cfg, p["ln_final"], x)
    logits = _unembed(p, cfg, x)
    return logits, new_cache


# ---------------------------------------------------------------------------
# slot-indexed cache writes (continuous batching)
# ---------------------------------------------------------------------------

def write_cache_slot(cfg, cache, one_cache, slot, *, pos=None,
                     one_pos=None, cache_rules=None):
    """Write a single-request cache into slot ``slot`` of a live batch.

    ``one_cache`` comes from a batch-1 :func:`prefill` with the same
    ``cache_len``/``serve_window`` as the live ``cache`` — every leaf is
    inserted along its ``cache_batch`` axis (located via the logical-axes
    tree, so SSM state / conv context / cross-KV leaves, whose batch
    axis sits at different ranks, all route correctly) with
    ``jax.lax.dynamic_update_slice``: ``slot`` may be traced, keeping
    one jit signature for the process lifetime.

    With ``cache_rules`` and an active mesh, every spliced leaf is
    re-pinned to the sharding its logical axes resolve to — the splice
    PRESERVES leaf shardings (the batch-1 source is resharded into the
    live layout; the live cache never moves).

    Optionally also splices ``one_pos`` (scalar or (1,)) into the
    per-slot ``pos`` vector. Returns ``new_cache`` (and ``new_pos``
    when ``pos`` is given).
    """
    from repro.dist.sharding import _ambient_mesh
    axes = cache_logical_axes_tree(cfg)
    flat_dst, treedef = jax.tree_util.tree_flatten(cache)
    flat_src = jax.tree_util.tree_flatten(one_cache)[0]
    flat_ax = jax.tree_util.tree_flatten(
        axes, is_leaf=lambda x: isinstance(x, tuple))[0]
    assert len(flat_dst) == len(flat_src) == len(flat_ax)
    mesh = _ambient_mesh() if cache_rules is not None else None
    slot = jnp.asarray(slot, jnp.int32)
    out = []
    for dst, src, ax in zip(flat_dst, flat_src, flat_ax):
        b = ax.index("cache_batch")
        start = [jnp.zeros((), jnp.int32)] * dst.ndim
        start[b] = slot
        new = jax.lax.dynamic_update_slice(
            dst, src.astype(dst.dtype), tuple(start))
        if mesh is not None:
            from jax.sharding import NamedSharding
            new = jax.lax.with_sharding_constraint(
                new, NamedSharding(mesh, cache_rules.spec_for_shape(
                    tuple(ax), tuple(new.shape), mesh)))
        out.append(new)
    new_cache = jax.tree_util.tree_unflatten(treedef, out)
    if pos is None:
        return new_cache
    one_pos = jnp.asarray(one_pos, jnp.int32).reshape(())
    new_pos = pos.at[slot].set(one_pos)
    return new_cache, new_pos


# ---------------------------------------------------------------------------
# paged cache (DESIGN.md §15): attention K/V in a shared page pool,
# recurrent state per-slot; chunked prefill + page-map decode
# ---------------------------------------------------------------------------

PAGED_KINDS = ("dense", "moe", "ssm", "hybrid")


def init_paged_cache_tree(cfg, slots: int, num_pages: int, page_size: int,
                          dtype=jnp.bfloat16, mesh=None, cache_rules=None):
    """Paged cache pytree: attention K/V leaves become a page pool
    ``(layers, num_pages, page_size, K, hd)`` shared by all slots (page
    0 reserved as the dummy sink); SSM/RG-LRU/conv state is O(1) per
    request and stays per-slot, identical to the ring layout.
    """
    if cfg.kind not in PAGED_KINDS:
        raise ValueError(
            f"paged serving is token-only; arch kind {cfg.kind!r} is "
            "not served by the request schedulers")
    tree = _init_paged_cache_tree(cfg, slots, num_pages, page_size, dtype)
    if mesh is None:
        return tree
    from repro.serving.sharding import SERVE_CACHE_RULES
    rules = cache_rules or SERVE_CACHE_RULES
    axes = paged_cache_logical_axes_tree(cfg)
    from jax.sharding import NamedSharding
    flat, treedef = jax.tree_util.tree_flatten(tree)
    flat_ax = jax.tree_util.tree_flatten(
        axes, is_leaf=lambda x: isinstance(x, tuple))[0]
    assert len(flat) == len(flat_ax)
    out = [jax.device_put(l, NamedSharding(
        mesh, rules.spec_for_shape(tuple(ax), tuple(l.shape), mesh)))
        for l, ax in zip(flat, flat_ax)]
    return jax.tree_util.tree_unflatten(treedef, out)


def _init_paged_cache_tree(cfg, slots, num_pages, page_size, dtype):
    kind = cfg.kind

    def stack(make_one, n):
        one = make_one()
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), one)

    pool = lambda: attn.init_paged_cache(cfg, num_pages, page_size,  # noqa: E731
                                         dtype)
    if kind == "dense" or (kind == "moe" and cfg.moe_every == 1):
        return {"layers": stack(pool, cfg.num_layers)}
    if kind == "moe":
        n_groups = cfg.num_layers // cfg.moe_every
        def group():
            g = {f"dense_{i}": pool() for i in range(cfg.moe_every - 1)}
            g["moe"] = pool()
            return g
        return {"groups": stack(group, n_groups)}
    if kind == "ssm":
        return {"layers": stack(
            lambda: ssmm.init_ssm_cache(cfg, slots, dtype), cfg.num_layers)}
    if kind == "hybrid":
        period = cfg.local_attn_every or 3
        n_groups = cfg.num_layers // period
        rem = cfg.num_layers - n_groups * period
        def group():
            g = {f"rec_{i}": rgm.init_rglru_cache(cfg, slots, dtype)
                 for i in range(period - 1)}
            g["attn"] = pool()
            return g
        out = {}
        if n_groups:
            out["groups"] = stack(group, n_groups)
        if rem:
            out["tail"] = stack(
                lambda: rgm.init_rglru_cache(cfg, slots, dtype), rem)
        return out
    raise ValueError(kind)


def paged_cache_logical_axes_tree(cfg):
    """Logical axes matching init_paged_cache_tree's structure."""
    def with_layers(d):
        return jax.tree.map(lambda a: ("layers",) + tuple(a), d,
                            is_leaf=lambda x: isinstance(x, tuple))

    kind = cfg.kind
    pool = attn.paged_cache_logical_axes
    if kind == "dense" or (kind == "moe" and cfg.moe_every == 1):
        return {"layers": with_layers(pool())}
    if kind == "moe":
        g = {f"dense_{i}": pool() for i in range(cfg.moe_every - 1)}
        g["moe"] = pool()
        return {"groups": with_layers(g)}
    if kind == "ssm":
        return {"layers": with_layers(ssmm.ssm_cache_logical_axes(cfg))}
    if kind == "hybrid":
        period = cfg.local_attn_every or 3
        rem = cfg.num_layers - (cfg.num_layers // period) * period
        g = {f"rec_{i}": rgm.rglru_cache_logical_axes(cfg)
             for i in range(period - 1)}
        g["attn"] = pool()
        out = {}
        if cfg.num_layers // period:
            out["groups"] = with_layers(g)
        if rem:
            out["tail"] = with_layers(rgm.rglru_cache_logical_axes(cfg))
        return out
    raise ValueError(kind)


def _slot_slice(leaf, slot):
    return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=0)


def _slot_write(leaf, val, slot):
    return jax.lax.dynamic_update_slice_in_dim(
        leaf, val.astype(leaf.dtype), slot, axis=0)


def _chunk_attn_layer(lp, cfg, x, kv, *, mode, window, start, valid,
                      page_row):
    """One attn layer over a prefill chunk, writing K/V into pages.

    x: (1, C, d); kv: {'k','v'} page pools; start/valid: traced scalars
    (chunk offset, #real tokens in the chunk); page_row:
    (pages_per_slot,) this slot's pages. Rows j >= valid are padding:
    their writes go to the dummy page, their queries never feed the
    cache or the logits, and MoE routing masks them out.
    """
    from repro.dist.sharding import hint
    from repro.models.common import rope as rope_fn
    B, C, _ = x.shape
    h = apply_norm(cfg, lp["ln_attn"], x)
    q = attn._project_q(lp["attn"], cfg, h)
    k, v = attn._project_kv(lp["attn"], cfg, h)
    q = hint(q, ("pod", "data"), None, "model", None, None)
    k = hint(k, ("pod", "data"), None, "model", None)
    v = hint(v, ("pod", "data"), None, "model", None)
    if cfg.rope:
        tpos = start + jnp.arange(C)
        q = rope_fn(q.reshape(B, C, -1, cfg.head_dim), tpos,
                    cfg.rope_theta).reshape(q.shape)
        k = rope_fn(k, tpos, cfg.rope_theta)
    q = hint(q, ("pod", "data"), None, "model", None, None)
    k = hint(k, ("pod", "data"), None, "model", None)
    v = hint(v, ("pod", "data"), None, "model", None)

    N, ps = kv["k"].shape[:2]
    P = page_row.shape[0]
    j = jnp.arange(C)
    tgt = start + j                                  # absolute positions
    pg = page_row[jnp.clip(tgt // ps, 0, P - 1)]
    flat = jnp.where(j < valid, pg * ps + tgt % ps, j % ps)
    k_pages, v_pages = attn._paged_scatter(kv, k[0], v[0], flat)

    kg = k_pages[page_row].reshape(1, P * ps, *k_pages.shape[2:])
    vg = v_pages[page_row].reshape(1, P * ps, *v_pages.shape[2:])
    out = attn.simple_attention(q, kg.astype(q.dtype), vg.astype(q.dtype),
                                mode=mode, window=window, q_offset=start,
                                k_len=start + valid)
    out = out.reshape(B, C, cfg.num_heads * cfg.head_dim)
    x = x + out @ lp["attn"]["wo"].astype(x.dtype)

    h = apply_norm(cfg, lp["ln_mlp"], x)
    if "moe" in lp:
        h, _ = moem.apply_moe(lp["moe"], cfg, h,
                              token_mask=(j < valid)[None, :])
    else:
        h = mlpm.apply_mlp(lp["mlp"], cfg, h)
    return x + h, {"k": k_pages, "v": v_pages}


def _chunk_ssm_layer(lp, cfg, x, c, *, slot, start, valid):
    """One SSM layer over a prefill chunk, carrying slot state across
    chunks: conv context + SSD ``h0`` are read from (and written back
    to) the per-slot cache leaves; ``start == 0`` starts fresh."""
    h = apply_norm(cfg, lp["ln"], x)
    b, C, _ = h.shape
    d_in, H, P, S = ssmm._dims(cfg)
    K = cfg.ssm_conv_width
    fresh = start == 0
    h0 = jnp.where(fresh, 0.0, _slot_slice(c["h"], slot))
    cx0 = jnp.where(fresh, 0.0, _slot_slice(c["conv_x"], slot))
    cB0 = jnp.where(fresh, 0.0, _slot_slice(c["conv_B"], slot))
    cC0 = jnp.where(fresh, 0.0, _slot_slice(c["conv_C"], slot))

    proj = h @ lp["ssm"]["w_in"].astype(h.dtype)
    z, xs, Bm, Cm, dt_raw = ssmm._split_proj(cfg, proj)
    xs_pre, Bm_pre, Cm_pre = xs, Bm, Cm
    xs, _ = ssmm._causal_conv(xs, lp["ssm"]["conv_x"], cx0)
    Bm, _ = ssmm._causal_conv(Bm, lp["ssm"]["conv_B"], cB0)
    Cm, _ = ssmm._causal_conv(Cm, lp["ssm"]["conv_C"], cC0)
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + lp["ssm"]["dt_bias"].astype(jnp.float32))
    # dt = 0 freezes the recurrence on pad rows (same trick as the
    # mixed-length one-shot prefill), so h_fin is the state at valid-1
    keep = (jnp.arange(C)[None, :] < valid)[..., None]
    dt = jnp.where(keep, dt, 0.0)
    A = -jnp.exp(lp["ssm"]["A_log"].astype(jnp.float32))
    y, h_fin = ssmm.ssd_chunked(xs.reshape(b, C, H, P), dt, dt * A,
                                Bm, Cm, h0=h0, chunk=cfg.ssm_chunk)
    y = y + xs.reshape(b, C, H, P) * lp["ssm"]["D"].astype(
        h.dtype)[None, None, :, None]
    y = y.reshape(b, C, d_in) * jax.nn.silu(z)
    x = x + y @ lp["ssm"]["w_out"].astype(h.dtype)

    def conv_next(state0, pre):
        if K <= 1:
            return state0
        xp = jnp.concatenate([state0.astype(pre.dtype), pre], axis=1)
        return jax.lax.dynamic_slice_in_dim(xp, valid, K - 1, axis=1)

    new = {"h": _slot_write(c["h"], h_fin, slot),
           "conv_x": _slot_write(c["conv_x"],
                                 conv_next(cx0, xs_pre), slot),
           "conv_B": _slot_write(c["conv_B"],
                                 conv_next(cB0, Bm_pre), slot),
           "conv_C": _slot_write(c["conv_C"],
                                 conv_next(cC0, Cm_pre), slot)}
    return x, new


def _chunk_rec_layer(lp, cfg, x, c, *, slot, start, valid):
    """One RG-LRU layer over a prefill chunk with carried (h, conv)
    state: the inbound hidden state is folded into the first scan
    element (h_0 = a_0 h_in + b_0), which continues the recurrence
    exactly."""
    dt = x.dtype
    K = cfg.rglru_conv_width
    h = apply_norm(cfg, lp["ln_rec"], x)
    ga = jax.nn.gelu(h @ lp["rec"]["w_gelu"].astype(dt), approximate=True)
    xb = h @ lp["rec"]["w_rec"].astype(dt)
    xb_pre = xb
    fresh = start == 0
    h0 = jnp.where(fresh, 0.0, _slot_slice(c["h"], slot))   # (1, w)
    conv0 = jnp.where(fresh, 0.0, _slot_slice(c["conv"], slot))
    xb, _ = rgm._causal_conv(xb, lp["rec"]["conv"], conv0)
    a, beta = rgm._gates(lp["rec"], xb)
    b = beta * xb.astype(jnp.float32)
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (ga.astype(jnp.float32) * hs).astype(dt)
    x = x + y @ lp["rec"]["w_out"].astype(dt)
    x = x + mlpm.apply_mlp(lp["mlp"], cfg,
                           apply_norm(cfg, lp["ln_mlp"], x))
    h_last = jax.lax.dynamic_slice_in_dim(
        hs, jnp.clip(valid - 1, 0), 1, axis=1)[:, 0]

    if K > 1:
        xp = jnp.concatenate([conv0.astype(xb_pre.dtype), xb_pre], axis=1)
        conv1 = jax.lax.dynamic_slice_in_dim(xp, valid, K - 1, axis=1)
    else:
        conv1 = conv0
    new = {"h": _slot_write(c["h"], h_last, slot),
           "conv": _slot_write(c["conv"], conv1, slot)}
    return x, new


def prefill_chunk(p, cfg, cache, tokens, start, valid, page_row, slot,
                  *, dtype=jnp.float32, serve_window: int = 0):
    """Process ONE page_size-multiple chunk of a prompt into the paged
    cache (chunked prefill, DESIGN.md §15).

    tokens: (1, C) right-padded chunk; start: traced absolute offset of
    the chunk (a page_size multiple — or the shared-prefix length when
    earlier pages came from the prefix trie); valid: #real tokens in
    the chunk; page_row: (pages_per_slot,) int32 page ids; slot: traced
    recurrent-state lane. One jit signature serves single-shot prefill
    (C >= prompt length) and streamed long prompts alike.

    Returns (new_cache, logits at token ``start + valid - 1``). The
    caller flips the slot live only after the LAST chunk — until then
    the decode-visible page map row stays all-dummy, so interleaved
    decode ticks cannot observe a half-written prefix.
    """
    kind = cfg.kind
    if kind not in PAGED_KINDS:
        raise ValueError(kind)
    B, C = tokens.shape
    start = jnp.asarray(start, jnp.int32).reshape(())
    valid = jnp.asarray(valid, jnp.int32).reshape(())
    slot = jnp.asarray(slot, jnp.int32).reshape(())
    page_row = jnp.asarray(page_row, jnp.int32)
    x = _embed_tokens(p, cfg, tokens, dtype)
    mode, window = "causal", 0
    if cfg.sliding_window:
        mode, window = "sliding", cfg.sliding_window
    elif serve_window and kind not in ("ssm", "hybrid"):
        mode, window = "sliding", serve_window

    def attn_body(lp, xx, c):
        return _chunk_attn_layer(lp, cfg, xx, c, mode=mode, window=window,
                                 start=start, valid=valid,
                                 page_row=page_row)

    def scan(x, stacked_p, stacked_c, body):
        def f(xx, sc):
            lp, c = sc
            return body(lp, xx, c)
        return jax.lax.scan(f, x, (stacked_p, stacked_c))

    if kind == "dense" or (kind == "moe" and cfg.moe_every == 1):
        x, new_cache = scan(x, p["layers"], cache["layers"], attn_body)
        new_cache = {"layers": new_cache}
    elif kind == "moe":
        def body(lp, xx, c):
            new = {}
            for i in range(cfg.moe_every - 1):
                xx, new[f"dense_{i}"] = attn_body(
                    lp[f"dense_{i}"], xx, c[f"dense_{i}"])
            xx, new["moe"] = attn_body(lp["moe"], xx, c["moe"])
            return xx, new
        x, new_cache = scan(x, p["groups"], cache["groups"], body)
        new_cache = {"groups": new_cache}
    elif kind == "ssm":
        def body(lp, xx, c):
            return _chunk_ssm_layer(lp, cfg, xx, c, slot=slot,
                                    start=start, valid=valid)
        x, new_cache = scan(x, p["layers"], cache["layers"], body)
        new_cache = {"layers": new_cache}
    elif kind == "hybrid":
        period = cfg.local_attn_every or 3
        def body(lp, xx, c):
            new = {}
            for i in range(period - 1):
                xx, new[f"rec_{i}"] = _chunk_rec_layer(
                    lp[f"rec_{i}"], cfg, xx, c[f"rec_{i}"],
                    slot=slot, start=start, valid=valid)
            xx, new["attn"] = _chunk_attn_layer(
                lp["attn"], cfg, xx, c["attn"], mode="sliding",
                window=cfg.attention_window, start=start, valid=valid,
                page_row=page_row)
            return xx, new
        new_cache = {}
        if "groups" in p:
            x, gnew = scan(x, p["groups"], cache["groups"], body)
            new_cache["groups"] = gnew
        if "tail" in p:
            def tail_body(lp, xx, c):
                return _chunk_rec_layer(lp, cfg, xx, c, slot=slot,
                                        start=start, valid=valid)
            x, tnew = scan(x, p["tail"], cache["tail"], tail_body)
            new_cache["tail"] = tnew
    else:
        raise ValueError(kind)

    x = apply_norm(cfg, p["ln_final"], x)
    x_last = jax.lax.dynamic_slice_in_dim(
        x, jnp.clip(valid - 1, 0), 1, axis=1)        # (1, 1, d)
    logits = _unembed(p, cfg, x_last)
    return new_cache, logits


def _gate_live(new, old, live):
    """Keep ``old`` on non-live lanes (mid-prefill / retired slots must
    not have their carried recurrent state trampled by decode ticks).
    Leaves with a leading slots axis only — page pools self-protect via
    the dummy page."""
    m = live.reshape((-1,) + (1,) * (new.ndim - 1))
    return jnp.where(m, new, old)


def decode_step_paged(p, cfg, token, cache, pos, page_map, live, *,
                      dtype=jnp.bfloat16, serve_window: int = 0,
                      use_kernel: bool = False):
    """One-token generation step against the PAGED cache.

    token: (B, 1); cache: tree from init_paged_cache_tree; pos: (B,);
    page_map: (B, pages_per_slot) int32 (dummy rows for inactive
    slots); live: (B,) bool — recurrent-state updates are masked off
    for non-live lanes, and their attention writes land in the dummy
    page via the page map. Returns (logits, new_cache).
    """
    kind = cfg.kind
    if kind not in PAGED_KINDS:
        raise ValueError(kind)
    B = token.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    live = jnp.asarray(live, bool).reshape(B)
    x = _embed_tokens(p, cfg, token, dtype)
    w = effective_window(cfg, serve_window)

    def attn_dec(lp, xx, c):
        h = apply_norm(cfg, lp["ln_attn"], xx)
        out, c_new = attn.paged_decode_attention(
            lp["attn"], cfg, h, c, pos, page_map, window=w,
            use_kernel=use_kernel)
        xx = xx + out
        h = apply_norm(cfg, lp["ln_mlp"], xx)
        if "moe" in lp:
            h, _ = moem.apply_moe(lp["moe"], cfg, h)
        else:
            h = mlpm.apply_mlp(lp["mlp"], cfg, h)
        return xx + h, c_new

    def ssm_dec(lp, xx, c):
        h = apply_norm(cfg, lp["ln"], xx)
        y, c_new = ssmm.decode_ssm(lp["ssm"], cfg, h, c)
        c_new = jax.tree.map(lambda n, o: _gate_live(n, o, live), c_new, c)
        return xx + y, c_new

    def rec_dec(lp, xx, c):
        h = apply_norm(cfg, lp["ln_rec"], xx)
        y, c_new = rgm.decode_rglru(lp["rec"], cfg, h, c)
        c_new = jax.tree.map(lambda n, o: _gate_live(n, o, live), c_new, c)
        xx = xx + y
        xx = xx + mlpm.apply_mlp(lp["mlp"], cfg,
                                 apply_norm(cfg, lp["ln_mlp"], xx))
        return xx, c_new

    if kind == "dense" or (kind == "moe" and cfg.moe_every == 1):
        def body(xx, sc):
            lp, c = sc
            return attn_dec(lp, xx, c)
        x, new_cache = jax.lax.scan(body, x, (p["layers"], cache["layers"]))
        new_cache = {"layers": new_cache}
    elif kind == "moe":
        def body(xx, sc):
            lp, c = sc
            new = {}
            for i in range(cfg.moe_every - 1):
                xx, new[f"dense_{i}"] = attn_dec(
                    lp[f"dense_{i}"], xx, c[f"dense_{i}"])
            xx, new["moe"] = attn_dec(lp["moe"], xx, c["moe"])
            return xx, new
        x, new_cache = jax.lax.scan(body, x, (p["groups"], cache["groups"]))
        new_cache = {"groups": new_cache}
    elif kind == "ssm":
        def body(xx, sc):
            lp, c = sc
            return ssm_dec(lp, xx, c)
        x, new_cache = jax.lax.scan(body, x, (p["layers"], cache["layers"]))
        new_cache = {"layers": new_cache}
    elif kind == "hybrid":
        period = cfg.local_attn_every or 3
        def body(xx, sc):
            lp, c = sc
            new = {}
            for i in range(period - 1):
                xx, new[f"rec_{i}"] = rec_dec(
                    lp[f"rec_{i}"], xx, c[f"rec_{i}"])
            xx, new["attn"] = attn_dec(lp["attn"], xx, c["attn"])
            return xx, new
        new_cache = {}
        if "groups" in p:
            x, gnew = jax.lax.scan(body, x,
                                   (p["groups"], cache["groups"]))
            new_cache["groups"] = gnew
        if "tail" in p:
            def tail_body(xx, sc):
                lp, c = sc
                return rec_dec(lp, xx, c)
            x, tnew = jax.lax.scan(tail_body, x,
                                   (p["tail"], cache["tail"]))
            new_cache["tail"] = tnew
    else:
        raise ValueError(kind)

    x = apply_norm(cfg, p["ln_final"], x)
    logits = _unembed(p, cfg, x)
    return logits, new_cache
