from repro.serving.engine import (
    init_cache_tree, cache_logical_axes_tree, prefill, decode_step,
)

__all__ = ["init_cache_tree", "cache_logical_axes_tree", "prefill",
           "decode_step"]
