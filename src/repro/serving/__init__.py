from repro.serving.engine import (
    init_cache_tree, cache_logical_axes_tree, prefill, decode_step,
    write_cache_slot,
)
from repro.serving.sampling import sample_tokens

__all__ = ["init_cache_tree", "cache_logical_axes_tree", "prefill",
           "decode_step", "write_cache_slot", "sample_tokens"]
