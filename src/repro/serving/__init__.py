"""Public serving API: engine primitives, schedulers, sampling, and
the serving sharding layer (DESIGN.md §11, §14, §15).

Import from here — ``launch/serve.py``, benchmarks, and tests should
not deep-import ``repro.serving.*`` modules.
"""
from repro.serving.engine import (
    init_cache_tree, cache_logical_axes_tree, prefill, decode_step,
    write_cache_slot, init_paged_cache_tree, paged_cache_logical_axes_tree,
    prefill_chunk, decode_step_paged,
)
from repro.serving.pages import (
    DUMMY_PAGE, PageTable, PrefixTrie, pages_per_slot,
)
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import (
    BatchScheduler, ContinuousScheduler, PagedContinuousScheduler,
    Request, RequestRecord, SchedulerStats, make_scheduler, run_trace,
)
from repro.serving.sharding import (
    SERVE_CACHE_RULES, SERVE_PARAM_RULES, ServeShardings,
    cache_shardings, paged_cache_shardings, param_shardings,
    serve_shardings, shard_params,
)

__all__ = [
    "init_cache_tree", "cache_logical_axes_tree", "prefill",
    "decode_step", "write_cache_slot", "init_paged_cache_tree",
    "paged_cache_logical_axes_tree", "prefill_chunk", "decode_step_paged",
    "DUMMY_PAGE", "PageTable", "PrefixTrie", "pages_per_slot",
    "sample_tokens",
    "BatchScheduler", "ContinuousScheduler", "PagedContinuousScheduler",
    "Request", "RequestRecord", "SchedulerStats", "make_scheduler",
    "run_trace",
    "SERVE_CACHE_RULES", "SERVE_PARAM_RULES", "ServeShardings",
    "cache_shardings", "paged_cache_shardings", "param_shardings",
    "serve_shardings", "shard_params",
]
