"""Serving-side sharding: the rule tables and resolved shardings that
thread ``repro.dist`` through the inference engine (DESIGN.md §14).

Two tables, same layer as the train tables in ``launch/steps.py``:

* :data:`SERVE_PARAM_RULES` — weights tensor-parallel over ``model``
  (heads / ffn / experts), replicated over the replica axes (latency
  path); expert weights additionally FSDP-sharded over ``(pod, data)``
  (memory).
* :data:`SERVE_CACHE_RULES` — cache leaves sharded along heads/experts
  first (``cache_kv_heads`` / ``ssm_heads`` / ``rnn_width`` over
  ``model``), with ``cache_seq`` as the model-axis FALLBACK for configs
  whose head count does not divide the mesh (table order is the
  priority — see ``ShardingRules.spec_for_shape``), and the slot/batch
  dimension over the replica axes when it divides.

All resolution is shape-aware (``spec_for_shape``): a small config on a
big mesh degrades toward replication instead of failing to place, so
one table serves the 8-device host smoke and the 512-chip dryrun.

:func:`serve_shardings` bundles the resolved `NamedSharding`s for one
(model, mesh, slot geometry) into a :class:`ServeShardings`; both
schedulers and the dryrun serve program pin their jit boundaries with
it, which is what keeps the admission splice (`write_cache_slot`)
sharding-preserving without any resharding collective.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import ShardingRules

# Params: tensor-parallel over model, replicated over (pod, data) —
# the latency path keeps every replica axis free for cache slots.
# Expert weights stay FSDP-sharded (the giant-MoE memory story).
SERVE_PARAM_RULES = ShardingRules((
    ("batch", ("pod", "data")),
    ("embed", None),
    ("embed_nomodel", None),
    ("vocab", "model"),
    ("q_proj", "model"),
    ("kv_proj", "model"),
    ("ffn", "model"),
    ("experts", "model"),
    ("expert_ffn", None),
    ("experts_router", None),
    ("embed_fsdp", ("pod", "data")),
    ("ssm_in", "model"),
    ("ssm_heads", "model"),
    ("ssm_state", None),
    ("rnn_width", "model"),
    ("rnn_width_in", None),
    ("conv_k", None),
    ("layers", None),
))

# Cache leaves: heads/experts first, sequence as the model-axis
# fallback (table order = contention priority under spec_for_shape).
# Paged leaves reuse the same head/TP placement; the page pool and
# in-page offset dims stay replicated (pages are the unit of host-side
# allocation — splitting them across devices would turn every page-map
# gather into a collective).
SERVE_CACHE_RULES = ShardingRules((
    ("cache_kv_heads", "model"),
    ("ssm_heads", "model"),
    ("rnn_width", "model"),
    ("ssm_in", "model"),
    ("cache_seq", "model"),
    ("cache_batch", ("pod", "data")),
    ("cache_pages", None),
    ("page_off", None),
    ("head_dim", None),
    ("ssm_state", None),
    ("layers", None),
))


def _shard_shaped(axes_tree, abs_tree, mesh: Mesh, rules: ShardingRules):
    """Per-leaf NamedSharding from (logical axes, abstract shapes)."""
    is_ax = lambda x: isinstance(x, tuple)  # noqa: E731
    flat_ax, treedef = jax.tree_util.tree_flatten(axes_tree, is_leaf=is_ax)
    flat_ab = jax.tree_util.tree_flatten(abs_tree)[0]
    assert len(flat_ax) == len(flat_ab), (len(flat_ax), len(flat_ab))
    out = [NamedSharding(mesh,
                         rules.spec_for_shape(tuple(ax), tuple(ab.shape),
                                              mesh))
           for ax, ab in zip(flat_ax, flat_ab)]
    return jax.tree_util.tree_unflatten(treedef, out)


def param_shardings(model, mesh: Mesh, *,
                    rules: Optional[ShardingRules] = None,
                    param_dtype=jnp.float32):
    """Shape-aware serve-phase NamedSharding tree for the params."""
    rules = rules or SERVE_PARAM_RULES
    abs_p, axes = model.abstract_params(dtype=param_dtype)
    return _shard_shaped(axes, abs_p, mesh, rules)


def cache_shardings(model, mesh: Mesh, batch: int, seq_len: int,
                    dtype=jnp.bfloat16, *, serve_window: int = 0,
                    cache_rules: Optional[ShardingRules] = None):
    """NamedSharding tree matching ``init_cache_tree``'s structure."""
    rules = cache_rules or SERVE_CACHE_RULES
    abs_c = model.abstract_cache(batch, seq_len, dtype,
                                 serve_window=serve_window)
    axes = model.cache_axes()
    return _shard_shaped(axes, abs_c, mesh, rules)


@dataclass(frozen=True)
class ServeShardings:
    """Resolved shardings for one (model, mesh, slot geometry)."""
    mesh: Mesh
    rules: ShardingRules            # param table
    cache_rules: ShardingRules      # cache table
    params: Any                     # NamedSharding tree
    cache: Any                      # NamedSharding tree
    token: NamedSharding            # (slots, 1) int32
    logits: NamedSharding           # (slots, 1, vocab)
    pos: NamedSharding              # (slots,) int32
    replicated: NamedSharding
    # paged layout (set when serve_shardings gets page_size > 0)
    paged_cache: Any = None         # NamedSharding tree (page pools)
    page_map: Optional[NamedSharding] = None   # (slots, pages_per_slot)
    live: Optional[NamedSharding] = None       # (slots,) bool


def paged_cache_shardings(model, mesh: Mesh, slots: int, cache_pages: int,
                          page_size: int, dtype=jnp.bfloat16, *,
                          cache_rules: Optional[ShardingRules] = None):
    """NamedSharding tree matching ``init_paged_cache_tree``'s
    structure: heads TP over ``model``, page/offset dims replicated."""
    rules = cache_rules or SERVE_CACHE_RULES
    abs_c = model.abstract_paged_cache(slots, cache_pages, page_size,
                                       dtype)
    axes = model.paged_cache_axes()
    return _shard_shaped(axes, abs_c, mesh, rules)


def serve_shardings(model, mesh: Mesh, *, slots: int, max_total: int,
                    dtype=jnp.float32, serve_window: int = 0,
                    param_dtype=None, page_size: int = 0,
                    cache_pages: int = 0,
                    rules: Optional[ShardingRules] = None,
                    cache_rules: Optional[ShardingRules] = None
                    ) -> ServeShardings:
    """Resolve every sharding the serving stack pins at jit boundaries.

    ``dtype`` is the cache dtype (shapes only — resolution is dtype-
    free); ``param_dtype`` defaults to ``dtype``. Pass ``page_size`` /
    ``cache_pages`` to additionally resolve the paged cache tree and
    its page-map/live inputs (replicated — they are tiny i32/bool
    control state every device needs whole).
    """
    rules = rules or SERVE_PARAM_RULES
    cache_rules = cache_rules or SERVE_CACHE_RULES
    p_sh = param_shardings(model, mesh, rules=rules,
                           param_dtype=param_dtype or dtype)
    c_sh = cache_shardings(model, mesh, slots, max_total, dtype,
                           serve_window=serve_window,
                           cache_rules=cache_rules)
    V = model.cfg.padded_vocab   # logits carry the padded width
    tok = NamedSharding(mesh, cache_rules.spec_for_shape(
        ("cache_batch", None), (slots, 1), mesh))
    lg = NamedSharding(mesh, cache_rules.spec_for_shape(
        ("cache_batch", None, None), (slots, 1, V), mesh))
    repl = NamedSharding(mesh, P())
    paged_kw = {}
    if page_size:
        paged_kw = dict(
            paged_cache=paged_cache_shardings(
                model, mesh, slots, cache_pages, page_size, dtype,
                cache_rules=cache_rules),
            page_map=repl, live=repl)
    return ServeShardings(
        mesh=mesh, rules=rules, cache_rules=cache_rules, params=p_sh,
        cache=c_sh, token=tok, logits=lg,
        pos=NamedSharding(mesh, P()),
        replicated=repl, **paged_kw)


def shard_params(params, model, mesh: Mesh, *,
                 rules: Optional[ShardingRules] = None):
    """Place a live param tree onto ``mesh`` under the serve rules."""
    rules = rules or SERVE_PARAM_RULES
    _, axes = model.abstract_params()
    sh = _shard_shaped(axes, params, mesh, rules)
    return jax.tree.map(jax.device_put, params, sh)


__all__ = ["SERVE_PARAM_RULES", "SERVE_CACHE_RULES", "ServeShardings",
           "serve_shardings", "param_shardings", "cache_shardings",
           "paged_cache_shardings", "shard_params"]
