"""Page-table memory management for the paged serving cache.

The paged engine (DESIGN.md §15) stores attention K/V as a pool of
fixed-size pages ``(num_pages, page_size, kv_heads, head_dim)`` instead
of one ``(slots, max_total)`` ring per lane. Two host-side structures
own that pool — everything here is plain Python/numpy bookkeeping; the
device only ever sees the static-shape ``(slots, pages_per_slot)`` page
map, so the PR 5 single-jit-signature invariant holds:

* :class:`PageTable` — free-list allocation with per-page refcounts.
  Page 0 is a reserved **dummy page**: retired / mid-prefill slots keep
  an all-dummy page-map row, so their (masked) decode writes land in a
  garbage sink instead of a live request's memory.

* :class:`PrefixTrie` — the resident-prefix index for prefix sharing.
  Nodes are keyed ``(parent_page, page_size-token chunk) -> page``;
  admission walks the prompt's full-page chunks and retains every
  matched page instead of re-prefilling it. Registration happens at
  prefill *completion* (a page is only shareable once its K/V are
  actually written), and a page leaves the trie the moment its refcount
  drops to zero.

Allocation is all-upfront at admission (``ceil((plen + budget) /
page_size)`` pages minus the shared prefix), so decode never allocates
and the only OOM point is admission — which defers instead of failing.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

DUMMY_PAGE = 0


def pages_per_slot(max_total: int, page_size: int) -> int:
    """Static page-map width: enough pages for a full-length request."""
    return -(-max_total // page_size)


@dataclass
class PageTable:
    """Refcounted free-list allocator over ``num_pages`` cache pages.

    ``num_pages`` INCLUDES the reserved dummy page 0, mirroring the
    device-side pool shape; usable capacity is ``num_pages - 1``.
    """
    num_pages: int
    page_size: int
    _free: List[int] = field(default_factory=list)
    _ref: Dict[int, int] = field(default_factory=dict)

    def __post_init__(self):
        assert self.num_pages >= 2, "need at least one usable page"
        assert self.page_size >= 1
        # LIFO free list: recently-freed pages are reused first (their
        # contents are dead by construction — validity is masked by pos)
        self._free = list(range(self.num_pages - 1, DUMMY_PAGE, -1))
        self._ref = {}

    # ------------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_live(self) -> int:
        return len(self._ref)

    @property
    def occupancy(self) -> float:
        usable = self.num_pages - 1
        return self.num_live / max(usable, 1)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` fresh pages (refcount 1 each); None if short —
        the scheduler's cue to defer admission, not an error."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for pg in pages:
            self._ref[pg] = 1
        return pages

    def retain(self, pages: Sequence[int]) -> None:
        """Bump refcounts of already-live pages (prefix sharing)."""
        for pg in pages:
            if pg == DUMMY_PAGE or pg not in self._ref:
                raise ValueError(f"retain of non-live page {pg}")
            self._ref[pg] += 1

    def release(self, pages: Sequence[int]) -> List[int]:
        """Drop one reference per page; returns the pages that hit
        refcount zero (now back on the free list)."""
        freed = []
        for pg in pages:
            if pg == DUMMY_PAGE or pg not in self._ref:
                raise ValueError(f"release of non-live page {pg}")
            self._ref[pg] -= 1
            if self._ref[pg] == 0:
                del self._ref[pg]
                self._free.append(pg)
                freed.append(pg)
        return freed

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)


class PrefixTrie:
    """Resident-prefix index: full-page token chunks -> live page ids.

    A node ``(parent_page, chunk) -> page`` means: the prompt prefix
    that ends with ``chunk`` (page_size tokens) on top of the prefix
    resident in ``parent_page``'s chain is cached in ``page``. The root
    parent is ``DUMMY_PAGE`` (no real page ever maps there).
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self._nodes: Dict[Tuple[int, Tuple[int, ...]], int] = {}
        self._rev: Dict[int, Tuple[int, Tuple[int, ...]]] = {}

    def __len__(self) -> int:
        return len(self._nodes)

    def _chunks(self, prompt: np.ndarray, n: int):
        ps = self.page_size
        for ci in range(n):
            yield tuple(int(t) for t in prompt[ci * ps:(ci + 1) * ps])

    def match(self, prompt: np.ndarray, max_pages: int) -> List[int]:
        """Longest resident prefix of ``prompt``, as page ids, capped at
        ``max_pages`` (callers cap at ``(plen - 1) // page_size`` so at
        least one prompt token is always prefilled — the admission
        logits come from a real forward pass, never from a cache hit)."""
        pages: List[int] = []
        parent = DUMMY_PAGE
        for chunk in self._chunks(prompt, max_pages):
            page = self._nodes.get((parent, chunk))
            if page is None:
                break
            pages.append(page)
            parent = page
        return pages

    def register(self, prompt: np.ndarray, page_ids: Sequence[int]) -> int:
        """Publish ``prompt``'s first ``len(page_ids)`` full-page chunks
        as resident in ``page_ids``. Existing nodes win (first writer
        keeps the slot; the duplicate pages simply stay unshared).
        Returns the number of newly published pages."""
        added = 0
        parent = DUMMY_PAGE
        for ci, chunk in enumerate(self._chunks(prompt, len(page_ids))):
            key = (parent, chunk)
            page = self._nodes.get(key)
            if page is None:
                page = page_ids[ci]
                if page in self._rev:       # one trie slot per page
                    parent = page
                    continue
                self._nodes[key] = page
                self._rev[page] = key
                added += 1
            parent = page
        return added

    def forget(self, page: int) -> None:
        """Remove a freed page from the index (no-op if absent). By the
        prefix-closed retention invariant a freed page has no resident
        children, so single-node removal is complete."""
        key = self._rev.pop(page, None)
        if key is not None:
            del self._nodes[key]


__all__ = ["DUMMY_PAGE", "PageTable", "PrefixTrie", "pages_per_slot"]
