"""Batched request schedulers over the model zoo's prefill/decode steps.

Two admission policies, one slot-based execution model (static shapes,
a single jit signature for the process lifetime):

* :class:`BatchScheduler` — wave batching. Up to ``slots`` requests are
  packed into one fixed-shape batch, prefilled jointly, and decoded
  together; the next wave is admitted only when the batch drains, so
  early-finishing slots idle until the longest request completes.

* :class:`ContinuousScheduler` — continuous batching. Each slot is an
  independent lane over one shared cache: a freed slot is immediately
  re-prefilled (a batch-1 prefill written into the live cache along the
  batch axis via ``write_cache_slot``) while the other slots keep
  decoding. Per-slot ``pos`` vectors carry each lane's absolute
  position through ``decode_step``.

Both right-pad prompts to ``max_prompt`` and pass per-request
``lengths`` to prefill, so padded prefixes never enter attention and
per-request generation budgets are enforced without any per-step
host sync.
"""
from __future__ import annotations

from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.sink import NULL_OBS
from repro.serving.sampling import sample_tokens

if TYPE_CHECKING:  # annotation-only: keeps repro.serving import-cycle-free
    from repro.models import ModelApi


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (T,) int32
    max_new: int = 16
    out_tokens: list = field(default_factory=list)
    done: bool = False
    budget: int = 0                 # set at admission
    # lifecycle stamps in scheduler-step clock ticks (repro.obs §13);
    # -1 = never happened (e.g. first_token of a zero-budget request)
    submit_clock: int = -1
    admit_clock: int = -1
    first_token_clock: int = -1
    retire_clock: int = -1
    # paged-scheduler provenance (DESIGN.md §15): how the prompt entered
    # the cache — #prefill chunks run, #pages borrowed from the trie
    prefill_chunks: int = 0
    prefix_pages_reused: int = 0


@dataclass
class RequestRecord:
    """One retired request's latency breakdown, in step-clock ticks."""
    rid: int
    submit: int
    admit: int
    first_token: int
    retire: int
    decode: int                     # tokens generated
    budget: int
    prefill_chunks: int = 0
    prefix_pages_reused: int = 0

    @property
    def queue_latency(self) -> int:
        return self.admit - self.submit if self.admit >= 0 else -1

    @property
    def ttft(self) -> int:
        return (self.first_token - self.submit
                if self.first_token >= 0 else -1)

    @property
    def prefill_latency(self) -> int:
        """Ticks between admission and the first sampled token — the
        chunked-prefill share of TTFT (TTFT = queue_latency + this)."""
        return (self.first_token - self.admit
                if self.first_token >= 0 and self.admit >= 0 else -1)


@dataclass
class SchedulerStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_generated: int = 0
    requests_done: int = 0
    slot_steps: int = 0             # slots * decode_steps
    live_slot_steps: int = 0        # slots actually generating
    # one RequestRecord per retired request, in retirement order —
    # run_trace returns stats, so per-request latencies ride along
    # without changing any signature
    records: list = field(default_factory=list)

    @property
    def utilization(self) -> float:
        return self.live_slot_steps / max(self.slot_steps, 1)


class _SchedulerBase:
    """Shared request plumbing: queue, slots, padding, sampling.

    With ``mesh``, the scheduler serves multi-device: params/cache/
    logits shardings are resolved once (``serving.sharding.serve_
    shardings``) and pinned as jit out_shardings, so every compiled
    entry point keeps its single process-lifetime signature (PR 5
    invariant) while the cache lives sharded across the mesh.
    """

    def __init__(self, model: ModelApi, *, slots: int = 4,
                 max_prompt: int = 64, max_total: int = 128,
                 temperature: float = 0.0, seed: int = 0,
                 cache_dtype=jnp.float32, obs=NULL_OBS, mesh=None,
                 rules=None, cache_rules=None, **shard_kw):
        assert max_prompt <= max_total
        if model.cfg.kind in ("vlm", "encdec", "audio"):
            raise ValueError(
                f"{type(self).__name__} serves token-only requests; "
                f"arch kind {model.cfg.kind!r} needs frontend inputs "
                "(patches/frames) that Request does not carry")
        self.model = model
        self.slots = slots
        self.max_prompt = max_prompt
        self.max_total = max_total
        self.temperature = temperature
        self.cache_dtype = cache_dtype
        self.key = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.active: list[Optional[Request]] = [None] * slots
        self.stats = SchedulerStats()
        self.obs = obs
        self.mesh = mesh
        self.shardings = None
        if mesh is not None:
            from repro.serving.sharding import serve_shardings
            self.shardings = serve_shardings(
                model, mesh, slots=slots, max_total=max_total,
                dtype=cache_dtype, rules=rules, cache_rules=cache_rules,
                **shard_kw)
        # the step clock: one tick per step() call (admission attempts
        # and decode steps alike) — all Request stamps use this clock
        self.clock = 0

    def _mesh_ctx(self):
        """Ambient-mesh context for jit tracing/execution: the in-model
        ``hint`` calls resolve against it; ``nullcontext`` when serving
        single-device."""
        return self.mesh if self.mesh is not None else nullcontext()

    def submit(self, req: Request) -> None:
        assert 1 <= len(req.prompt) <= self.max_prompt
        if req.submit_clock < 0:
            req.submit_clock = self.clock
        self.queue.append(req)

    @property
    def outstanding(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.active)

    def _budget(self, req: Request) -> int:
        # the cache holds prompt + generated tokens: never decode past it
        return min(req.max_new, self.max_total - len(req.prompt))

    def _retire(self, req: Request) -> None:
        """Mark done, stamp the clock, append the latency record."""
        req.done = True
        req.retire_clock = self.clock
        self.stats.requests_done += 1
        self.stats.records.append(RequestRecord(
            rid=req.rid, submit=req.submit_clock, admit=req.admit_clock,
            first_token=req.first_token_clock, retire=req.retire_clock,
            decode=len(req.out_tokens), budget=req.budget,
            prefill_chunks=req.prefill_chunks,
            prefix_pages_reused=req.prefix_pages_reused))

    # -- slot lifecycle hooks (overridden by the paged scheduler) -------
    def _slot_ready(self, i: int) -> bool:
        """Is slot ``i`` producing valid logits? (Paged slots are not
        ready while their chunked prefill is still streaming in.)"""
        return True

    def _free_slot(self, i: int) -> None:
        """Release slot ``i``'s resources after retirement."""
        self.active[i] = None

    def _work_pending(self) -> bool:
        """Non-queue work in flight (e.g. unfinished chunked prefills)
        that must keep ``run`` stepping even when no tokens came out."""
        return False

    def _take_next(self) -> Optional[Request]:
        """Pop the next admissible request; zero-budget requests (prompt
        already fills the cache) complete immediately with no tokens."""
        while self.queue:
            req = self.queue.pop(0)
            req.budget = self._budget(req)
            if req.budget > 0:
                req.admit_clock = self.clock
                return req
            req.admit_clock = self.clock
            self._retire(req)
        return None

    def _sample(self, logits) -> jnp.ndarray:
        k = None
        if self.temperature > 0:
            self.key, k = jax.random.split(self.key)
        return sample_tokens(logits, temperature=self.temperature, key=k)

    def _emit(self, tok_np) -> int:
        """Append sampled tokens to live requests; retire exhausted ones."""
        emitted = 0
        for i, r in enumerate(self.active):
            if r is None or r.done or not self._slot_ready(i):
                continue
            r.out_tokens.append(int(tok_np[i]))
            if r.first_token_clock < 0:
                r.first_token_clock = self.clock
            emitted += 1
            if len(r.out_tokens) >= r.budget:
                self._retire(r)
                self._free_slot(i)
        self.stats.tokens_generated += emitted
        return emitted

    def _decode_tick(self, params) -> int:
        """Sample from the held logits, emit/retire, then decode the
        batch one step (skipped when every lane just retired — the
        final tokens need no decode)."""
        tok = self._sample(self._last_logits)
        emitted = self._emit(np.asarray(tok)[:, 0])
        if not any(r is not None for r in self.active):
            return emitted
        with self.obs.span("decode_step", step=self.clock):
            with self._mesh_ctx():
                self._last_logits, self._cache = self._decode(
                    params, tok, self._cache, self._pos)
        self._pos = self._pos + 1
        self.stats.decode_steps += 1
        self.stats.slot_steps += self.slots
        self.stats.live_slot_steps += sum(
            r is not None and self._slot_ready(i)
            for i, r in enumerate(self.active))
        return emitted

    def _tick(self) -> None:
        """Advance the step clock + record the slot/queue gauges."""
        self.clock += 1
        if self.obs.enabled:
            self.obs.counter(
                "scheduler",
                live_slots=sum(r is not None for r in self.active),
                queue_depth=len(self.queue),
                tokens=self.stats.tokens_generated)

    def run(self, params, max_steps: int = 1000) -> SchedulerStats:
        steps = 0
        with self.obs.span("run", scheduler=type(self).__name__,
                           slots=self.slots):
            while self.outstanding and steps < max_steps:
                if self.step(params) == 0 and not self.queue \
                        and not self._work_pending():
                    break
                steps += 1
        if self.outstanding:
            import warnings
            warnings.warn(
                f"{type(self).__name__}.run hit max_steps={max_steps} "
                "with requests still outstanding — results are "
                "truncated; raise max_steps", RuntimeWarning,
                stacklevel=2)
        return self.stats


class BatchScheduler(_SchedulerBase):
    """Slot-based wave batching (static shapes, per-slot pos)."""

    def __init__(self, model: ModelApi, **kw):
        super().__init__(model, **kw)
        max_total = self.max_total
        cache_dtype = self.cache_dtype
        sh = self.shardings
        jit_kw_pf = {} if sh is None else {
            "out_shardings": (sh.logits, sh.cache, sh.pos)}
        jit_kw_dec = {} if sh is None else {
            "out_shardings": (sh.logits, sh.cache)}
        self._prefill = jax.jit(lambda p, b, l: model.prefill(
            p, b, dtype=jnp.float32, cache_dtype=cache_dtype,
            cache_len=max_total, lengths=l), **jit_kw_pf)
        self._decode = jax.jit(lambda p, t, c, s: model.decode_step(
            p, t, c, s, dtype=jnp.float32), **jit_kw_dec)
        self._cache = None
        self._pos = None            # (slots,) per-slot absolute position
        self._last_logits = None

    # ------------------------------------------------------------------
    def _admit(self, params) -> bool:
        """Fill free slots from the queue and prefill the wave jointly.

        Prompts are RIGHT-padded to ``max_prompt`` (one prefill jit
        signature for the process lifetime) with per-request ``lengths``
        so padded tails never enter attention or the cache."""
        free = [i for i, r in enumerate(self.active) if r is None]
        if not free or not self.queue:
            return False
        for i in free:
            req = self._take_next()
            if req is None:
                break
            self.active[i] = req
        if not any(r is not None for r in self.active):
            return False
        toks = np.zeros((self.slots, self.max_prompt), np.int32)
        lens = np.zeros((self.slots,), np.int32)
        for i, r in enumerate(self.active):
            if r is not None:
                toks[i, : len(r.prompt)] = r.prompt
                lens[i] = len(r.prompt)
        with self.obs.span("prefill", wave=self.stats.prefills,
                           requests=int((lens > 0).sum())):
            with self._mesh_ctx():
                logits, cache, pos = self._prefill(
                    params, {"tokens": jnp.asarray(toks)},
                    jnp.asarray(lens))
        self._cache = cache
        self._pos = pos             # (slots,) = per-request prompt length
        self._last_logits = logits
        self.stats.prefills += 1
        return True

    def step(self, params) -> int:
        """One decode step for all live slots; returns #tokens emitted."""
        self._tick()
        if self._cache is None:
            with self.obs.span("admission", step=self.clock):
                admitted = self._admit(params)
            if not admitted:
                return 0
        emitted = self._decode_tick(params)
        if not any(r is not None for r in self.active):
            self._cache = None  # drained -> allow the next admission wave
        return emitted


class ContinuousScheduler(_SchedulerBase):
    """Per-slot admission/retirement without draining the batch.

    The cache for all ``slots`` lanes is allocated once; a freed slot is
    refilled by a batch-1 prefill spliced in along the batch axis
    (``jax.lax.dynamic_update_slice`` with a *traced* slot index), so
    admission, like decode, has a single jit signature for the process
    lifetime."""

    def __init__(self, model: ModelApi, **kw):
        super().__init__(model, **kw)
        cfg = model.cfg
        slots, max_total = self.slots, self.max_total
        cache_dtype = self.cache_dtype
        sh = self.shardings
        crules = None if sh is None else sh.cache_rules
        self._cache = model.init_cache(slots, max_total, cache_dtype,
                                       mesh=self.mesh, cache_rules=crules)
        self._pos = jnp.zeros((slots,), jnp.int32)
        self._last_logits = jnp.zeros((slots, 1, cfg.padded_vocab),
                                      jnp.float32)
        if sh is not None:
            self._pos = jax.device_put(self._pos, sh.pos)
            self._last_logits = jax.device_put(self._last_logits,
                                               sh.logits)

        def _admit_fn(params, cache, pos, logits, tokens, length, slot):
            lg1, c1, p1 = model.prefill(
                params, {"tokens": tokens}, dtype=jnp.float32,
                cache_dtype=cache_dtype, cache_len=max_total,
                lengths=length)
            cache, pos = model.write_cache_slot(cache, c1, slot, pos=pos,
                                                one_pos=p1[0],
                                                cache_rules=crules)
            logits = jax.lax.dynamic_update_slice(logits, lg1, (slot, 0, 0))
            return cache, pos, logits

        jit_kw_adm = {} if sh is None else {
            "out_shardings": (sh.cache, sh.pos, sh.logits)}
        jit_kw_dec = {} if sh is None else {
            "out_shardings": (sh.logits, sh.cache)}
        self._admit_one = jax.jit(_admit_fn, **jit_kw_adm)
        self._decode = jax.jit(lambda p, t, c, s: model.decode_step(
            p, t, c, s, dtype=jnp.float32), **jit_kw_dec)

    # ------------------------------------------------------------------
    def _admit(self, params) -> int:
        """Prefill queued requests into every free slot; others keep
        their cache/pos untouched."""
        admitted = 0
        for i, r in enumerate(self.active):
            if r is not None or not self.queue:
                continue
            req = self._take_next()
            if req is None:
                break
            self.active[i] = req
            toks = np.zeros((1, self.max_prompt), np.int32)
            toks[0, : len(req.prompt)] = req.prompt
            with self.obs.span("prefill", slot=i, rid=req.rid):
                with self._mesh_ctx():
                    self._cache, self._pos, self._last_logits = \
                        self._admit_one(
                            params, self._cache, self._pos,
                            self._last_logits, jnp.asarray(toks),
                            jnp.asarray([len(req.prompt)], jnp.int32),
                            jnp.asarray(i, jnp.int32))
            self.stats.prefills += 1
            admitted += 1
        return admitted

    def step(self, params) -> int:
        """Admit into free slots, then one decode step for the batch."""
        self._tick()
        with self.obs.span("admission", step=self.clock):
            self._admit(params)
        if not any(r is not None for r in self.active):
            return 0
        return self._decode_tick(params)


class PagedContinuousScheduler(_SchedulerBase):
    """Continuous batching over the PAGED cache (DESIGN.md §15).

    Attention K/V live in a shared refcounted page pool instead of one
    ``(slots, max_total)`` ring per lane:

    * **Admission** allocates ``ceil((plen + budget) / page_size)``
      pages up front (minus any shared prefix) — when the free list is
      short the head request DEFERS in the queue instead of failing, so
      memory pressure degrades to queueing latency, never to an OOM.
    * **Prefix sharing**: prompts are hashed against the resident-prefix
      trie; matched full-page chunks are retained (refcount++) and the
      prefill starts after them. Pages are published to the trie at
      prefill *completion* and forgotten when their refcount hits zero.
      Only attention-cache families share (dense/moe) — recurrent state
      is per-request and cannot be borrowed.
    * **Chunked prefill**: prompts stream in ``prefill_chunk``-sized
      pieces (a page_size multiple), at most ``chunks_per_tick`` chunk
      launches per scheduler tick, interleaved with decode steps for the
      live lanes. A slot flips live only after its last chunk, so decode
      never observes a half-written prefix: until then its page-map row
      is all-dummy and its recurrent state is masked via ``live``.

    The device only ever sees static shapes — the page map is a fixed
    ``(slots, pages_per_slot)`` i32 array — so both entry points keep
    the single process-lifetime jit signature (PR 5 invariant).
    """

    def __init__(self, model: ModelApi, *, page_size: int = 16,
                 cache_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 chunks_per_tick: int = 1,
                 paged_kernel: Optional[bool] = None, **kw):
        from repro.serving.pages import (DUMMY_PAGE, PageTable, PrefixTrie,
                                         pages_per_slot)
        self.page_size = page_size
        self.pages_slot = pages_per_slot(
            kw.get("max_total", 128), page_size)
        if cache_pages is None:
            # default: every slot can hold a full-length request (+1 for
            # the dummy page) — byte-parity with the ring layout; pass
            # fewer pages to trade capacity for queueing (the
            # --memory-ceiling benchmark regime)
            cache_pages = kw.get("slots", 4) * self.pages_slot + 1
        self.cache_pages = cache_pages
        super().__init__(
            model, **kw,
            **({"page_size": page_size, "cache_pages": cache_pages}
               if kw.get("mesh") is not None else {}))
        cfg = model.cfg
        slots = self.slots
        if prefill_chunk is None:
            prefill_chunk = -(-self.max_prompt // page_size) * page_size
        assert prefill_chunk % page_size == 0 and prefill_chunk > 0, \
            "prefill_chunk must be a positive page_size multiple"
        self.prefill_chunk_len = prefill_chunk
        self.chunks_per_tick = chunks_per_tick
        if paged_kernel is None:
            from repro.kernels import runtime
            paged_kernel = not runtime.default_interpret()
        self.paged_kernel = paged_kernel
        # page pools only exist for attention-bearing families; pure-SSM
        # archs carry O(1) per-slot state and need zero pages
        self._has_pages = cfg.kind != "ssm"
        self._shareable = cfg.kind in ("dense", "moe")
        self._dummy = DUMMY_PAGE
        self.table = PageTable(cache_pages, page_size)
        self.trie = PrefixTrie(page_size)
        # memory-pressure / prefix-sharing counters (benchmarks read
        # these; obs gauges mirror them per tick)
        self.page_deferrals = 0
        self.prefix_pages_hit = 0
        self.prefix_pages_possible = 0

        self._page_map = np.full((slots, self.pages_slot), DUMMY_PAGE,
                                 np.int32)
        self._live = np.zeros((slots,), bool)
        self._slot_pages: list[Optional[list]] = [None] * slots
        self._jobs: dict[int, dict] = {}

        sh = self.shardings
        crules = None if sh is None else sh.cache_rules
        self._cache = model.init_paged_cache(
            slots, cache_pages, page_size, self.cache_dtype,
            mesh=self.mesh, cache_rules=crules)
        self._pos = jnp.zeros((slots,), jnp.int32)
        self._last_logits = jnp.zeros((slots, 1, cfg.padded_vocab),
                                      jnp.float32)
        if sh is not None:
            self._pos = jax.device_put(self._pos, sh.pos)
            self._last_logits = jax.device_put(self._last_logits,
                                               sh.logits)

        def _chunk_fn(params, cache, logits, tokens, start, valid, row,
                      slot):
            c1, lg = model.prefill_chunk(
                params, cache, tokens, start, valid, row, slot,
                dtype=jnp.float32)
            logits = jax.lax.dynamic_update_slice(logits, lg,
                                                  (slot, 0, 0))
            return c1, logits

        use_kernel = self.paged_kernel
        jit_kw_ch = {} if sh is None else {
            "out_shardings": (sh.paged_cache, sh.logits)}
        jit_kw_dec = {} if sh is None else {
            "out_shardings": (sh.logits, sh.paged_cache)}
        self._chunk_jit = jax.jit(_chunk_fn, **jit_kw_ch)
        self._decode_jit = jax.jit(
            lambda p, t, c, s, pm, lv: model.decode_step_paged(
                p, t, c, s, pm, lv, dtype=jnp.float32,
                use_kernel=use_kernel), **jit_kw_dec)

    # -- page planning --------------------------------------------------
    def _plan_pages(self, req: Request, budget: int):
        """(shared, fresh) page lists for a request, or None to defer.

        Commit is atomic: the trie match is only retained once the fresh
        allocation is known to fit, so a deferral leaves no refcounts
        behind."""
        if not self._has_pages:
            return [], []
        plen = len(req.prompt)
        total = -(-(plen + budget) // self.page_size)
        assert total <= self.pages_slot
        shared: list = []
        if self._shareable:
            # cap: at least one prompt token always prefills, so the
            # admission logits come from a real forward pass
            cap = min((plen - 1) // self.page_size, total)
            shared = self.trie.match(np.asarray(req.prompt), cap)
            self.prefix_pages_possible += cap
        need = total - len(shared)
        if self.table.num_free < need:
            return None
        if shared:
            self.table.retain(shared)
            self.prefix_pages_hit += len(shared)
        fresh = self.table.alloc(need)
        assert fresh is not None
        return shared, fresh

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_pages_hit / max(self.prefix_pages_possible, 1)

    # -- slot lifecycle -------------------------------------------------
    def _slot_ready(self, i: int) -> bool:
        return bool(self._live[i])

    def _free_slot(self, i: int) -> None:
        pages = self._slot_pages[i]
        if pages:
            for pg in self.table.release(pages):
                self.trie.forget(pg)
        self._slot_pages[i] = None
        self._page_map[i] = self._dummy
        self._live[i] = False
        self._jobs.pop(i, None)
        self.active[i] = None

    def _work_pending(self) -> bool:
        return bool(self._jobs)

    # -- admission / prefill --------------------------------------------
    def _admit(self) -> int:
        """Plan pages + enqueue a chunked-prefill job per free slot.
        Head-of-line deferral: if the head request's pages don't fit,
        admission stops until retirements refill the free list."""
        admitted = 0
        for i in range(self.slots):
            if self.active[i] is not None or not self.queue:
                continue
            req = self.queue[0]
            budget = self._budget(req)
            if budget <= 0:
                self.queue.pop(0)
                req.budget = budget
                req.admit_clock = self.clock
                self._retire(req)
                continue
            plan = self._plan_pages(req, budget)
            if plan is None:
                self.page_deferrals += 1
                break
            self.queue.pop(0)
            shared, fresh = plan
            req.budget = budget
            req.admit_clock = self.clock
            req.prefix_pages_reused = len(shared)
            self.active[i] = req
            pages = shared + fresh
            self._slot_pages[i] = pages
            self._jobs[i] = {
                "req": req, "pages": pages,
                "start": len(shared) * self.page_size,
                "plen": len(req.prompt)}
            admitted += 1
        return admitted

    def _advance_prefills(self, params) -> None:
        """Run up to ``chunks_per_tick`` prefill chunks per pending job;
        completed slots splice their page row in and flip live."""
        C = self.prefill_chunk_len
        P = self.pages_slot
        for slot in list(self._jobs):
            job = self._jobs[slot]
            req = job["req"]
            row = np.full((P,), self._dummy, np.int32)
            row[: len(job["pages"])] = job["pages"]
            for _ in range(self.chunks_per_tick):
                start, plen = job["start"], job["plen"]
                valid = min(C, plen - start)
                toks = np.zeros((1, C), np.int32)
                toks[0, :valid] = req.prompt[start:start + valid]
                with self.obs.span("prefill_chunk", slot=slot,
                                   rid=req.rid, start=start):
                    with self._mesh_ctx():
                        self._cache, self._last_logits = self._chunk_jit(
                            params, self._cache, self._last_logits,
                            jnp.asarray(toks),
                            jnp.asarray(start, jnp.int32),
                            jnp.asarray(valid, jnp.int32),
                            jnp.asarray(row),
                            jnp.asarray(slot, jnp.int32))
                req.prefill_chunks += 1
                job["start"] = start + valid
                if job["start"] >= plen:
                    self._page_map[slot] = row
                    self._live[slot] = True
                    self._pos = self._pos.at[slot].set(plen)
                    if self._shareable:
                        self.trie.register(
                            np.asarray(req.prompt),
                            job["pages"][: plen // self.page_size])
                    self.stats.prefills += 1
                    del self._jobs[slot]
                    break

    # -- decode ---------------------------------------------------------
    def _decode(self, params, tok, cache, pos):
        return self._decode_jit(params, tok, cache, pos,
                                jnp.asarray(self._page_map),
                                jnp.asarray(self._live))

    def _tick(self) -> None:
        super()._tick()
        if self.obs.enabled:
            self.obs.counter(
                "pages", free=self.table.num_free,
                occupancy=self.table.occupancy,
                prefix_hit_rate=self.prefix_hit_rate,
                deferrals=self.page_deferrals)

    def step(self, params) -> int:
        """Admit + advance chunked prefills, then one decode step for
        the live lanes; returns #tokens emitted."""
        self._tick()
        with self.obs.span("admission", step=self.clock):
            self._admit()
        self._advance_prefills(params)
        if not self._live.any():
            return 0
        return self._decode_tick(params)


SCHEDULERS = {"wave": BatchScheduler, "continuous": ContinuousScheduler,
              "paged": PagedContinuousScheduler}


def make_scheduler(kind: str, model: ModelApi, **kw):
    try:
        cls = SCHEDULERS[kind]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {kind!r}; choose from {sorted(SCHEDULERS)}")
    return cls(model, **kw)


def run_trace(sched, params, arrivals, max_steps: int = 10_000):
    """Drive a scheduler through an arrival trace.

    arrivals: iterable of ``(arrive_step, Request)`` — each request is
    submitted once the driver's step counter reaches ``arrive_step``
    (steps advance even while the scheduler idles waiting for work, so
    a bursty Poisson trace exercises admission under load). Returns the
    scheduler's stats.
    """
    pending = sorted(arrivals, key=lambda a: a[0])
    i = 0
    steps = 0
    with sched.obs.span("run", scheduler=type(sched).__name__,
                        driver="trace", requests=len(pending)):
        while (i < len(pending) or sched.outstanding) and \
                steps < max_steps:
            while i < len(pending) and pending[i][0] <= steps:
                sched.submit(pending[i][1])
                i += 1
            sched.step(params)
            steps += 1
    if i < len(pending) or sched.outstanding:
        import warnings
        warnings.warn(
            f"run_trace hit max_steps={max_steps} with requests still "
            "outstanding — results are truncated; raise max_steps",
            RuntimeWarning, stacklevel=2)
    return sched.stats
