"""Batched request scheduler: wave-based (static) batching over the
model zoo's prefill/decode steps.

Requests arrive with different prompt lengths and generation budgets;
the scheduler packs up to `slots` of them into one fixed-shape batch
(left-padded prompts), prefills once, and decodes the wave together,
retiring slots as they hit their budgets; the next wave is admitted
when the batch drains. Static shapes keep a single jit signature for
the whole lifetime. Per-slot incremental prefill into freed slots
(true continuous batching) is the documented upgrade path — it needs
slot-indexed cache writes, which the ring-buffer cache layout already
supports.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelApi


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (T,) int32
    max_new: int = 16
    out_tokens: list = field(default_factory=list)
    done: bool = False


@dataclass
class SchedulerStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_generated: int = 0
    requests_done: int = 0


class BatchScheduler:
    """Slot-based wave batching (static shapes, shared pos)."""

    def __init__(self, model: ModelApi, *, slots: int = 4,
                 max_prompt: int = 64, max_total: int = 128,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.slots = slots
        self.max_prompt = max_prompt
        self.max_total = max_total
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.active: list[Optional[Request]] = [None] * slots
        self.stats = SchedulerStats()
        self._prefill = jax.jit(lambda p, b: model.prefill(
            p, b, dtype=jnp.float32, cache_dtype=jnp.float32,
            cache_len=max_total))
        self._decode = jax.jit(lambda p, t, c, s: model.decode_step(
            p, t, c, s, dtype=jnp.float32))
        self._cache = None
        self._pos = None            # (slots,) per-slot absolute position
        self._last_logits = None

    def submit(self, req: Request) -> None:
        assert len(req.prompt) <= self.max_prompt
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _admit(self, params) -> bool:
        """Fill free slots from the queue and (re)prefill the batch.

        Simplification: a joint prefill re-encodes all active prompts
        (cheap at these sizes; per-slot incremental prefill is the
        production upgrade path)."""
        free = [i for i, r in enumerate(self.active) if r is None]
        if not free or not self.queue:
            return False
        for i in free:
            if not self.queue:
                break
            self.active[i] = self.queue.pop(0)
        live = [r for r in self.active if r is not None]
        if not live:
            return False
        # right-align prompts into a common length (left-pad with 0)
        L = max(len(r.prompt) for r in live)
        toks = np.zeros((self.slots, L), np.int32)
        for i, r in enumerate(self.active):
            if r is not None:
                toks[i, L - len(r.prompt):] = r.prompt
        logits, cache, pos = self._prefill(params,
                                           {"tokens": jnp.asarray(toks)})
        self._cache = cache
        self._pos = jnp.full((), int(pos), jnp.int32)
        self._last_logits = logits
        self.stats.prefills += 1
        return True

    def _sample(self, logits) -> jnp.ndarray:
        if self.temperature > 0:
            self.key, k = jax.random.split(self.key)
            return jax.random.categorical(
                k, logits[:, -1] / self.temperature)[:, None]
        return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)

    def step(self, params) -> int:
        """One decode step for all live slots; returns #tokens emitted."""
        if self._cache is None and not self._admit(params):
            return 0
        tok = self._sample(self._last_logits)
        self._last_logits, self._cache = self._decode(
            params, tok, self._cache, self._pos)
        self._pos = self._pos + 1
        self.stats.decode_steps += 1
        emitted = 0
        tok_np = np.asarray(tok)[:, 0]
        for i, r in enumerate(self.active):
            if r is None or r.done:
                continue
            r.out_tokens.append(int(tok_np[i]))
            emitted += 1
            if len(r.out_tokens) >= r.max_new or \
                    int(self._pos) >= self.max_total:
                r.done = True
                self.stats.requests_done += 1
                self.active[i] = None
        self.stats.tokens_generated += emitted
        # batch drained -> allow the next admission wave
        if all(r is None for r in self.active):
            self._cache = None
        return emitted

    def run(self, params, max_steps: int = 1000) -> SchedulerStats:
        steps = 0
        while (self.queue or any(self.active)) and steps < max_steps:
            if self.step(params) == 0 and not self.queue:
                break
            steps += 1
        return self.stats
