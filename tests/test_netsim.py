"""repro.netsim: event streams, time-varying consensus, availability-
aware sampling, straggler pricing, and the masked-mixing contract
(DESIGN.md §8)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DynamicsConfig, TopologyConfig, TTHFConfig
from repro.core import mixing
from repro.core.energy import DELTA_GLOB_S, E_GLOB_J, CommLedger
from repro.core.sampling import sample_devices, sample_devices_multi, \
    sampled_global_model_multi
from repro.core.schedule import adaptive_gamma
from repro.core.topology import build_network, geometric_adjacency, \
    metropolis_weights
from repro.netsim import (
    EventStream, TimeVaryingNetwork, aggregation_weights,
    availability_sample, check_masked_assumption2, consensus_tail_mult,
    full_participation_weights, renormalized_varrho, scenarios,
    weighted_global_pytree,
)

PARITY_TOL = 1e-5


def small_net(seed=0, devices=20, clusters=4):
    return build_network(TopologyConfig(
        num_devices=devices, num_clusters=clusters, graph="geometric",
        seed=seed))


# ---------------------------------------------------------------------------
# event streams
# ---------------------------------------------------------------------------

def test_event_stream_deterministic_and_random_access():
    net = small_net()
    cfg = scenarios.get("device_churn", seed=7)
    a, b = EventStream(cfg, net.adj), EventStream(cfg, net.adj)
    # interleaved / out-of-order queries must agree with fresh streams
    for t in (5, 2, 17, 17, 9):
        ea, eb = a.at(t), b.at(t)
        np.testing.assert_array_equal(ea.device_up, eb.device_up)
        np.testing.assert_array_equal(ea.link_up, eb.link_up)
        np.testing.assert_array_equal(ea.delay_mult, eb.delay_mult)


def test_static_stream_is_all_up_forever():
    net = small_net()
    st = EventStream(scenarios.get("static"), net.adj)
    for t in (0, 1, 13, 50):
        ev = st.at(t)
        assert ev.all_up and (ev.delay_mult == 1.0).all()


def test_flash_crowd_window():
    net = small_net()
    cfg = scenarios.get("flash_crowd", seed=1)
    st = EventStream(cfg, net.adj)
    n = net.num_devices
    assert st.at(cfg.flash_at - 1).device_up.sum() == n
    dark = n - st.at(cfg.flash_at).device_up.sum()
    assert dark == round(cfg.flash_drop_frac * n)
    assert st.at(cfg.flash_at + cfg.flash_duration).device_up.sum() == n


def test_scenario_registry():
    assert set(scenarios.names()) >= {
        "static", "markov_links", "device_churn", "stragglers",
        "flash_crowd"}
    assert scenarios.get("static").is_static
    assert not scenarios.get("device_churn").is_static
    assert scenarios.get("stragglers", seed=9).seed == 9
    with pytest.raises(KeyError):
        scenarios.get("nope")


# ---------------------------------------------------------------------------
# time-varying network: Assumption 2 per event
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["static", "markov_links", "device_churn",
                                  "stragglers", "flash_crowd"])
def test_every_event_satisfies_masked_assumption2(name):
    net = small_net(seed=2)
    tv = TimeVaryingNetwork(net, scenarios.get(name, seed=3))
    for t in range(1, 41):
        snap = tv.snapshot(t)
        for c in range(net.num_clusters):
            check_masked_assumption2(snap.V[c], snap.adj[c],
                                     snap.device_up[c])
        # component-wise contraction is always < 1 (graceful degradation
        # even when the active subgraph disconnects)
        assert (snap.lambdas < 1.0).all()
        assert abs(snap.varrho.sum() - 1.0) < 1e-6


def test_static_snapshot_matches_base_network():
    net = small_net(seed=4)
    tv = TimeVaryingNetwork(net, scenarios.get("static"))
    snap = tv.snapshot(10)
    np.testing.assert_allclose(snap.V, net.V, atol=1e-6)
    np.testing.assert_array_equal(snap.adj, net.adj)
    np.testing.assert_allclose(snap.varrho, net.varrho, atol=1e-7)


# ---------------------------------------------------------------------------
# masked mixing: cross-backend parity + hold-your-parameters contract
# ---------------------------------------------------------------------------

def test_masked_mixing_backend_parity_and_dropped_device_invariance():
    rng = np.random.default_rng(0)
    N, s, M = 4, 5, 33
    V = jnp.asarray(np.stack(
        [metropolis_weights(geometric_adjacency(s, 0.8, rng))
         for _ in range(N)]), jnp.float32)
    z = jnp.asarray(rng.normal(size=(N, s, M)), jnp.float32)
    mask_np = rng.random((N, s)) > 0.35
    mask_np[0] = True                      # one fully-active cluster
    mask_np[1] = [True, False, False, False, False]   # near-dark cluster
    mask = jnp.asarray(mask_np)
    gamma = jnp.asarray([3, 2, 1, 4], jnp.int32)

    outs = {b: np.asarray(mixing.mix(z, V, gamma, backend=b,
                                     device_mask=mask))
            for b in ("reference", "masked_loop", "fused_power", "pallas")}
    for b in ("masked_loop", "fused_power", "pallas"):
        np.testing.assert_allclose(outs[b], outs["reference"],
                                   atol=PARITY_TOL, err_msg=b)

    ref, zn = outs["reference"], np.asarray(z)
    Vn = np.asarray(V)
    for c in range(N):
        dropped = np.flatnonzero(~mask_np[c])
        active = np.flatnonzero(mask_np[c])
        # dropped devices hold their parameters exactly
        np.testing.assert_allclose(ref[c, dropped], zn[c, dropped],
                                   atol=1e-7)
        # active devices mix ONLY among themselves: reproduce from the
        # masked matrix restricted to the active block
        vm = np.asarray(mixing.masked_consensus_matrix(V, mask))[c]
        sub = vm[np.ix_(active, active)]
        expect = np.linalg.matrix_power(sub, int(gamma[c])) @ zn[c, active]
        np.testing.assert_allclose(ref[c, active], expect, atol=PARITY_TOL)


def test_masked_matrix_rejects_precomputed_w():
    net = small_net()
    V = jnp.asarray(net.V)
    z = jnp.zeros((net.num_clusters, net.cluster_size, 3))
    mask = jnp.ones((net.num_clusters, net.cluster_size), bool)
    W = mixing.matrix_powers(V, jnp.full((net.num_clusters,), 2))
    with pytest.raises(ValueError):
        mixing.mix(z, V, 2, backend="fused_power", W=W, device_mask=mask)


# ---------------------------------------------------------------------------
# availability-aware sampling
# ---------------------------------------------------------------------------

def test_renormalized_varrho_darkens_clusters():
    base = np.array([0.25, 0.25, 0.25, 0.25])
    up = np.ones((4, 5), bool)
    np.testing.assert_allclose(renormalized_varrho(up, base), base)
    up[2] = False
    v = renormalized_varrho(up, base)
    assert v[2] == 0.0 and abs(v.sum() - 1.0) < 1e-12
    np.testing.assert_allclose(v[[0, 1, 3]], 1 / 3)


def test_availability_sample_respects_mask_and_count():
    rng = np.random.default_rng(0)
    up = np.ones((3, 6), bool)
    up[0, :4] = False                      # 2 available
    up[1] = False                          # dark
    picks, counts = availability_sample(rng, up, k=3)
    assert counts.tolist() == [2, 0, 3]
    assert set(picks[0, :2]) <= {4, 5}
    assert (picks[1] == -1).all()
    assert len(set(picks[2, :3])) == 3     # without replacement


def test_availability_sampling_unbiased_over_seeds():
    """Mean over seeds of the sampled aggregate ~= varrho'-weighted mean
    of the AVAILABLE devices' values (the Theorem-1 unbiasedness
    property, availability-aware)."""
    rng = np.random.default_rng(1)
    N, s, M = 3, 5, 7
    z = rng.normal(size=(N, s, M))
    up = rng.random((N, s)) > 0.4
    up[:, 0] = True                        # no dark cluster
    base = np.full((N,), 1 / N)
    varrho = renormalized_varrho(up, base)
    zj = jnp.asarray(z)

    acc = np.zeros(M)
    trials = 600
    for t in range(trials):
        picks, counts = availability_sample(
            np.random.default_rng(t), up, k=1)
        w = aggregation_weights(picks, counts, varrho, s)
        acc += np.asarray(weighted_global_pytree(
            {"z": zj.reshape(N * s, M)}, jnp.asarray(w), N)["z"])
    mean = acc / trials
    expect = sum(varrho[c] * z[c][up[c]].mean(axis=0) for c in range(N))
    np.testing.assert_allclose(mean, expect, atol=0.05)


def test_full_participation_weights_cover_available_only():
    up = np.ones((2, 4), bool)
    up[0, 1:] = False
    w = full_participation_weights(up, np.array([0.5, 0.5]))
    assert abs(w.sum() - 1.0) < 1e-12
    assert w[0, 0] == 0.5 and (w[0, 1:] == 0).all()
    np.testing.assert_allclose(w[1], 0.125)


# ---------------------------------------------------------------------------
# multi-device sampling (satellite: the ledger must stop lying)
# ---------------------------------------------------------------------------

def test_multi_sampling_without_replacement_and_k1_compat():
    key = jax.random.PRNGKey(3)
    picks = sample_devices_multi(key, 6, 5, 3)
    assert picks.shape == (6, 3)
    for row in np.asarray(picks):
        assert len(set(row.tolist())) == 3
        assert all(0 <= i < 5 for i in row)
    # k=1 reproduces the historical single-device stream bit-for-bit
    np.testing.assert_array_equal(
        np.asarray(sample_devices_multi(key, 6, 5, 1))[:, 0],
        np.asarray(sample_devices(key, 6, 5)))
    with pytest.raises(ValueError):
        sample_devices_multi(key, 6, 5, 9)


def test_multi_sampling_k_equals_s_is_full_mean():
    rng = np.random.default_rng(5)
    N, s, M = 4, 5, 11
    z = jnp.asarray(rng.normal(size=(N, s, M)), jnp.float32)
    varrho = jnp.full((N,), 1 / N, jnp.float32)
    picks = sample_devices_multi(jax.random.PRNGKey(0), N, s, s)
    out = np.asarray(sampled_global_model_multi(z, picks, varrho))
    expect = np.asarray(jnp.einsum("c,cm->m", varrho, z.mean(axis=1)))
    np.testing.assert_allclose(out, expect, atol=1e-5)


# ---------------------------------------------------------------------------
# CommLedger pricing (incl. straggler tails)
# ---------------------------------------------------------------------------

def test_ledger_energy_delay_pricing_exact():
    led = CommLedger()
    led.record_aggregation(4)                       # 4 uplinks
    led.record_consensus([2, 3], [5, 1])            # 5 rounds, 26 msgs
    assert led.uplinks == 4 and led.d2d_rounds == 5
    assert led.d2d_msgs == 2 * 2 * 5 + 3 * 2 * 1
    e_ratio, d_ratio = 0.1, 0.25
    assert led.energy(e_ratio) == pytest.approx(
        4 * E_GLOB_J + led.d2d_msgs * e_ratio * E_GLOB_J)
    assert led.delay(d_ratio) == pytest.approx(
        4 * DELTA_GLOB_S + 5 * d_ratio * DELTA_GLOB_S)


def test_ledger_straggler_tails_stretch_delay_not_energy():
    base, slow = CommLedger(), CommLedger()
    for led, mults in ((base, None), (slow, [3.0, 1.0])):
        led.record_aggregation(2, uplink_delay_mults=mults)
        led.record_consensus([4], [6],
                             tail_mult_per_cluster=None if mults is None
                             else [2.5])
    assert base.energy(0.1) == pytest.approx(slow.energy(0.1))
    # uplinks: one device 3x slower -> +2 uplink-equivalents;
    # rounds: 4 rounds at 2.5x -> +6 round-equivalents
    assert slow.straggler_uplink_extra == pytest.approx(2.0)
    assert slow.straggler_round_extra == pytest.approx(6.0)
    d_ratio = 0.5
    assert slow.delay(d_ratio) - base.delay(d_ratio) == pytest.approx(
        2.0 * DELTA_GLOB_S + 6.0 * d_ratio * DELTA_GLOB_S)


def test_consensus_tail_is_slowest_exchanging_device():
    up = np.array([[True, True, False], [True, False, False]])
    adj = np.zeros((2, 3, 3), bool)
    adj[0, 0, 1] = adj[0, 1, 0] = True
    mult = np.array([[2.0, 5.0, 99.0], [7.0, 1.0, 1.0]])
    tails = consensus_tail_mult(mult, up, adj)
    # cluster 0: devices 0,1 exchange -> tail 5; dropped 99x ignored
    # cluster 1: nobody has an active edge -> baseline 1
    np.testing.assert_allclose(tails, [5.0, 1.0])


# ---------------------------------------------------------------------------
# adaptive gamma under churn
# ---------------------------------------------------------------------------

def test_adaptive_gamma_zero_for_isolated_clusters():
    ups = jnp.asarray([1.0, 1.0, 1.0])
    lam = jnp.asarray([0.7, 0.7, 0.7])
    active = jnp.asarray([5, 1, 0])
    g = adaptive_gamma(jnp.float32(0.01), 1.0, ups, lam, active, 100)
    g = np.asarray(g)
    assert g[0] > 0 and g[1] == 0 and g[2] == 0


# ---------------------------------------------------------------------------
# geometric fallback surfacing (satellite)
# ---------------------------------------------------------------------------

def test_geometric_fallback_warns_and_counts():
    counter = []
    with pytest.warns(RuntimeWarning, match="falling back to a ring"):
        adj = geometric_adjacency(12, 0.01, np.random.default_rng(0),
                                  fallback_counter=counter)
    assert len(counter) == 1
    assert adj.sum() == 2 * 12              # it IS the ring
    net = small_net()
    assert net.geometric_fallbacks == 0     # healthy tuning reports none
