"""Deterministic stand-in for ``hypothesis`` when it is not installed.

This container has no network access, so ``hypothesis`` may be absent;
property tests should still *run* (not skip) with reduced example
budgets.  The shim covers exactly the API surface the test suite uses:

  * ``given(**kwargs)`` with keyword strategies
  * ``settings(max_examples=..., deadline=...)``
  * ``strategies.integers / floats / sampled_from``

Example generation is seeded and boundary-biased (endpoints first, then
uniform draws), so failures reproduce exactly.  When the real
``hypothesis`` is importable, ``install()`` is a no-op and the genuine
library is used — see ``conftest.py``.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

# cap shim runs so the fallback suite stays fast on the 1-core CI box;
# real hypothesis (when installed) honors the tests' own max_examples
MAX_EXAMPLES_CAP = 12


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random, index: int):
        return self._draw(rng, index)


def integers(min_value: int, max_value: int) -> _Strategy:
    def draw(rng, i):
        if i == 0:
            return min_value
        if i == 1:
            return max_value
        return rng.randint(min_value, max_value)
    return _Strategy(draw)


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    def draw(rng, i):
        if i == 0:
            return min_value
        if i == 1:
            return max_value
        return rng.uniform(min_value, max_value)
    return _Strategy(draw)


def sampled_from(elements) -> _Strategy:
    elements = list(elements)

    def draw(rng, i):
        if i < len(elements):
            return elements[i]
        return rng.choice(elements)
    return _Strategy(draw)


def settings(max_examples: int = 10, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        n = min(getattr(fn, "_fallback_max_examples", 10), MAX_EXAMPLES_CAP)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(f"repro:{fn.__name__}")
            for i in range(max(n, 1)):
                drawn = {k: s.draw(rng, i) for k, s in strategies.items()}
                fn(*args, **kwargs, **drawn)

        # hide the strategy-filled parameters from pytest's fixture
        # resolution (it inspects the signature; remaining params — e.g.
        # pytest.mark.parametrize args — still pass through)
        sig = inspect.signature(fn)
        remaining = [p for name, p in sig.parameters.items()
                     if name not in strategies]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        return wrapper
    return deco


def install() -> bool:
    """Register the shim as ``hypothesis`` if the real one is missing.

    Returns True when the shim was installed."""
    try:
        import hypothesis  # noqa: F401
        return False
    except ModuleNotFoundError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.__is_repro_fallback__ = True
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.sampled_from = sampled_from
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
    return True
