"""Shared fixtures. NOTE: tests run on the single real CPU device —
the 512-device production mesh lives ONLY in launch/dryrun.py."""
import os

# determinism + keep hypothesis/jax quiet on this 1-core box
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
