"""Shared fixtures. NOTE: tests run on the single real CPU device —
the 512-device production mesh lives ONLY in launch/dryrun.py."""
import os
import sys

# determinism + keep hypothesis/jax quiet on this 1-core box
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# `hypothesis` is optional (requirements-dev.txt): when absent, install
# a deterministic shim so property tests still run (reduced budgets)
sys.path.insert(0, os.path.dirname(__file__))
from _hypothesis_fallback import install as _install_hypothesis_fallback

HYPOTHESIS_IS_FALLBACK = _install_hypothesis_fallback()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
