"""repro.dist sharding layer: rule resolution, mesh-aware dropping,
duplicate-mesh-axis conflicts, overrides, and hint/drop_hint_axes
semantics (on small host-device meshes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    ShardingRules, drop_hint_axes, hint, resolve_hint_spec,
)

RULES = ShardingRules((
    ("batch", ("pod", "data")),
    ("replica", ("pod", "data")),
    ("embed", ("pod", "data")),
    ("vocab", "model"),
    ("ffn", "model"),
    ("layers", None),
))


@pytest.fixture(scope="module")
def mesh3():
    """(pod=1, data=1, model=1) — axis names matter, sizes don't."""
    return jax.make_mesh((1, 1, 1), ("pod", "data", "model"))


@pytest.fixture(scope="module")
def mesh_dm():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_basic(mesh3):
    assert RULES.spec(("batch", None, None), mesh3) == \
        P(("pod", "data"), None, None)
    assert RULES.spec(("layers", "embed", "vocab"), mesh3) == \
        P(None, ("pod", "data"), "model")


def test_spec_drops_axes_missing_from_mesh(mesh_dm):
    # same table serves the single-pod mesh: "pod" silently dropped
    assert RULES.spec(("batch", "vocab"), mesh_dm) == P("data", "model")


def test_spec_duplicate_mesh_axis_leftmost_wins(mesh3):
    # replica claims (pod, data); embed's (pod, data) and a second
    # "model" dim must not re-claim — a mesh axis shards ONE dim only
    spec = RULES.spec(("replica", "embed", "vocab", "ffn"), mesh3)
    assert spec == P(("pod", "data"), None, "model", None)


def test_spec_unknown_logical_axis_raises(mesh3):
    with pytest.raises(KeyError):
        RULES.spec(("no_such_axis",), mesh3)


def test_duplicate_rule_rejected():
    with pytest.raises(ValueError):
        ShardingRules((("a", None), ("a", "model")))


def test_with_overrides_preserves_order_and_appends(mesh3):
    over = RULES.with_overrides(embed=None, cache_seq="model")
    assert over.logical_axes()[:6] == RULES.logical_axes()
    assert over.logical_axes()[-1] == "cache_seq"
    assert over.mesh_axes("embed") == ()
    assert over.mesh_axes("cache_seq") == ("model",)
    # original untouched (immutability)
    assert RULES.mesh_axes("embed") == ("pod", "data")
    assert over.spec(("batch", "cache_seq"), mesh3) == \
        P(("pod", "data"), "model")


def test_hint_noop_off_mesh():
    x = jnp.ones((4, 8))
    assert hint(x, ("pod", "data"), "model") is x


def test_hint_arity_check():
    with pytest.raises(ValueError):
        hint(jnp.ones((4, 8)), ("pod", "data"))


def test_hint_spec_under_mesh(mesh3):
    assert resolve_hint_spec((("pod", "data"), "model"), mesh3) == \
        P(("pod", "data"), "model")
    # duplicate-claim: later dim must not re-claim "model"
    assert resolve_hint_spec(("model", "model"), mesh3) == P("model", None)


def test_hint_spec_filters_missing_axes(mesh_dm):
    assert resolve_hint_spec((("pod", "data"), "model"), mesh_dm) == \
        P("data", "model")


def test_drop_hint_axes_masks_and_nests(mesh3):
    x = jnp.ones((4, 8))
    spec = (("pod", "data"), "model")
    with drop_hint_axes(("pod",)):
        assert resolve_hint_spec(spec, mesh3) == P("data", "model")
        with drop_hint_axes(("data",)):   # inner ADDS to outer
            assert resolve_hint_spec(spec, mesh3) == P(None, "model")
        # outer drop set restored
        assert resolve_hint_spec(spec, mesh3) == P("data", "model")
    assert resolve_hint_spec(spec, mesh3) == P(("pod", "data"), "model")
    # all-dropped hint is a no-op even under an active mesh
    with mesh3:
        with drop_hint_axes(("pod", "data", "model")):
            assert hint(x, ("pod", "data"), "model") is x


def test_hint_inside_jit(mesh3):
    x = jnp.ones((4, 8))
    with mesh3:
        y = jax.jit(lambda a: hint(a, ("pod", "data"), "model") * 2)(x)
    np.testing.assert_allclose(np.asarray(y), 2 * np.ones((4, 8)))


def test_tthf_scale_rule_table_resolves(mesh3):
    """The scale-mode table from core/distributed.py resolves for every
    declared logical axis on the multi-pod mesh."""
    from repro.core.distributed import TTHF_PARAM_RULES
    rules = ShardingRules(TTHF_PARAM_RULES)
    for name in rules.logical_axes():
        spec = rules.spec(("replica", name), mesh3)
        assert spec[0] == ("pod", "data")
        # replica already claimed (pod, data): no other axis may re-use
        assert spec[1] in (None, "model")
