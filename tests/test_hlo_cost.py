"""Trip-count-aware HLO cost analysis — correctness against known
workloads (this underpins every §Roofline number)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_hlo


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    x = jnp.ones((64, 128))
    w = jnp.ones((128, 32))
    txt = _compile_text(lambda a, b: a @ b, x, w)
    c = analyze_hlo(txt)
    assert abs(c.flops - 2 * 64 * 128 * 32) / (2 * 64 * 128 * 32) < 0.01


def test_scan_multiplies_by_trip_count():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, ws)[0]
    x = jnp.ones((128, 128))
    ws = jnp.ones((12, 128, 128))
    c = analyze_hlo(_compile_text(f, x, ws))
    expect = 12 * 2 * 128 ** 3
    assert abs(c.flops - expect) / expect < 0.01


def test_nested_scan():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            return jax.lax.scan(inner, c, None, length=5)[0], None
        return jax.lax.scan(outer, x, ws)[0]
    x = jnp.ones((64, 64))
    ws = jnp.ones((4, 64, 64))
    c = analyze_hlo(_compile_text(f, x, ws))
    expect = 20 * 2 * 64 ** 3
    assert abs(c.flops - expect) / expect < 0.01


def test_grad_of_scan_counts_backward():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, ws)[0].sum()
    x = jnp.ones((64, 64))
    ws = jnp.ones((6, 64, 64))
    c = analyze_hlo(_compile_text(jax.grad(f, argnums=1), x, ws))
    # fwd 6 + bwd (dx, dw) 12 = 18 matmuls
    expect = 18 * 2 * 64 ** 3
    assert abs(c.flops - expect) / expect < 0.05


def test_bytes_nonzero_and_bounded():
    def f(x, w):
        return jnp.tanh(x @ w)
    x = jnp.ones((256, 256))
    w = jnp.ones((256, 256))
    c = analyze_hlo(_compile_text(f, x, w))
    lo = 3 * 256 * 256 * 4            # read x, w; write out
    assert lo <= c.bytes <= 12 * lo
