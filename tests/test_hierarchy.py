"""Multi-stage fog hierarchy (repro.hierarchy, DESIGN.md §9): tree
construction, per-level weight-matrix invariants under churn, L=2
degeneracy (bit-for-bit flat TT-HF in both trainers), and multi-level
runs in sim and scale mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (DynamicsConfig, HierarchyConfig,
                           TopologyConfig, TTHFConfig)
from repro.core import TTHFTrainer
from repro.core import sampling as smp
from repro.data import fashion_synth, partition_noniid_labels
from repro.hierarchy import (build_event, build_tree, interval_depth,
                             presets)
from repro.models import make_sim_model


@pytest.fixture(scope="module")
def fleet():
    x, y = fashion_synth(num_points=800, seed=0)
    data = partition_noniid_labels(x, y, num_devices=24)
    topo = TopologyConfig(num_devices=24, num_clusters=8,
                          graph="geometric", seed=0)
    model = make_sim_model("svm", 784, 10)
    return data, topo, model


ALGO = TTHFConfig(tau=5, consensus_every=5, gamma_d2d=2,
                  constant_lr=0.002)


def _run(fleet, algo, hier=None, dyn=None, steps=20):
    data, topo, model = fleet
    tr = TTHFTrainer(model, data, topo, algo, batch_size=8,
                     dynamics=dyn, hierarchy=hier)
    st, h = tr.run(steps=steps, eval_every=5, seed=0)
    return tr, st, h


# ---------------------------------------------------------------------------
# tree + calendar
# ---------------------------------------------------------------------------

def test_tree_shapes_and_mass():
    tree = build_tree(presets.get("fog4", tau=5), num_clusters=8,
                      cluster_size=3)
    assert tree.node_counts == (8, 4, 2, 1)
    for level, m in enumerate(tree.mass):
        assert m.shape == (tree.node_counts[level],)
        np.testing.assert_allclose(m.sum(), 1.0)
    np.testing.assert_allclose(tree.mass[0], np.full(8, 1 / 8))
    # contiguous grouping and full ancestor chains
    assert tree.ancestors(2).tolist() == [0, 0, 0, 0, 1, 1, 1, 1]
    assert tree.device_ancestors(3).tolist() == [0] * 24


def test_auto_branching_needs_divisors():
    with pytest.raises(ValueError, match="divisor"):
        build_tree(HierarchyConfig(levels=4, taus=(5, 10, 20),
                                   sample=(1, 0, 0)),
                   num_clusters=5, cluster_size=2)


def test_interval_depth_nesting():
    taus = (5, 10, 20)
    depths = {t: interval_depth(t, taus) for t in range(0, 41, 5)}
    assert depths == {0: 0, 5: 1, 10: 2, 15: 1, 20: 3, 25: 1, 30: 2,
                      35: 1, 40: 3}


# ---------------------------------------------------------------------------
# per-level weight-matrix invariants
# ---------------------------------------------------------------------------

def test_level_matrices_sum_to_one_under_churn():
    """Every tier's matrix: live parents' weight vectors over their
    children sum to exactly 1 (dark/unsampled mass renormalized away,
    like netsim's dark clusters); dark parents' rows are all zero."""
    cfg = presets.get("fog4", tau=5)
    tree = build_tree(cfg, num_clusters=8, cluster_size=3)
    rng = np.random.default_rng(7)
    for trial in range(20):
        up = rng.random((8, 3)) > 0.4        # heavy churn, dark clusters
        ev = build_event(np.random.default_rng(trial), tree, cfg,
                         t=20, device_up=up)
        A, *Gs = ev.level_weights
        live = up.any(axis=1)
        np.testing.assert_allclose(A.sum(1), np.where(live, 1.0, 0.0),
                                   atol=1e-12)
        for G in Gs:
            sums = G.sum(1)
            assert np.all((np.abs(sums - 1.0) < 1e-12) | (sums == 0.0))
        # composed device matrix: receiving rows sum to 1, every other
        # row is exactly the identity row (hold-your-parameters)
        M = ev.device_matrix
        rows = M.sum(1)
        eye = np.eye(24, dtype=np.float32)
        for i in range(24):
            if not np.array_equal(M[i], eye[i]):
                assert abs(rows[i] - 1.0) < 1e-6


def test_all_dark_event_is_identity():
    cfg = presets.get("fog3", tau=5)
    tree = build_tree(cfg, num_clusters=4, cluster_size=2)
    ev = build_event(np.random.default_rng(0), tree, cfg, t=10,
                     device_up=np.zeros((4, 2), bool))
    assert ev.total_uplinks == 0
    np.testing.assert_array_equal(ev.device_matrix, np.eye(8))


def test_flat_event_matches_flat_aggregation():
    """An all-up L=2 event with k=1 composes to exactly the paper's
    eq. (7): every device receives the varrho-weighted sampled model."""
    cfg = presets.get("flat", tau=5)
    tree = build_tree(cfg, num_clusters=4, cluster_size=3)
    ev = build_event(np.random.default_rng(3), tree, cfg, t=5,
                     device_up=np.ones((4, 3), bool))
    assert ev.depth == 1 and ev.uplinks_by_level == {1: 4}
    picks = jnp.asarray(ev.picks[:, 0])
    varrho = jnp.full((4,), 0.25, jnp.float32)
    params = {"w": jnp.asarray(
        np.random.default_rng(0).normal(size=(12, 6)), jnp.float32)}
    g = smp.sampled_global_pytree(params, picks, varrho, 4)
    from repro.hierarchy import apply_device_matrix_pytree
    out = apply_device_matrix_pytree(params,
                                     jnp.asarray(ev.device_matrix))
    for r in range(12):
        np.testing.assert_allclose(np.asarray(out["w"][r]),
                                   np.asarray(g["w"]), atol=1e-6)


def test_offline_devices_hold_params_through_broadcast():
    cfg = presets.get("fog3", tau=5)
    tree = build_tree(cfg, num_clusters=4, cluster_size=2)
    up = np.ones((4, 2), bool)
    up[1, 0] = False
    ev = build_event(np.random.default_rng(0), tree, cfg, t=10,
                     device_up=up, receive_offline=False)
    params = {"w": jnp.asarray(
        np.random.default_rng(1).normal(size=(8, 3)), jnp.float32)}
    from repro.hierarchy import apply_device_matrix_pytree
    out = apply_device_matrix_pytree(params,
                                     jnp.asarray(ev.device_matrix))
    np.testing.assert_array_equal(np.asarray(out["w"][2]),
                                  np.asarray(params["w"][2]))


# ---------------------------------------------------------------------------
# simulation mode
# ---------------------------------------------------------------------------

def test_flat_hierarchy_is_bit_for_bit_sim(fleet):
    _, _, h0 = _run(fleet, ALGO, hier=None)
    _, _, h1 = _run(fleet, ALGO, hier=presets.get("flat", tau=5))
    assert h0.global_loss == h1.global_loss      # exact float equality
    assert h0.global_acc == h1.global_acc
    assert h0.dispersion == h1.dispersion


def test_fog3_sim_levels_and_ledger(fleet):
    tr, st, h = _run(fleet, ALGO, hier=presets.get("fog3", tau=5))
    assert all(np.isfinite(h.global_loss))
    # 4 tier-1 events x 8 clusters; 2 root events x 4 edge nodes
    assert tr.ledger.uplinks_by_level == {1: 32, 2: 8}
    assert tr.ledger.uplinks == 40


def test_fog4_root_event_syncs_all_devices(fleet):
    tr, st, _ = _run(fleet, ALGO, hier=presets.get("fog4", tau=5))
    # steps=20 == the fog4 root period: everyone holds the root model
    for leaf in jax.tree.leaves(st.params):
        arr = np.asarray(leaf)
        np.testing.assert_array_equal(arr, np.broadcast_to(arr[0:1],
                                                           arr.shape))
    assert tr.ledger.uplinks_by_level == {1: 32, 2: 8, 3: 2}


def test_fog3_sim_under_churn_stays_finite(fleet):
    dyn = DynamicsConfig(name="churny", p_device_drop=0.2,
                         p_device_return=0.3, seed=1)
    tr, _, h = _run(fleet, ALGO, hier=presets.get("fog3", tau=5),
                    dyn=dyn)
    assert all(np.isfinite(h.global_loss))
    # churn can only remove uplinks relative to the all-up calendar
    assert tr.ledger.uplinks_by_level.get(1, 0) <= 32
    assert tr.ledger.uplinks_by_level.get(2, 0) <= 8


def test_hierarchy_rejects_mismatched_tau(fleet):
    data, topo, model = fleet
    with pytest.raises(AssertionError, match="tier-1 period"):
        TTHFTrainer(model, data, topo, ALGO, batch_size=8,
                    hierarchy=presets.get("fog3", tau=10))


def test_flat_hierarchy_is_identity_for_baselines(fleet):
    """'flat' is the identity preset: combined with a baseline (or any
    knob mismatch) it is simply ignored — plain TT-HF semantics."""
    from repro.core import make_baseline_config
    data, topo, model = fleet
    algo = make_baseline_config("fedavg", tau=10)
    tr = TTHFTrainer(model, data, topo, algo, batch_size=8,
                     hierarchy=presets.get("flat", tau=5))
    assert tr.tree is None
    _, h = tr.run(steps=10, eval_every=10, seed=0)
    assert all(np.isfinite(h.global_loss))


# ---------------------------------------------------------------------------
# scale mode
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def scale_world():
    from repro.configs import get_arch
    from repro.core.distributed import TTHFScaleConfig
    from repro.train import TrainerConfig
    cfg = get_arch("qwen1.5-0.5b").reduced(num_layers=2, d_model=64,
                                           d_ff=128, vocab_size=128)
    scale = TTHFScaleConfig(replicas=8, cluster_size=2, tau=2,
                            consensus_every=2, gamma_d2d=2, lr=0.05)
    tcfg = TrainerConfig(batch_per_replica=2, seq_len=16, intervals=4,
                         eval_every=0, eval_batches=1)
    return cfg, scale, tcfg


def _leaves_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_flat_hierarchy_is_bit_for_bit_scale(scale_world):
    from repro.train import ScaleTrainer
    cfg, scale, tcfg = scale_world
    tr0 = ScaleTrainer(cfg, scale, tcfg).init()
    tr0.run()
    tr1 = ScaleTrainer(cfg, scale, tcfg,
                       hierarchy=presets.get("flat", tau=2)).init()
    tr1.run()
    assert _leaves_equal(tr0.params, tr1.params)
    assert tr0.ledger == tr1.ledger


def test_fog3_scale_levels_and_root_sync(scale_world):
    from repro.train import ScaleTrainer
    cfg, scale, tcfg = scale_world
    tr = ScaleTrainer(cfg, scale, tcfg,
                      hierarchy=presets.get("fog3", tau=2)).init()
    tr.run()
    # 4 intervals x 4 clusters; root fires every 2nd interval x 2 nodes
    assert tr.ledger.uplinks_by_level == {1: 16, 2: 4}
    for leaf in jax.tree.leaves(tr.params):
        arr = np.asarray(leaf)
        assert np.isfinite(arr).all()
        # interval 4 was a root event: all replicas agree
        np.testing.assert_allclose(arr, np.broadcast_to(arr[0:1],
                                                        arr.shape),
                                   atol=1e-6)


def test_scale_hierarchy_serves_root_model(scale_world):
    """Between root events the served (eval) model is the LAST root
    snapshot — not whatever subtree model replica 0 happens to hold."""
    from repro.train import ScaleTrainer
    cfg, scale, tcfg = scale_world
    tr = ScaleTrainer(cfg, scale, tcfg,
                      hierarchy=presets.get("fog3", tau=2)).init()
    init_global = jax.tree.map(np.asarray, tr._global_params())
    tr.run(1)                       # tier 1 only: root not fired yet
    assert _leaves_equal(tr._global_params(), init_global)
    tr.run(1)                       # interval 2 is a root event
    assert _leaves_equal(tr._global_params(),
                         jax.tree.map(lambda l: l[0], tr.params))
    assert not _leaves_equal(tr._global_params(), init_global)


def test_fog3_scale_under_churn(scale_world):
    from repro.netsim import scenarios
    from repro.train import ScaleTrainer
    cfg, scale, tcfg = scale_world
    tr = ScaleTrainer(cfg, scale, tcfg,
                      hierarchy=presets.get("fog3", tau=2),
                      dynamics=scenarios.get("device_churn", seed=3)
                      ).init()
    tr.run()
    assert tr.interval == 4
    for leaf in jax.tree.leaves(tr.params):
        assert np.isfinite(np.asarray(leaf)).all()
    assert tr.ledger.uplinks_by_level[1] <= 16


def test_scale_rejects_mismatched_fan_in(scale_world):
    """scale.sample_per_cluster and the tier-1 fan-in must agree —
    a silent mismatch would sample with the wrong k."""
    import dataclasses
    from repro.train import ScaleTrainer
    cfg, scale, tcfg = scale_world
    bad = dataclasses.replace(scale, sample_per_cluster=2)
    with pytest.raises(AssertionError, match="fan-in"):
        ScaleTrainer(cfg, bad, tcfg, hierarchy=presets.get("fog3", tau=2))


def test_presets_registry():
    assert set(presets.names()) >= {"flat", "fog3", "fog4",
                                    "fog3_sampled"}
    h = presets.get("fog3_sampled", tau=10)
    assert h.levels == 3 and h.taus == (10, 20) and h.sample == (1, 2)
    with pytest.raises(KeyError):
        presets.get("nope")
    with pytest.raises(AssertionError):
        HierarchyConfig(levels=3, taus=(5, 12), sample=(1, 0))
    with pytest.raises(AssertionError, match="branching"):
        # partial branching: must be empty (auto) or cover every tier
        HierarchyConfig(levels=4, branching=(2,), taus=(5, 10, 20),
                        sample=(1, 0, 0))
