"""MoE routing invariants (Switch top-1 with capacity dispatch)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.common import split_tree
from repro.models.moe import apply_moe, init_moe


def _setup(E=4, d=64, f=128, cf=8.0, seed=0):
    cfg = dataclasses.replace(
        get_arch("llama4-scout-17b-a16e").reduced(),
        d_model=d, d_ff=f, moe_num_experts=E, moe_capacity_factor=cf)
    p_px = init_moe(jax.random.PRNGKey(seed), cfg)
    p, _ = split_tree(p_px)
    return cfg, p


def test_moe_output_shape_and_finite():
    cfg, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    y, aux = apply_moe(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux["load_balance"]) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz


def test_moe_no_drops_with_large_capacity():
    cfg, p = _setup(cf=16.0)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 64))
    _, aux = apply_moe(p, cfg, x)
    assert float(aux["drop_frac"]) == 0.0


def test_moe_matches_manual_top1():
    """Dispatch/gather must equal running each token through its argmax
    expert (no capacity overflow)."""
    cfg, p = _setup(cf=32.0)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 64))
    y, _ = apply_moe(p, cfg, x)

    xf = x.reshape(-1, 64)
    logits = xf @ p["router"]
    eid = jnp.argmax(logits, axis=-1)
    gate = jnp.max(jax.nn.softmax(logits, -1), axis=-1)
    outs = []
    for i in range(xf.shape[0]):
        e = int(eid[i])
        h = xf[i]
        g = jax.nn.silu(h @ p["w_gate"][e]) * (h @ p["w_up"][e])
        outs.append((g @ p["w_down"][e]) * gate[i])
    manual = jnp.stack(outs).reshape(y.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(manual),
                               rtol=2e-3, atol=2e-4)


def test_moe_capacity_drops_overflow():
    """With capacity 1 token per expert, most tokens pass through as 0."""
    cfg, p = _setup(cf=0.01)   # tiny capacity
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 64, 64))
    y, aux = apply_moe(p, cfg, x)
    assert float(aux["drop_frac"]) > 0.5
    assert bool(jnp.isfinite(y).all())


def test_interleaved_moe_structure():
    """maverick: MoE every other layer -> groups of (dense, moe)."""
    cfg = get_arch("llama4-maverick-400b-a17b").reduced()
    from repro.models import build_model
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    assert "groups" in params
    g = params["groups"]
    assert "dense_0" in g and "moe" in g
    assert "moe" in g["moe"] or "mlp" in g["dense_0"]
