"""Unified mixing engine: all four backends must be the SAME operator.

Property: ``reference == masked_loop == pallas(interpret) ==
fused_power`` on random (N, s, M) stacks with *vector* per-cluster
gamma (including gamma = 0 and heterogeneous Remark-1 round counts),
plus plan-level invariants (build-time W precompute, alias resolution,
pytree routing, traced-gamma support)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mixing
from repro.core.topology import (
    build_network, geometric_adjacency, metropolis_weights, ring_adjacency,
)
from repro.configs.base import TopologyConfig

PARITY_TOL = 1e-5


def _stack(N, s, M, seed):
    rng = np.random.default_rng(seed)
    V = jnp.asarray(
        np.stack([metropolis_weights(geometric_adjacency(s, 0.8, rng))
                  for _ in range(N)]), jnp.float32)
    z = jnp.asarray(rng.normal(size=(N, s, M)), jnp.float32)
    return z, V, rng


@given(seed=st.integers(0, 100), gmax=st.integers(1, 9),
       M=st.sampled_from([1, 17, 96, 513]))
@settings(max_examples=15, deadline=None)
def test_backend_parity_heterogeneous_gamma(seed, gmax, M):
    N, s = 4, 5
    z, V, rng = _stack(N, s, M, seed)
    # heterogeneous per-cluster rounds, always including a 0 (aperiodic
    # Remark-1 calendar: some clusters skip the event entirely)
    gamma = rng.integers(0, gmax + 1, size=(N,))
    gamma[rng.integers(0, N)] = 0
    gamma = jnp.asarray(gamma, jnp.int32)

    outs = {b: np.asarray(mixing.mix(z, V, gamma, backend=b))
            for b in mixing.BACKENDS}
    ref = outs["reference"]
    for b in ("masked_loop", "pallas", "fused_power"):
        np.testing.assert_allclose(
            outs[b], ref, atol=PARITY_TOL,
            err_msg=f"backend {b} diverged from reference")


@pytest.mark.parametrize("backend", mixing.BACKENDS)
def test_gamma_zero_is_identity(backend):
    z, V, _ = _stack(3, 4, 23, 7)
    out = mixing.mix(z, V, jnp.zeros((3,), jnp.int32), backend=backend)
    np.testing.assert_allclose(np.asarray(out), np.asarray(z), atol=1e-7)


@pytest.mark.parametrize("backend", mixing.BACKENDS)
def test_scalar_gamma_broadcasts(backend):
    z, V, _ = _stack(2, 5, 31, 3)
    a = mixing.mix(z, V, 3, backend=backend)
    b = mixing.mix(z, V, jnp.full((2,), 3, jnp.int32), backend=backend)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_traced_gamma_backends_under_jit():
    """masked_loop / pallas / fused_power accept TRACED gamma (the
    Remark-1 adaptive path); reference raises a clear error."""
    z, V, _ = _stack(2, 4, 16, 11)
    gamma = jnp.asarray([2, 5], jnp.int32)
    expect = np.asarray(mixing.mix(z, V, gamma, backend="reference"))
    for b in ("masked_loop", "pallas", "fused_power"):
        out = jax.jit(lambda g, b=b: mixing.mix(z, V, g, backend=b))(gamma)
        np.testing.assert_allclose(np.asarray(out), expect, atol=PARITY_TOL)
    with pytest.raises((ValueError, jax.errors.ConcretizationTypeError)):
        jax.jit(lambda g: mixing.mix(z, V, g, backend="reference"))(gamma)


def test_matrix_powers_matches_numpy():
    _, V, _ = _stack(3, 5, 1, 5)
    gamma = jnp.asarray([0, 2, 6], jnp.int32)
    W = np.asarray(mixing.matrix_powers(V, gamma))
    for c, g in enumerate(np.asarray(gamma)):
        np.testing.assert_allclose(
            W[c], np.linalg.matrix_power(np.asarray(V)[c], int(g)),
            atol=1e-6)


def test_plan_precomputes_w_and_matches_reference():
    net = build_network(TopologyConfig(num_devices=12, num_clusters=3,
                                       graph="ring"))
    gamma = np.asarray([1, 0, 4], np.int32)
    plan = mixing.build_mixing_plan(net, gamma, backend="fused_power")
    assert plan.W is not None and plan.W.shape == (3, 4, 4)
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=(3, 4, 29)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(plan.apply(z)),
        np.asarray(mixing.mix(z, jnp.asarray(net.V), gamma,
                              backend="reference")),
        atol=PARITY_TOL)


def test_plan_apply_pytree_and_noop():
    net = build_network(TopologyConfig(num_devices=8, num_clusters=2,
                                       graph="ring"))
    rng = np.random.default_rng(1)
    params = {"w": jnp.asarray(rng.normal(size=(8, 3, 2)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(8, 5)), jnp.float32)}
    noop = mixing.build_mixing_plan(net, 0, backend="fused_power")
    assert noop.is_noop
    assert noop.apply_pytree(params) is params
    plan = mixing.build_mixing_plan(net, [2, 3], backend="pallas")
    out = plan.apply_pytree(params)
    for k, leaf in params.items():
        flat = leaf.reshape(2, 4, -1)
        expect = mixing.mix(flat, jnp.asarray(net.V),
                            jnp.asarray([2, 3], jnp.int32),
                            backend="reference").reshape(leaf.shape)
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(expect),
                                   atol=PARITY_TOL)


def test_backend_aliases():
    assert mixing.canonical_backend("fused") == "fused_power"
    assert mixing.canonical_backend("rounds") == "reference"
    assert mixing.canonical_backend("kernel") == "pallas"
    assert mixing.canonical_backend("masked_loop") == "masked_loop"
    with pytest.raises(ValueError):
        mixing.canonical_backend("warp_drive")


def test_bf16_roundtrip_keeps_dtype():
    z, V, _ = _stack(2, 4, 64, 9)
    zb = z.astype(jnp.bfloat16)
    for b in mixing.BACKENDS:
        out = mixing.mix(zb, V, jnp.asarray([1, 3], jnp.int32), backend=b)
        assert out.dtype == jnp.bfloat16, b


def test_consensus_event_accepts_vector_gamma():
    """Scale mode now takes per-cluster aperiodic Gamma_c (Remark 1)."""
    from repro.core.distributed import consensus_event
    net = build_network(TopologyConfig(num_devices=8, num_clusters=2,
                                       graph="ring"))
    rng = np.random.default_rng(2)
    params = {"w": jnp.asarray(rng.normal(size=(8, 6)), jnp.float32)}
    gamma = np.asarray([0, 3], np.int32)
    fused = consensus_event(params, net, gamma, "fused")
    rounds = consensus_event(params, net, gamma, "rounds")
    np.testing.assert_allclose(np.asarray(fused["w"]),
                               np.asarray(rounds["w"]), atol=PARITY_TOL)
    # cluster 0 (gamma=0) untouched
    np.testing.assert_allclose(np.asarray(fused["w"][:4]),
                               np.asarray(params["w"][:4]), atol=1e-7)
