"""Sharded serving (DESIGN.md §14).

Fast: every registry config's cache/param logical-axes trees resolve
to VALID PartitionSpecs under the default serve rule tables on the
production and host mesh geometries (a mesh axis shards at most one
dimension, and only one it divides); the seq-fallback contract for
GQA configs whose head count does not divide ``model``; and the
mesh-threaded schedulers reproduce the single-device token streams on
a trivial (1, 1) mesh in-process.

Slow (subprocess, 8 forced host devices): data-parallel continuous
batching is BITWISE-identical to single-device (per-row computation is
unchanged — only placement differs), and tensor-parallel prefill +
decode logits match to numerical tolerance (reductions are split, so
only allclose is guaranteed).
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh

from repro.configs import ARCHS, get_arch
from repro.models import build_model
from repro.serving import SERVE_CACHE_RULES, SERVE_PARAM_RULES

ALL_ARCHS = sorted(ARCHS)

# production multi-pod geometry (sizes only — AbstractMesh never
# touches devices, so the 1-CPU test session can resolve 512-chip specs)
MULTIPOD = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
HOST8 = AbstractMesh((("data", 2), ("model", 4)))


def _entries(spec, ndim):
    """Per-dimension mesh-axis tuples of a PartitionSpec, padded."""
    dims = list(spec) + [None] * (ndim - len(spec))
    return [() if e is None else ((e,) if isinstance(e, str) else tuple(e))
            for e in dims]


def _assert_valid(spec, shape, mesh, where=""):
    sizes = dict(mesh.shape)
    used = []
    for dim, axes in zip(shape, _entries(spec, len(shape))):
        prod = 1
        for m in axes:
            assert m in sizes, f"{where}: unknown mesh axis {m!r}"
            assert m not in used, f"{where}: mesh axis {m!r} used twice"
            used.append(m)
            prod *= sizes[m]
        assert dim % prod == 0, \
            f"{where}: dim {dim} not divisible by {prod} ({spec}, {shape})"


def _flat_axes_and_shapes(axes_tree, abs_tree):
    is_ax = lambda x: isinstance(x, tuple)  # noqa: E731
    flat_ax = jax.tree_util.tree_flatten(axes_tree, is_leaf=is_ax)[0]
    flat_ab = jax.tree_util.tree_flatten(abs_tree)[0]
    assert len(flat_ax) == len(flat_ab)
    return list(zip(flat_ax, flat_ab))


@pytest.mark.parametrize("mesh", [MULTIPOD, HOST8],
                         ids=["multipod", "host8"])
@pytest.mark.parametrize("name", ALL_ARCHS)
def test_cache_axes_resolve_every_arch(name, mesh):
    """Satellite: every leaf of cache_logical_axes_tree resolves to a
    valid PartitionSpec under SERVE_CACHE_RULES for every registry
    config — full size, production slot geometry."""
    model = build_model(get_arch(name))
    slots, seq = 16, 2048
    axes = model.cache_axes()
    abs_c = model.abstract_cache(slots, seq, jnp.bfloat16)
    any_model = False
    for ax, ab in _flat_axes_and_shapes(axes, abs_c):
        spec = SERVE_CACHE_RULES.spec_for_shape(tuple(ax), tuple(ab.shape),
                                                mesh)
        _assert_valid(spec, ab.shape, mesh, where=f"{name} cache {ax}")
        any_model = any_model or any(
            "model" in e for e in _entries(spec, len(ab.shape)))
    # a full-size config must never serve with a fully model-replicated
    # cache: heads take the model axis, or the 2048 seq fallback does
    assert any_model, f"{name}: no cache leaf sharded over 'model'"


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_param_axes_resolve_every_arch(name):
    model = build_model(get_arch(name))
    abs_p, axes = model.abstract_params(dtype=jnp.bfloat16)
    for ax, ab in _flat_axes_and_shapes(axes, abs_p):
        spec = SERVE_PARAM_RULES.spec_for_shape(tuple(ax), tuple(ab.shape),
                                                MULTIPOD)
        _assert_valid(spec, ab.shape, MULTIPOD,
                      where=f"{name} param {ax}")


def test_gqa_seq_fallback_on_production_mesh():
    """maverick's kv_heads=8 does not divide model=16: the KV cache
    must fall back to sharding the sequence dim over 'model' (table
    order is the priority), never silently replicate."""
    cfg = get_arch("llama4-maverick-400b-a17b")
    assert cfg.num_kv_heads % 16 != 0     # the premise of the fallback
    spec = SERVE_CACHE_RULES.spec_for_shape(
        ("cache_batch", "cache_seq", "cache_kv_heads", "head_dim"),
        (16, 2048, cfg.num_kv_heads, cfg.head_dim), MULTIPOD)
    assert spec[1] == "model"             # seq picked up the model axis
    assert spec[2] is None                # heads replicated (8 % 16)
    # …and a config whose head count DOES divide keeps heads on model
    spec2 = SERVE_CACHE_RULES.spec_for_shape(
        ("cache_batch", "cache_seq", "cache_kv_heads", "head_dim"),
        (16, 2048, 16, 64), MULTIPOD)
    assert spec2[2] == "model"
    assert spec2[1] is None


def _reduced(name="qwen1.5-0.5b"):
    cfg = get_arch(name).reduced()
    if cfg.kind == "hybrid":
        cfg = dataclasses.replace(cfg, attention_window=16)
    if cfg.moe_num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    return cfg


def _poisson_trace(cfg, n_req, max_prompt, seed=0):
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    arrivals, step = [], 0
    for rid in range(n_req):
        plen = int(rng.integers(2, max_prompt + 1))
        prompt = rng.integers(1, cfg.vocab_size, size=plen).astype(np.int32)
        arrivals.append((step, Request(rid=rid, prompt=prompt, max_new=6)))
        step += int(rng.poisson(1.5))
    return arrivals


def _run_tokens(model, params, mesh, kind="continuous", slots=4,
                n_req=8, max_prompt=12, max_total=32):
    from repro.serving import make_scheduler, run_trace, shard_params
    p = params if mesh is None else shard_params(params, model, mesh)
    arrivals = _poisson_trace(model.cfg, n_req, max_prompt)
    sched = make_scheduler(kind, model, slots=slots, max_prompt=max_prompt,
                           max_total=max_total, temperature=0.0, seed=0,
                           mesh=mesh)
    stats = run_trace(sched, p, arrivals)
    assert stats.requests_done == n_req
    return {req.rid: list(req.out_tokens) for _, req in arrivals}


@pytest.mark.parametrize("kind", ["continuous", "wave"])
def test_scheduler_mesh_threading_parity_one_device(kind):
    """The mesh code path end-to-end in-process: a (1, 1) mesh over the
    single test device must reproduce the no-mesh token streams
    exactly (and exercises sharded init_cache/write_cache_slot/jit
    out_shardings without needing forced host devices)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    model = build_model(_reduced())
    params = model.init(jax.random.PRNGKey(0))
    base = _run_tokens(model, params, None, kind=kind)
    sharded = _run_tokens(model, params, mesh, kind=kind)
    assert base == sharded


@pytest.mark.slow
def test_sharded_smoke_8dev_subprocess():
    """8 simulated host devices (the CI serving-shard-smoke config):
    data-parallel continuous batching is bitwise-identical to
    single-device; tensor-parallel logits allclose."""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child"],
        capture_output=True, text=True, timeout=1200, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.splitlines()[-1])
    assert rec["devices"] == 8
    assert rec["done_single"] == rec["done_data"] == 16
    assert rec["bitwise_equal"], \
        "data-parallel token stream diverged from single-device"
    assert rec["tp_max_abs_diff"] < 1e-4, rec


# ---------------------------------------------------------------------------
# child entry for the slow smoke (runs under 8 forced host devices)
# ---------------------------------------------------------------------------

def _child_main():
    from repro.launch.mesh import make_serve_mesh
    from repro.serving import serve_shardings, shard_params

    assert len(jax.devices()) == 8, jax.devices()
    cfg = _reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # 1) scheduler trace: single-device vs data-parallel (slots=8 over
    #    data=8) — per-row computation unchanged, must be bitwise equal
    kw = dict(kind="continuous", slots=8, n_req=16, max_prompt=16,
              max_total=48)
    t_single = _run_tokens(model, params, None, **kw)
    t_data = _run_tokens(model, params, make_serve_mesh("data"), **kw)

    # 2) tensor-parallel logits vs single-device, teacher-forced with
    #    one fixed token sequence so a sampling flip cannot cascade
    B, T, G = 8, 16, 4
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    forced = jax.random.randint(jax.random.PRNGKey(2), (G, B, 1), 0,
                                cfg.vocab_size)

    def direct(mesh):
        from contextlib import nullcontext
        ctx, p, kw_pf, kw_dec = nullcontext(), params, {}, {}
        if mesh is not None:
            sh = serve_shardings(model, mesh, slots=B, max_total=T + G,
                                 dtype=jnp.float32)
            ctx = mesh
            p = shard_params(params, model, mesh)
            kw_pf = {"out_shardings": (sh.logits, sh.cache,
                                       sh.replicated)}
            kw_dec = {"out_shardings": (sh.logits, sh.cache)}
        pf = jax.jit(lambda p_, b: model.prefill(
            p_, b, dtype=jnp.float32, cache_dtype=jnp.float32,
            cache_len=T + G), **kw_pf)
        dec = jax.jit(lambda p_, t_, c, s: model.decode_step(
            p_, t_, c, s, dtype=jnp.float32), **kw_dec)
        outs = []
        with ctx:
            lg, cache, pos = pf(p, {"tokens": tokens})
        outs.append(np.asarray(lg))
        for i in range(G):
            with ctx:
                lg, cache = dec(p, forced[i], cache, pos)
            pos = pos + 1
            outs.append(np.asarray(lg))
        return np.concatenate(outs, axis=1)

    base = direct(None)
    tp = direct(make_serve_mesh("2x4"))
    print(json.dumps({
        "devices": len(jax.devices()),
        "done_single": len(t_single), "done_data": len(t_data),
        "bitwise_equal": bool(t_single == t_data),
        "tp_max_abs_diff": float(np.max(np.abs(base - tp))),
    }))


if __name__ == "__main__" and "--child" in sys.argv:
    _child_main()
