"""Dry-run smoke: one real 512-placeholder-device lowering in a
subprocess (the in-process test session is pinned to 1 CPU device)."""
import json
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_subprocess_decode():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen1.5-0.5b", "--shape", "decode_32k",
         "--mesh", "pod", "--out", "-"],
        capture_output=True, text=True, timeout=1200,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    assert rec["dominant"] in ("compute", "memory", "collective")
    assert rec["flops_dev"] > 0 and rec["coll_bytes_dev"] >= 0


def test_skip_list_documented():
    from repro.launch.dryrun import SKIPS
    assert ("whisper-small", "long_500k") in SKIPS
    assert len(SKIPS) == 1          # 39/40 combos run