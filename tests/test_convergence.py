"""Convergence-theory validation (Theorem 2, Proposition 1) + the
paper's qualitative experimental claims (C1-C3 in DESIGN.md) at reduced
scale. The full-scale versions live in benchmarks/."""
import dataclasses

import numpy as np
import pytest

from repro.configs import TopologyConfig, TTHFConfig
from repro.core import (
    ProblemConstants, TTHFTrainer, bound_curve, check_theorem2_conditions,
    make_baseline_config, theorem2_Z, theorem2_nu,
)
from repro.data import fashion_synth, partition_noniid_labels
from repro.models import make_sim_model


@pytest.fixture(scope="module")
def fleet():
    x, y = fashion_synth(num_points=2500, seed=0)
    data = partition_noniid_labels(x, y, num_devices=25)
    topo = TopologyConfig(num_devices=25, num_clusters=5,
                          graph="geometric", seed=0)
    model = make_sim_model("svm", 784, 10)
    return data, topo, model


def _run(data, topo, model, algo, steps=120, seed=0):
    tr = TTHFTrainer(model, data, topo, algo, batch_size=16)
    _, hist = tr.run(steps=steps, eval_every=steps // 6, seed=seed)
    return tr, hist


def test_c1_tthf_beats_fedavg_same_tau(fleet):
    """Fig. 4: TT-HF (tau=20, Gamma=2) beats FL tau=20 at equal steps,
    with 5x fewer uplinks."""
    data, topo, model = fleet
    lr = 0.002
    tthf = TTHFConfig(tau=20, consensus_every=5, gamma_d2d=2,
                      constant_lr=lr)
    fed = dataclasses.replace(make_baseline_config("fedavg", 20),
                              constant_lr=lr)
    tr1, h1 = _run(data, topo, model, tthf)
    tr2, h2 = _run(data, topo, model, fed)
    assert h1.global_loss[-1] < h2.global_loss[-1]
    assert tr1.ledger.uplinks * 4 <= tr2.ledger.uplinks


def test_c1_gamma_monotone_and_diminishing(fleet):
    """More D2D rounds -> better loss, approaching the tau=1 bound."""
    data, topo, model = fleet
    lr = 0.002
    finals = {}
    for g in (0, 2, 8):
        algo = TTHFConfig(tau=20, consensus_every=5, gamma_d2d=g,
                          constant_lr=lr)
        _, h = _run(data, topo, model, algo)
        finals[g] = h.global_loss[-1]
    cent = dataclasses.replace(make_baseline_config("centralized", 1),
                               constant_lr=lr)
    _, hc = _run(data, topo, model, cent)
    assert finals[2] < finals[0]
    assert finals[8] <= finals[2] + 1e-3
    # diminishing returns: Gamma=8 still no better than the tau=1 bound
    assert hc.global_loss[-1] <= finals[8] + 0.02


def test_consensus_error_reduced_by_d2d(fleet):
    """Definition 3: D2D rounds shrink the WITHIN-cluster consensus
    error eps^(t) (note: A^(t), the ACROSS-cluster dispersion, is not
    directly reduced by D2D — it enters the theory only through the
    eps-dependent bound of Proposition 1)."""
    data, topo, model = fleet
    lr = 0.002
    no_d2d = TTHFConfig(tau=40, consensus_every=0, gamma_d2d=0,
                        constant_lr=lr)
    with_d2d = TTHFConfig(tau=40, consensus_every=5, gamma_d2d=4,
                          constant_lr=lr)
    _, h0 = _run(data, topo, model, no_d2d, steps=39)
    _, h1 = _run(data, topo, model, with_d2d, steps=39)
    assert np.mean(h1.consensus_err[-3:]) < np.mean(h0.consensus_err[-3:])


def test_theorem2_conditions_and_nu():
    k = ProblemConstants(mu=0.1, beta=5.0, sigma=1.0, delta=0.5,
                         varrho_min=0.2)
    gamma = 20.0          # > 1/mu = 10
    alpha = gamma * k.beta ** 2 / k.mu  # minimum allowed
    conds = check_theorem2_conditions(k, gamma, alpha)
    assert all(conds.values()), conds
    nu = theorem2_nu(k, gamma, alpha, tau=20, phi=1.0, initial_gap=1.0)
    assert nu > 0
    # nu grows with tau (paper: sharp increase of the bound with tau)
    nu_long = theorem2_nu(k, gamma, alpha, tau=40, phi=1.0, initial_gap=1.0)
    assert nu_long > nu
    # and with phi (quadratic impact of consensus error)
    nu_phi = theorem2_nu(k, gamma, alpha, tau=20, phi=3.0, initial_gap=1.0)
    assert nu_phi > nu


def test_theorem2_rejects_bad_gamma():
    k = ProblemConstants(mu=0.1, beta=5.0, sigma=1.0, delta=0.5,
                         varrho_min=0.2)
    with pytest.raises(ValueError):
        theorem2_nu(k, gamma=5.0, alpha=1e4, tau=20, phi=1.0,
                    initial_gap=1.0)


def test_o1_over_t_convergence_envelope():
    """With eta_t = gamma/(t+alpha) under conditions that SATISFY
    Theorem 2 (unit-norm features -> beta = O(1), gamma > 1/mu,
    alpha ~ gamma*beta^2/mu) plus adaptive Remark-1 consensus, the SVM
    loss gap is enveloped by nu/(t+alpha) with nu fitted at the first
    checkpoint — the O(1/t) *shape* check."""
    from repro.data import fashion_synth, partition_noniid_labels
    x, y = fashion_synth(num_points=2500, seed=0, unit_norm=True)
    data = partition_noniid_labels(x, y, num_devices=25)
    topo = TopologyConfig(num_devices=25, num_clusters=5,
                          graph="geometric", seed=0)
    model = make_sim_model("svm", 784, 10)
    # mu = reg = 0.1; empirical beta ~ O(1) with unit-norm rows
    algo = TTHFConfig(tau=10, consensus_every=5, gamma_d2d=-1, phi=0.05,
                      gamma=20.0, alpha=1000.0)
    tr = TTHFTrainer(model, data, topo, algo, batch_size=16)
    _, hist = tr.run(steps=600, eval_every=60, seed=0)
    ts = np.asarray(hist.ts, float)
    loss = np.asarray(hist.global_loss)
    assert np.isfinite(loss).all(), loss
    f_star = loss.min() - 1e-3
    gap = loss - f_star
    nu = gap[0] * (ts[0] + algo.alpha)
    env = bound_curve(nu * 1.5, algo.alpha, ts)   # 1.5 slack
    assert (gap[2:] <= env[2:]).all(), (gap, env)
    assert gap[-1] < 0.7 * gap[0]
