"""Consensus operator properties: mean preservation (exactly — V is
doubly stochastic), contraction (Lemma 1), and pytree mixing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    broadcast_pytree, cluster_means, consensus_error, divergence_upsilon,
    lemma1_bound, mix, mix_pytree, metropolis_weights, ring_adjacency,
    spectral_radius, geometric_adjacency,
)


def _net(N, s, seed=0):
    rng = np.random.default_rng(seed)
    adjs = [geometric_adjacency(s, 0.8, rng) for _ in range(N)]
    V = np.stack([metropolis_weights(a) for a in adjs])
    lam = np.array([spectral_radius(v) for v in V])
    return jnp.asarray(V, jnp.float32), lam


@given(gamma=st.integers(0, 12), seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_mix_preserves_cluster_mean(gamma, seed):
    N, s, M = 3, 5, 17
    V, _ = _net(N, s, seed)
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(N, s, M)), jnp.float32)
    out = mix(z, V, jnp.full((N,), gamma, jnp.int32))
    np.testing.assert_allclose(np.asarray(cluster_means(out)),
                               np.asarray(cluster_means(z)),
                               rtol=0, atol=1e-4)


@given(seed=st.integers(0, 30))
@settings(max_examples=15, deadline=None)
def test_consensus_error_contracts(seed):
    """More rounds -> strictly smaller consensus error (for eps > 0)."""
    N, s, M = 2, 6, 11
    V, _ = _net(N, s, seed)
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(N, s, M)), jnp.float32)
    errs = [float(consensus_error(
        mix(z, V, jnp.full((N,), g, jnp.int32))).sum()) for g in (0, 2, 6)]
    assert errs[1] < errs[0] and errs[2] < errs[1]


@given(gamma=st.integers(1, 20), seed=st.integers(0, 30))
@settings(max_examples=25, deadline=None)
def test_lemma1_bound_holds(gamma, seed):
    """||e_i|| <= lambda^Gamma * s * Upsilon * M, elementwise over devices."""
    N, s, M = 1, 5, 8
    V, lam = _net(N, s, seed)
    rng = np.random.default_rng(seed + 99)
    z = jnp.asarray(rng.normal(size=(N, s, M)), jnp.float32)
    ups = float(divergence_upsilon(z)[0])
    out = mix(z, V, jnp.full((N,), gamma, jnp.int32))
    e = np.asarray(out - cluster_means(out)[:, None])
    norms = np.linalg.norm(e[0], axis=-1)
    bound = lemma1_bound(float(lam[0]), gamma, s, ups, M)
    assert (norms <= bound + 1e-5).all()


def test_mix_per_cluster_gammas_differ():
    N, s, M = 2, 4, 6
    V, _ = _net(N, s, 1)
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=(N, s, M)), jnp.float32)
    out = mix(z, V, jnp.asarray([0, 5], jnp.int32))
    # cluster 0 untouched, cluster 1 mixed
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(z[0]))
    assert not np.allclose(np.asarray(out[1]), np.asarray(z[1]))


def test_mix_pytree_matches_flat():
    N, s = 2, 5
    V, _ = _net(N, s, 2)
    rng = np.random.default_rng(3)
    I = N * s
    params = {"w": jnp.asarray(rng.normal(size=(I, 4, 3)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(I, 7)), jnp.float32)}
    gamma = jnp.asarray([2, 3], jnp.int32)
    out = mix_pytree(params, V, gamma, N)
    for name, leaf in params.items():
        flat = leaf.reshape(N, s, -1)
        expect = mix(flat, V, gamma).reshape(leaf.shape)
        np.testing.assert_allclose(np.asarray(out[name]),
                                   np.asarray(expect), atol=1e-6)


def test_broadcast_pytree():
    g = {"w": jnp.ones((3, 2))}
    out = broadcast_pytree(g, 7)
    assert out["w"].shape == (7, 3, 2)
