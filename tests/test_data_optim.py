"""Data pipeline + optimizer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import (
    fashion_synth, partition_iid, partition_noniid_labels,
    synthetic_token_batches,
)
from repro.optim import adamw, apply_updates, momentum, sgd


def test_fashion_synth_shapes_and_range():
    x, y = fashion_synth(num_points=500, seed=1)
    assert x.shape == (500, 784) and y.shape == (500,)
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)) <= set(range(10))
    # classes are separable enough to matter: per-class means differ
    m0 = x[y == 0].mean(0)
    m1 = x[y == 1].mean(0)
    assert np.linalg.norm(m0 - m1) > 1.0


@given(devs=st.sampled_from([5, 10, 25]), lpd=st.sampled_from([2, 3]))
@settings(max_examples=6, deadline=None)
def test_noniid_partition_label_restriction(devs, lpd):
    x, y = fashion_synth(num_points=3000, seed=0)
    data = partition_noniid_labels(x, y, num_devices=devs,
                                   labels_per_device=lpd)
    assert data.num_devices == devs
    for i in range(devs):
        labels = set(np.unique(data.y[i]))
        assert len(labels) <= lpd
        expect = {(i + j) % 10 for j in range(lpd)}
        assert labels <= expect


def test_iid_partition_covers_labels():
    x, y = fashion_synth(num_points=3000, seed=0)
    data = partition_iid(x, y, num_devices=10)
    for i in range(10):
        assert len(np.unique(data.y[i])) >= 8   # iid: most classes present


def test_token_stream_heterogeneity():
    g0 = synthetic_token_batches(2, 16, 100, seed=0, shard_id=0)
    g1 = synthetic_token_batches(2, 16, 100, seed=0, shard_id=1)
    b0, b1 = next(g0), next(g1)
    assert b0["tokens"].shape == (2, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # labels are next-token shifted
    g = synthetic_token_batches(1, 8, 50, seed=3, shard_id=0)
    b = next(g)
    assert b["tokens"].shape == b["labels"].shape


def test_token_stream_is_seekable_at_any_offset():
    """start=k must resume the EXACT start=0 sequence at batch k (O(1)
    seek — ScaleTrainer.restore depends on it for fast resume)."""
    ref = synthetic_token_batches(2, 16, 100, seed=0, shard_id=1)
    batches = [next(ref) for _ in range(7)]
    for k in (0, 1, 3, 6):
        g = synthetic_token_batches(2, 16, 100, seed=0, shard_id=1,
                                    start=k)
        for want in batches[k:]:
            got = next(g)
            np.testing.assert_array_equal(got["tokens"], want["tokens"])
            np.testing.assert_array_equal(got["labels"], want["labels"])


def _quad_problem():
    """min 0.5||w - 3||^2 — every optimizer must converge."""
    w0 = {"w": jnp.zeros((4,))}
    grad = lambda w: {"w": w["w"] - 3.0}
    return w0, grad


@pytest.mark.parametrize("opt,lr,steps", [
    (sgd(), 0.1, 200), (momentum(0.9), 0.05, 200),
    (adamw(), 0.1, 400),
])
def test_optimizers_converge_quadratic(opt, lr, steps):
    w, grad = _quad_problem()
    state = opt.init(w)
    for _ in range(steps):
        updates, state = opt.update(grad(w), state, w, lr)
        w = apply_updates(w, updates)
    np.testing.assert_allclose(np.asarray(w["w"]), 3.0, atol=1e-2)


def test_sgd_matches_manual():
    opt = sgd(weight_decay=0.1)
    w = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -0.5])}
    updates, _ = opt.update(g, opt.init(w), w, 0.1)
    expect = -0.1 * (np.array([0.5, -0.5]) + 0.1 * np.array([1.0, 2.0]))
    np.testing.assert_allclose(np.asarray(updates["w"]), expect, atol=1e-6)


def test_energy_ledger():
    from repro.core import CommLedger, E_GLOB_J
    led = CommLedger()
    led.record_aggregation(devices_sampled=5)
    led.record_consensus([2, 3], [4, 6])
    assert led.uplinks == 5
    assert led.d2d_msgs == 2 * 2 * 4 + 3 * 2 * 6   # Gamma * 2 * |E_c|
    # energy monotone in the ratio
    assert led.energy(0.1) < led.energy(1.0)
    assert led.delay(0.1) < led.delay(1.0)
