"""Paged serving correctness (DESIGN.md §15): PageTable/PrefixTrie
invariants, paged-vs-ring decode parity for every family, chunked
prefill == one-shot, Pallas kernel parity, cache-dtype plumbing, and
scheduler-level equivalence with prefix reuse and zero page leaks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import build_model
from repro.serving import (
    ContinuousScheduler, DUMMY_PAGE, PagedContinuousScheduler, PageTable,
    PrefixTrie, Request, engine, pages_per_slot, run_trace,
)

# ---------------------------------------------------------------- pages


def test_page_table_alloc_release_roundtrip():
    t = PageTable(num_pages=8, page_size=4)      # 7 usable + dummy
    assert t.num_free == 7
    a = t.alloc(3)
    b = t.alloc(4)
    assert a is not None and b is not None
    assert t.num_free == 0
    assert DUMMY_PAGE not in a + b
    assert len(set(a + b)) == 7                  # no double-handout
    # pool exhausted -> deferral cue, no partial allocation
    assert t.alloc(1) is None
    assert t.num_free == 0
    freed = t.release(a)
    assert sorted(freed) == sorted(a)
    assert t.num_free == 3
    t.release(b)
    assert t.num_free == 7


def test_page_table_refcounts():
    t = PageTable(num_pages=4, page_size=4)
    (p,) = t.alloc(1)
    t.retain([p])                                # shared by two owners
    assert t.release([p]) == []                  # still referenced
    assert t.num_free == 2
    assert t.release([p]) == [p]                 # last owner frees
    assert t.num_free == 3


def test_page_table_occupancy():
    t = PageTable(num_pages=5, page_size=4)
    assert t.occupancy == 0.0
    t.alloc(2)
    assert t.occupancy == pytest.approx(0.5)


def test_prefix_trie_match_register_forget():
    ps = 4
    cap = lambda p: (len(p) - 1) // ps
    trie = PrefixTrie(ps)
    prompt = np.arange(1, 12, dtype=np.int32)    # 11 tokens, 2 full pages
    assert trie.match(prompt, cap(prompt)) == []
    assert trie.register(prompt, [3, 5]) == 2
    # full-page chunks shared; callers cap at (plen-1)//ps so the page
    # holding the final prompt token is never shared mid-write
    assert trie.match(prompt, cap(prompt)) == [3, 5]
    assert trie.match(prompt[:ps + 1], 1) == [3]
    assert trie.match(prompt[:ps], cap(prompt[:ps])) == []   # cap == 0
    divergent = prompt.copy()
    divergent[1] = 99
    assert trie.match(divergent, cap(divergent)) == []
    # forgetting the parent page orphans the chain from the root
    trie.forget(3)
    assert trie.match(prompt, cap(prompt)) == []
    trie.register(prompt, [3, 5])
    trie.forget(5)
    assert trie.match(prompt, cap(prompt)) == [3]
    # first writer keeps a trie slot; duplicates stay unshared
    assert trie.register(prompt, [3, 7]) == 1    # only chunk 2 republished
    assert trie.match(prompt, cap(prompt)) == [3, 7]


def test_pages_per_slot():
    assert pages_per_slot(16, 4) == 4
    assert pages_per_slot(17, 4) == 5


# ------------------------------------------------- paged decode parity

FAMILIES = {
    "dense": ("qwen1.5-0.5b", 0, {}),
    "dense-window": ("qwen1.5-0.5b", 8, {}),
    "sliding": ("starcoder2-3b", 0, {"sliding_window": 8}),
    "moe": ("llama4-scout-17b-a16e", 0, {"moe_capacity_factor": 8.0}),
    "ssm": ("mamba2-370m", 0, {}),
    "hybrid": ("recurrentgemma-9b", 0, {"attention_window": 8}),
}


def _tiny(arch, **over):
    cfg = get_arch(arch).reduced(num_layers=2, d_model=64, d_ff=128,
                                 vocab_size=128)
    if over:
        cfg = dataclasses.replace(cfg, **over)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _ring_reference(params, cfg, prompt, max_new, serve_window):
    max_total = len(prompt) + max_new
    toks = jnp.asarray(prompt)[None]
    logits, cache, pos = engine.prefill(
        params, cfg, {"tokens": toks}, dtype=jnp.float32,
        cache_dtype=jnp.float32, cache_len=max_total,
        serve_window=serve_window)
    out_logits = [np.asarray(logits[0, 0])]
    tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(1, 1)
    out_toks = [int(tok[0, 0])]
    for _ in range(max_new - 1):
        logits, cache = engine.decode_step(
            params, cfg, tok, cache, pos, dtype=jnp.float32,
            serve_window=serve_window)
        out_logits.append(np.asarray(logits[0, 0]))
        tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(1, 1)
        out_toks.append(int(tok[0, 0]))
        pos = pos + 1
    return out_logits, out_toks


def _paged_run(params, cfg, prompt, max_new, serve_window, *, ps=4,
               chunk=8):
    plen = len(prompt)
    P = pages_per_slot(plen + max_new, ps)
    table = PageTable(P + 1, ps)
    cache = engine.init_paged_cache_tree(cfg, 1, P + 1, ps, jnp.float32)
    row = jnp.asarray(table.alloc(P), jnp.int32)
    padded = np.zeros(((plen + chunk - 1) // chunk) * chunk, np.int32)
    padded[:plen] = prompt
    start = 0
    while start < plen:
        valid = min(chunk, plen - start)
        cache, logits = engine.prefill_chunk(
            params, cfg, cache, jnp.asarray(
                padded[start:start + chunk])[None],
            start, valid, row, 0, dtype=jnp.float32,
            serve_window=serve_window)
        start += valid
    out_logits = [np.asarray(logits[0, 0])]
    tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(1, 1)
    out_toks = [int(tok[0, 0])]
    page_map, live = row[None], jnp.asarray([True])
    pos = jnp.asarray([plen], jnp.int32)
    for _ in range(max_new - 1):
        logits, cache = engine.decode_step_paged(
            params, cfg, tok, cache, pos, page_map, live,
            dtype=jnp.float32, serve_window=serve_window)
        out_logits.append(np.asarray(logits[0, 0]))
        tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(1, 1)
        out_toks.append(int(tok[0, 0]))
        pos = pos + 1
    return out_logits, out_toks


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_paged_decode_matches_ring(family):
    arch, serve_window, over = FAMILIES[family]
    cfg, _, params = _tiny(arch, **over)
    prompt = np.random.default_rng(0).integers(
        1, cfg.vocab_size, size=11).astype(np.int32)
    ref_l, ref_t = _ring_reference(params, cfg, prompt, 5, serve_window)
    pg_l, pg_t = _paged_run(params, cfg, prompt, 5, serve_window)
    assert pg_t == ref_t
    err = max(np.abs(a - b).max() for a, b in zip(ref_l, pg_l))
    assert err <= 1e-5, f"{family}: max |logits diff| {err}"


def test_chunked_prefill_matches_one_shot():
    cfg, _, params = _tiny("qwen1.5-0.5b")
    prompt = np.random.default_rng(1).integers(
        1, cfg.vocab_size, size=13).astype(np.int32)
    # one-shot: chunk covers the whole (padded) prompt
    l_one, t_one = _paged_run(params, cfg, prompt, 4, 0, ps=4, chunk=16)
    l_chk, t_chk = _paged_run(params, cfg, prompt, 4, 0, ps=4, chunk=4)
    assert t_chk == t_one
    err = max(np.abs(a - b).max() for a, b in zip(l_one, l_chk))
    assert err <= 1e-5


def test_paged_kernel_matches_jnp_gather():
    from repro.kernels.paged_attn import paged_decode
    rng = np.random.default_rng(2)
    B, P, ps, K, G, hd = 2, 3, 4, 2, 2, 8
    q = jnp.asarray(rng.normal(size=(B, K, G, hd)), jnp.float32)
    k_pages = jnp.asarray(rng.normal(size=(P + 1, ps, K, hd)), jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(P + 1, ps, K, hd)), jnp.float32)
    page_map = jnp.asarray([[1, 2, 3], [3, 1, 2]], jnp.int32)
    pos = jnp.asarray([5, 9], jnp.int32)
    for window in (0, 4):
        out = paged_decode(q, k_pages, v_pages, page_map, pos,
                           window=window, interpret=True)
        # reference: gather + masked softmax
        kk = k_pages[page_map].reshape(B, P * ps, K, hd)
        vv = v_pages[page_map].reshape(B, P * ps, K, hd)
        k_pos = jnp.arange(P * ps)[None, :]
        ok = k_pos <= pos[:, None]
        if window:
            ok &= k_pos > pos[:, None] - window
        s = jnp.einsum("bkgh,btkh->bkgt", q, kk) / np.sqrt(hd)
        s = jnp.where(ok[:, None, None, :], s, -1e30)
        ref = jnp.einsum("bkgt,btkh->bkgh", jax.nn.softmax(s, -1), vv)
        assert float(jnp.abs(out - ref).max()) <= 1e-5


# --------------------------------------------------- cache-dtype plumb


@pytest.mark.parametrize("sched_cls",
                         [ContinuousScheduler, PagedContinuousScheduler])
def test_cache_dtype_reaches_cache_leaves(sched_cls):
    cfg, model, params = _tiny("qwen1.5-0.5b")
    sched = sched_cls(model, slots=2, max_prompt=8, max_total=16,
                      cache_dtype=jnp.bfloat16)
    sched.submit(Request(rid=0, prompt=np.arange(1, 7, dtype=np.int32),
                         max_new=2))
    for _ in range(16):
        sched.step(params)
        if not sched.outstanding:
            break
    floating = [leaf.dtype for leaf in jax.tree.leaves(sched._cache)
                if jnp.issubdtype(leaf.dtype, jnp.floating)]
    assert floating and all(d == jnp.bfloat16 for d in floating)
    assert sched.stats.requests_done == 1


# ------------------------------------------------- scheduler-level e2e


def _trace(cfg, rng, n_req, template=0):
    tmpl = rng.integers(1, cfg.vocab_size, size=template).astype(np.int32)
    arrivals, step = [], 0
    for rid in range(n_req):
        tail = rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(3, 10))).astype(np.int32)
        prompt = np.concatenate([tmpl, tail])[:14].astype(np.int32)
        arrivals.append((step, Request(rid=rid, prompt=prompt,
                                       max_new=int(rng.integers(2, 6)))))
        step += int(rng.poisson(2.0))
    return arrivals


def test_paged_scheduler_matches_continuous():
    cfg, model, params = _tiny("qwen1.5-0.5b")
    mk = lambda: np.random.default_rng(7)
    ring = _trace(cfg, mk(), 6)
    paged = _trace(cfg, mk(), 6)
    kw = dict(slots=2, max_prompt=14, max_total=20, temperature=0.0)
    s_ring = run_trace(ContinuousScheduler(model, **kw), params, ring)
    sched = PagedContinuousScheduler(model, page_size=4, prefill_chunk=8,
                                     **kw)
    s_paged = run_trace(sched, params, paged)
    assert s_paged.requests_done == s_ring.requests_done == 6
    for (_, a), (_, b) in zip(ring, paged):
        assert b.out_tokens == a.out_tokens, f"rid {a.rid} diverged"
    # every page returned to the pool, trie fully forgotten
    assert sched.table.num_free == sched.cache_pages - 1
    p0 = ring[0][1].prompt
    assert sched.trie.match(p0, (len(p0) - 1) // 4) == []
    assert len(sched.trie) == 0
    # chunk=8 over up-to-14-token prompts -> some prompts take 2 chunks
    assert any(r.prefill_chunks >= 2 for r in s_paged.records)


def test_paged_scheduler_prefix_reuse_and_deferral():
    cfg, model, params = _tiny("qwen1.5-0.5b")
    rng = np.random.default_rng(11)
    arrivals = _trace(cfg, rng, 8, template=8)
    # pool sized below slots * pages_per_slot: deferrals must engage
    sched = PagedContinuousScheduler(
        model, page_size=4, cache_pages=9, slots=2, max_prompt=14,
        max_total=20, temperature=0.0)
    stats = run_trace(sched, params, arrivals)
    assert stats.requests_done == 8
    reused = sum(r.prefix_pages_reused for r in stats.records)
    assert reused > 0                    # shared template actually hit
    assert sched.prefix_hit_rate > 0
    assert sched.table.num_free == sched.cache_pages - 1   # no leaks
