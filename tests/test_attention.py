"""Attention unit tests: flash == simple (fwd + grad) across masks,
GQA grouping, RoPE properties, decode against cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import (
    flash_attention, simple_attention, _mask_block,
)
from repro.models.common import rope


def _qkv(B=2, T=128, K=2, G=2, hd=16, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, T, K, G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, K, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, K, hd)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("mode,window,prefix", [
    ("causal", 0, None), ("sliding", 32, None), ("sliding", 7, None),
    ("prefix", 0, 13), ("full", 0, None),
])
def test_flash_equals_simple_forward(mode, window, prefix):
    q, k, v = _qkv()
    o1 = flash_attention(q, k, v, mode=mode, window=window,
                         prefix_len=prefix, q_chunk=32, k_chunk=64)
    o2 = simple_attention(q, k, v, mode=mode, window=window,
                          prefix_len=prefix)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


@pytest.mark.parametrize("mode,window,prefix", [
    ("causal", 0, None), ("sliding", 16, None), ("prefix", 0, 9),
])
def test_flash_gradients_equal_simple(mode, window, prefix):
    q, k, v = _qkv(T=64)
    f = lambda *a: (flash_attention(*a, mode=mode, window=window,
                                    prefix_len=prefix, q_chunk=16,
                                    k_chunk=16) ** 2).sum()
    s = lambda *a: (simple_attention(*a, mode=mode, window=window,
                                     prefix_len=prefix) ** 2).sum()
    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(s, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


@given(qc=st.sampled_from([16, 32, 64]), kc=st.sampled_from([16, 32, 64]))
@settings(max_examples=9, deadline=None)
def test_flash_chunk_size_invariance(qc, kc):
    q, k, v = _qkv(T=64)
    base = simple_attention(q, k, v, mode="causal")
    out = flash_attention(q, k, v, mode="causal", q_chunk=qc, k_chunk=kc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), atol=2e-5)


def test_mask_block_semantics():
    q_pos = jnp.arange(4) + 2
    k_pos = jnp.arange(8)
    causal = _mask_block(q_pos, k_pos, "causal", 0, 0)
    assert bool(causal[0, 2]) and not bool(causal[0, 3])
    sw = _mask_block(q_pos, k_pos, "sliding", 2, 0)
    # q=2 sees k in (0, 2]: k=1,2
    assert not bool(sw[0, 0]) and bool(sw[0, 1]) and bool(sw[0, 2])
    pf = _mask_block(q_pos, k_pos, "prefix", 0, 4)
    # q=2 (inside prefix) sees k=3 (also prefix) though 3 > 2
    assert bool(pf[0, 3]) and not bool(pf[0, 4])


def test_rope_preserves_norm_and_relativity():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 6, 2, 16)), jnp.float32)
    pos = jnp.arange(6)
    y = rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    dots = []
    for p in (0, 5):
        qr = rope(q, jnp.asarray([p]))
        kr = rope(k, jnp.asarray([p + 3]))
        dots.append(float(jnp.sum(qr * kr)))
    assert abs(dots[0] - dots[1]) < 1e-4


def test_gqa_grouping_consistency():
    """GQA with G groups == MHA when K/V are repeated per group."""
    B, T, K, G, hd = 1, 16, 2, 3, 8
    q, k, v = _qkv(B, T, K, G, hd)
    out = simple_attention(q, k, v, mode="causal")
    # expand to MHA: each (k-head, group) pair becomes its own kv head
    q_mha = q.reshape(B, T, K * G, 1, hd)
    k_mha = jnp.repeat(k, G, axis=2)
    v_mha = jnp.repeat(v, G, axis=2)
    out_mha = simple_attention(q_mha, k_mha, v_mha, mode="causal")
    np.testing.assert_allclose(np.asarray(out).reshape(B, T, -1),
                               np.asarray(out_mha).reshape(B, T, -1),
                               atol=1e-5)


@pytest.mark.parametrize("mode,window,prefix", [
    ("causal", 0, None), ("sliding", 48, None), ("prefix", 0, 37),
])
def test_pair_scheduled_flash_matches_simple(mode, window, prefix):
    from repro.models.attention import flash_attention_pairs
    q, k, v = _qkv(T=128)
    o1 = flash_attention_pairs(q, k, v, mode=mode, window=window,
                               prefix_len=prefix, q_chunk=32, k_chunk=32)
    o2 = simple_attention(q, k, v, mode=mode, window=window,
                          prefix_len=prefix)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
    f = lambda *a: (flash_attention_pairs(
        *a, mode=mode, window=window, prefix_len=prefix, q_chunk=32,
        k_chunk=32) ** 2).sum()
    s = lambda *a: (simple_attention(*a, mode=mode, window=window,
                                     prefix_len=prefix) ** 2).sum()
    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(s, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_pair_schedule_visits_fewer_blocks():
    from repro.models.attention import _block_pairs
    full = len(_block_pairs(8, 8, 64, 64, "full", 0, None, 0))
    causal = len(_block_pairs(8, 8, 64, 64, "causal", 0, None, 0))
    sliding = len(_block_pairs(8, 8, 64, 64, "sliding", 64, None, 0))
    assert full == 64
    assert causal == 36           # lower triangle incl. diagonal
    assert sliding == 15          # banded: diag + one off-diagonal
