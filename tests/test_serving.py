"""Serving correctness: prefill + step-by-step decode must equal the
full forward pass, for every architecture family; ring-buffer sliding
window checks; cache shape/axes consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import build_model
from repro.serving import engine

ALL_ARCHS = sorted(ARCHS)


def _prep(name, serve_window=0, T=24):
    cfg = get_arch(name).reduced()
    if cfg.kind == "hybrid":
        cfg = dataclasses.replace(cfg, attention_window=16)
    if cfg.moe_num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    B = 2
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.kind == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model)) * 0.1
    if cfg.kind in ("encdec", "audio"):
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model)) * 0.1
    return cfg, model, params, batch, tokens


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_matches_forward(name):
    T, n_dec = 24, 3
    cfg, model, params, batch, tokens = _prep(name, T=T)
    logits_full, _ = model.forward(params, batch, dtype=jnp.float32)
    Tp = T - n_dec
    pfb = {k: v for k, v in batch.items() if k != "labels"}
    pfb["tokens"] = tokens[:, :Tp]
    cl = T + (cfg.enc_seq_len if cfg.kind == "vlm" else 0)
    lg, cache, pos = model.prefill(params, pfb, dtype=jnp.float32,
                                   cache_dtype=jnp.float32, cache_len=cl)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(logits_full[:, Tp - 1]),
                               atol=5e-5)
    for i in range(n_dec):
        tok = tokens[:, Tp + i:Tp + i + 1]
        lg, cache = model.decode_step(params, tok, cache, pos,
                                      dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits_full[:, Tp + i]),
                                   atol=5e-5)
        pos = pos + 1


def test_sliding_window_ring_buffer_matches_full_recompute():
    """Dense arch + serving SWA: decode with a ring cache of width w must
    equal a full forward over the last w tokens."""
    name = "qwen1.5-0.5b"
    w = 8
    cfg = get_arch(name).reduced()
    cfg = dataclasses.replace(cfg, sliding_window=w)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(2)
    B, T = 1, 20
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    # forward with SWA over the full sequence
    logits_full, _ = model.forward(
        params, {"tokens": tokens, "labels": tokens}, dtype=jnp.float32)
    # prefill 16, decode 4 with the ring cache
    Tp = 16
    lg, cache, pos = model.prefill(params, {"tokens": tokens[:, :Tp]},
                                   dtype=jnp.float32,
                                   cache_dtype=jnp.float32, cache_len=T)
    assert cache["layers"]["k"].shape[2] == w   # ring capacity == window
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(logits_full[:, Tp - 1]),
                               atol=5e-5)
    for i in range(T - Tp):
        tok = tokens[:, Tp + i:Tp + i + 1]
        lg, cache = model.decode_step(params, tok, cache, pos,
                                      dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits_full[:, Tp + i]),
                                   atol=5e-5)
        pos = pos + 1


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_cache_axes_structure_matches_cache(name):
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    cache = model.init_cache(2, 16, jnp.float32)
    axes = model.cache_axes()
    flat_c = jax.tree.leaves(cache)
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_c) == len(flat_a)
    for c, a in zip(flat_c, flat_a):
        assert len(a) == c.ndim, (name, a, c.shape)


def test_input_specs_cover_all_shapes():
    from repro.configs import INPUT_SHAPES
    for name in ALL_ARCHS:
        model = build_model(get_arch(name))
        for sname, shape in INPUT_SHAPES.items():
            specs = model.input_specs(shape)
            if shape.phase == "decode":
                assert "cache" in specs and "token" in specs
                assert specs["token"].shape == (shape.global_batch, 1)
            else:
                assert specs["batch"]["tokens"].dtype == jnp.int32


def test_pair_schedule_serving_consistency():
    """Prefill with the pair-scheduled flash must produce the same
    logits as the rectangular sweep (HC3 §Perf optimization)."""
    import jax.numpy as jnp
    from repro.models import attention as attn_mod
    cfg, model, params, batch, tokens = _prep("starcoder2-3b", T=24)
    pfb = {"tokens": tokens}
    lg_base, _, _ = model.prefill(params, pfb, dtype=jnp.float32,
                                  cache_dtype=jnp.float32)
    with attn_mod.pair_schedule(True):
        lg_pair, _, _ = model.prefill(params, pfb, dtype=jnp.float32,
                                      cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg_base), np.asarray(lg_pair),
                               atol=1e-4)


def test_moe_expert_ffn_axis_controllable():
    """HC4: the expert FFN dim has its own logical axis so EP layouts
    can be flipped without touching model code."""
    import jax
    from repro.configs import get_arch
    from repro.models import build_model
    m = build_model(get_arch("llama4-scout-17b-a16e").reduced())
    _, axes = m.abstract_params()
    wup = axes["layers"]["moe"]["w_up"]
    assert "expert_ffn" in wup and "experts" in wup
