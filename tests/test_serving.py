"""Serving correctness: prefill + step-by-step decode must equal the
full forward pass, for every architecture family; ring-buffer sliding
window checks; cache shape/axes consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import build_model
from repro.serving import engine

ALL_ARCHS = sorted(ARCHS)


def _prep(name, serve_window=0, T=24):
    cfg = get_arch(name).reduced()
    if cfg.kind == "hybrid":
        cfg = dataclasses.replace(cfg, attention_window=16)
    if cfg.moe_num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    B = 2
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.kind == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model)) * 0.1
    if cfg.kind in ("encdec", "audio"):
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model)) * 0.1
    return cfg, model, params, batch, tokens


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_matches_forward(name):
    T, n_dec = 24, 3
    cfg, model, params, batch, tokens = _prep(name, T=T)
    logits_full, _ = model.forward(params, batch, dtype=jnp.float32)
    Tp = T - n_dec
    pfb = {k: v for k, v in batch.items() if k != "labels"}
    pfb["tokens"] = tokens[:, :Tp]
    cl = T + (cfg.enc_seq_len if cfg.kind == "vlm" else 0)
    lg, cache, pos = model.prefill(params, pfb, dtype=jnp.float32,
                                   cache_dtype=jnp.float32, cache_len=cl)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(logits_full[:, Tp - 1]),
                               atol=5e-5)
    for i in range(n_dec):
        tok = tokens[:, Tp + i:Tp + i + 1]
        lg, cache = model.decode_step(params, tok, cache, pos,
                                      dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits_full[:, Tp + i]),
                                   atol=5e-5)
        pos = pos + 1


def test_sliding_window_ring_buffer_matches_full_recompute():
    """Dense arch + serving SWA: decode with a ring cache of width w must
    equal a full forward over the last w tokens."""
    name = "qwen1.5-0.5b"
    w = 8
    cfg = get_arch(name).reduced()
    cfg = dataclasses.replace(cfg, sliding_window=w)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(2)
    B, T = 1, 20
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    # forward with SWA over the full sequence
    logits_full, _ = model.forward(
        params, {"tokens": tokens, "labels": tokens}, dtype=jnp.float32)
    # prefill 16, decode 4 with the ring cache
    Tp = 16
    lg, cache, pos = model.prefill(params, {"tokens": tokens[:, :Tp]},
                                   dtype=jnp.float32,
                                   cache_dtype=jnp.float32, cache_len=T)
    assert cache["layers"]["k"].shape[2] == w   # ring capacity == window
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(logits_full[:, Tp - 1]),
                               atol=5e-5)
    for i in range(T - Tp):
        tok = tokens[:, Tp + i:Tp + i + 1]
        lg, cache = model.decode_step(params, tok, cache, pos,
                                      dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits_full[:, Tp + i]),
                                   atol=5e-5)
        pos = pos + 1


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_cache_axes_structure_matches_cache(name):
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    cache = model.init_cache(2, 16, jnp.float32)
    axes = model.cache_axes()
    flat_c = jax.tree.leaves(cache)
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_c) == len(flat_a)
    for c, a in zip(flat_c, flat_a):
        assert len(a) == c.ndim, (name, a, c.shape)


def test_input_specs_cover_all_shapes():
    from repro.configs import INPUT_SHAPES
    for name in ALL_ARCHS:
        model = build_model(get_arch(name))
        for sname, shape in INPUT_SHAPES.items():
            specs = model.input_specs(shape)
            if shape.phase == "decode":
                assert "cache" in specs and "token" in specs
                assert specs["token"].shape == (shape.global_batch, 1)
            else:
                assert specs["batch"]["tokens"].dtype == jnp.int32


def test_pair_schedule_serving_consistency():
    """Prefill with the pair-scheduled flash must produce the same
    logits as the rectangular sweep (HC3 §Perf optimization)."""
    import jax.numpy as jnp
    from repro.models import attention as attn_mod
    cfg, model, params, batch, tokens = _prep("starcoder2-3b", T=24)
    pfb = {"tokens": tokens}
    lg_base, _, _ = model.prefill(params, pfb, dtype=jnp.float32,
                                  cache_dtype=jnp.float32)
    with attn_mod.pair_schedule(True):
        lg_pair, _, _ = model.prefill(params, pfb, dtype=jnp.float32,
                                      cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg_base), np.asarray(lg_pair),
                               atol=1e-4)


def test_moe_expert_ffn_axis_controllable():
    """HC4: the expert FFN dim has its own logical axis so EP layouts
    can be flipped without touching model code."""
    import jax
    from repro.configs import get_arch
    from repro.models import build_model
    m = build_model(get_arch("llama4-scout-17b-a16e").reduced())
    _, axes = m.abstract_params()
    wup = axes["layers"]["moe"]["w_up"]
    assert "expert_ffn" in wup and "experts" in wup


# ---------------------------------------------------------------------------
# mixed-length (right-padded) prefill + per-slot decode
# ---------------------------------------------------------------------------

MIXED_ARCHS = ["qwen1.5-0.5b", "llama4-scout-17b-a16e", "mamba2-370m",
               "recurrentgemma-9b", "whisper-small", "paligemma-3b"]


def _solo_prefill(model, params, prompt, extras, cl):
    b = {"tokens": jnp.asarray(prompt[None])}
    b.update(extras)
    return model.prefill(params, b, dtype=jnp.float32,
                         cache_dtype=jnp.float32, cache_len=cl)


@pytest.mark.parametrize("name", MIXED_ARCHS)
def test_prefill_lengths_matches_solo(name):
    """Right-padded mixed-length prefill == one solo prefill per row:
    logits at each row's last valid token, per-slot pos, and a cache
    that decodes identically to the solo caches."""
    lens = [5, 11]
    cfg = get_arch(name).reduced()
    if cfg.kind == "hybrid":
        cfg = dataclasses.replace(cfg, attention_window=16)
    if cfg.moe_num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size - 1, size=l).astype(np.int32)
               for l in lens]
    MP, MT = 16, 32
    cl = MT + (cfg.enc_seq_len if cfg.kind == "vlm" else 0)
    key = jax.random.PRNGKey(3)
    extras = {}
    if cfg.kind == "vlm":
        extras["patches"] = jax.random.normal(
            key, (len(lens), cfg.enc_seq_len, cfg.d_model)) * 0.1
    if cfg.kind in ("encdec", "audio"):
        extras["frames"] = jax.random.normal(
            key, (len(lens), cfg.enc_seq_len, cfg.d_model)) * 0.1
    toks = np.zeros((len(lens), MP), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p
    batch = {"tokens": jnp.asarray(toks)}
    batch.update(extras)
    lg, cache, pos = model.prefill(params, batch, dtype=jnp.float32,
                                   cache_dtype=jnp.float32, cache_len=cl,
                                   lengths=jnp.asarray(lens))
    assert pos.shape == (len(lens),)
    off = cfg.enc_seq_len if cfg.kind == "vlm" else 0
    np.testing.assert_array_equal(np.asarray(pos), np.asarray(lens) + off)

    solo = []
    for i, p in enumerate(prompts):
        ex = {k: v[i:i + 1] for k, v in extras.items()}
        lgs, cs, ps = _solo_prefill(model, params, p, ex, cl)
        np.testing.assert_allclose(np.asarray(lg[i]), np.asarray(lgs[0]),
                                   atol=3e-4)
        solo.append((lgs, cs, ps))

    # 4 greedy decode steps: batched per-slot pos vs each solo run
    lgb, cb, pb = lg, cache, pos
    for step in range(4):
        tb = jnp.argmax(lgb[:, -1], -1)[:, None].astype(jnp.int32)
        new_solo = []
        for i, (lgs, cs, ps) in enumerate(solo):
            ts = jnp.argmax(lgs[:, -1], -1)[:, None].astype(jnp.int32)
            assert int(ts[0, 0]) == int(tb[i, 0]), (name, step, i)
            lgs, cs = model.decode_step(params, ts, cs, ps,
                                        dtype=jnp.float32)
            new_solo.append((lgs, cs, ps + 1))
        solo = new_solo
        lgb, cb = model.decode_step(params, tb, cb, pb, dtype=jnp.float32)
        pb = pb + 1
        for i, (lgs, _, _) in enumerate(solo):
            np.testing.assert_allclose(np.asarray(lgb[i]),
                                       np.asarray(lgs[0]), atol=3e-4,
                                       err_msg=f"{name} step {step} row {i}")


def test_decode_step_vector_pos_matches_scalar():
    """A (B,) pos vector with equal entries must reproduce the scalar-pos
    decode path exactly."""
    cfg, model, params, batch, tokens = _prep("qwen1.5-0.5b", T=16)
    pfb = {"tokens": tokens[:, :12]}
    lg, cache, pos = model.prefill(params, pfb, dtype=jnp.float32,
                                   cache_dtype=jnp.float32, cache_len=24)
    tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
    lg_s, cache_s = model.decode_step(params, tok, cache, pos,
                                      dtype=jnp.float32)
    vec = jnp.full((tokens.shape[0],), pos, jnp.int32)
    lg_v, cache_v = model.decode_step(params, tok, cache, vec,
                                      dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_v))
    for a, b in zip(jax.tree.leaves(cache_s), jax.tree.leaves(cache_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", ["qwen1.5-0.5b", "mamba2-370m",
                                  "recurrentgemma-9b"])
def test_write_cache_slot_roundtrip(name):
    """A batch-1 prefill written into a live batch cache via
    write_cache_slot must decode exactly like its solo continuation,
    while the other slot's lane is untouched."""
    cfg = get_arch(name).reduced()
    if cfg.kind == "hybrid":
        cfg = dataclasses.replace(cfg, attention_window=16)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    MT = 32
    cache = model.init_cache(2, MT, jnp.float32)
    pos = jnp.zeros((2,), jnp.int32)

    # slot 0: a 7-token prompt; slot 1: a 10-token prompt, admitted later
    prompts = [rng.integers(1, cfg.vocab_size - 1, size=n).astype(np.int32)
               for n in (7, 10)]
    solos = []
    for slot, p in enumerate(prompts):
        lg1, c1, p1 = model.prefill(
            params, {"tokens": jnp.asarray(p[None])}, dtype=jnp.float32,
            cache_dtype=jnp.float32, cache_len=MT)
        cache, pos = model.write_cache_slot(cache, c1, slot, pos=pos,
                                            one_pos=p1)
        solos.append((lg1, c1, p1))
    np.testing.assert_array_equal(np.asarray(pos), [7, 10])

    # per-leaf: slot rows equal the solo cache rows
    axes = model.cache_axes()
    flat_c = jax.tree.leaves(cache)
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    for slot in (0, 1):
        flat_s = jax.tree.leaves(solos[slot][1])
        for c, s, a in zip(flat_c, flat_s, flat_a):
            b = a.index("cache_batch")
            np.testing.assert_array_equal(
                np.asarray(jnp.take(c, slot, axis=b)),
                np.asarray(jnp.take(s, 0, axis=b)))

    # 3 joint decode steps at per-slot positions == solo decode
    lgb = jnp.concatenate([solos[0][0], solos[1][0]], axis=0)
    for _ in range(3):
        tb = jnp.argmax(lgb[:, -1], -1)[:, None].astype(jnp.int32)
        new = []
        for i, (lgs, cs, ps) in enumerate(solos):
            ts = jnp.argmax(lgs[:, -1], -1)[:, None].astype(jnp.int32)
            assert int(ts[0, 0]) == int(tb[i, 0])
            lgs, cs = model.decode_step(params, ts, cs, ps,
                                        dtype=jnp.float32)
            new.append((lgs, cs, ps + 1))
        solos = new
        lgb, cache = model.decode_step(params, tb, cache, pos,
                                       dtype=jnp.float32)
        pos = pos + 1
        for i, (lgs, _, _) in enumerate(solos):
            np.testing.assert_allclose(np.asarray(lgb[i]),
                                       np.asarray(lgs[0]), atol=3e-4)


def test_moe_default_capacity_row_independent_routing():
    """At the DEFAULT (binding) capacity factor, serving prefill routes
    per row: pad tokens consume no expert capacity and a slot in a
    mixed-length batch dispatches exactly like a batch-1 admission
    prefill of the same padded prompt (what ContinuousScheduler runs).
    Unpadded-solo equality additionally needs a non-binding capacity
    (the cf=8.0 used elsewhere); capacity is a function of the padded
    group, so it is NOT asserted here."""
    cfg = get_arch("llama4-scout-17b-a16e").reduced()   # default cf
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    lens = [5, 11]
    prompts = [rng.integers(1, cfg.vocab_size - 1, size=l).astype(np.int32)
               for l in lens]
    MP, MT = 16, 32
    toks = np.zeros((2, MP), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p
    lg, _, _ = model.prefill(params, {"tokens": jnp.asarray(toks)},
                             dtype=jnp.float32, cache_dtype=jnp.float32,
                             cache_len=MT, lengths=jnp.asarray(lens))
    for i, p in enumerate(prompts):
        t1 = np.zeros((1, MP), np.int32)
        t1[0, : len(p)] = p
        lg1, _, _ = model.prefill(
            params, {"tokens": jnp.asarray(t1)}, dtype=jnp.float32,
            cache_dtype=jnp.float32, cache_len=MT,
            lengths=jnp.asarray([len(p)]))
        np.testing.assert_allclose(np.asarray(lg[i]), np.asarray(lg1[0]),
                                   atol=1e-5)
    # short row: no expert exceeds either capacity (padded c=5,
    # unpadded c=2) for this seed, so the unpadded solo matches too
    lgs, _, _ = model.prefill(
        params, {"tokens": jnp.asarray(prompts[0][None])},
        dtype=jnp.float32, cache_dtype=jnp.float32, cache_len=MT)
    np.testing.assert_allclose(np.asarray(lg[0]), np.asarray(lgs[0]),
                               atol=1e-5)
