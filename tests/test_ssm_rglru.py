"""SSM (Mamba-2 SSD) and RG-LRU block unit tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.kernels import ref as kref
from repro.models.common import split_tree
from repro.models.ssm import (
    apply_ssm, decode_ssm, init_ssm, init_ssm_cache, ssd_chunked,
)
from repro.models.rglru import (
    apply_rglru, decode_rglru, init_rglru, init_rglru_cache,
)


@pytest.fixture(scope="module")
def ssm_cfg():
    return get_arch("mamba2-370m").reduced(d_model=64)


def test_ssd_chunked_matches_sequential_ref():
    rng = np.random.default_rng(0)
    b, T, H, P, S = 2, 96, 3, 8, 16
    x = jnp.asarray(rng.normal(size=(b, T, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, size=(b, T, H)), jnp.float32)
    loga = -dt
    B = jnp.asarray(rng.normal(size=(b, T, S)), jnp.float32) * 0.3
    C = jnp.asarray(rng.normal(size=(b, T, S)), jnp.float32) * 0.3
    y, h = ssd_chunked(x, dt, loga, B, C, chunk=32)
    # sequential oracle via the kernel ref (flatten heads into BH)
    xb = x.transpose(0, 2, 1, 3).reshape(b * H, T, P)
    dtb = dt.transpose(0, 2, 1).reshape(b * H, T)
    lab = loga.transpose(0, 2, 1).reshape(b * H, T)
    Bb = jnp.broadcast_to(B[:, None], (b, H, T, S)).reshape(b * H, T, S)
    Cb = jnp.broadcast_to(C[:, None], (b, H, T, S)).reshape(b * H, T, S)
    yr, hr = kref.ssd_scan_ref(xb, dtb, lab, Bb, Cb)
    yr = yr.reshape(b, H, T, P).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)


def test_ssm_decode_matches_full_forward(ssm_cfg):
    cfg = ssm_cfg
    p_px = init_ssm(jax.random.PRNGKey(0), cfg)
    p, _ = split_tree(p_px)
    rng = np.random.default_rng(1)
    B, T = 2, 12
    x = jnp.asarray(rng.normal(size=(B, T, cfg.d_model)) * 0.3, jnp.float32)
    full = apply_ssm(p, cfg, x)
    cache = init_ssm_cache(cfg, B, jnp.float32)
    outs = []
    for t in range(T):
        y, cache = decode_ssm(p, cfg, x[:, t:t + 1], cache)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=1e-3, atol=1e-4)


def test_rglru_decode_matches_full_forward():
    cfg = get_arch("recurrentgemma-9b").reduced(d_model=64, d_ff=128)
    p_px = init_rglru(jax.random.PRNGKey(0), cfg)
    p, _ = split_tree(p_px)
    rng = np.random.default_rng(2)
    B, T = 2, 10
    x = jnp.asarray(rng.normal(size=(B, T, cfg.d_model)) * 0.3, jnp.float32)
    full = apply_rglru(p, cfg, x)
    cache = init_rglru_cache(cfg, B, jnp.float32)
    outs = []
    for t in range(T):
        y, cache = decode_rglru(p, cfg, x[:, t:t + 1], cache)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=1e-3, atol=1e-4)


def test_rglru_decay_in_unit_interval():
    cfg = get_arch("recurrentgemma-9b").reduced(d_model=32, d_ff=64)
    p_px = init_rglru(jax.random.PRNGKey(0), cfg)
    p, _ = split_tree(p_px)
    from repro.models.rglru import _gates
    xb = jnp.ones((1, 4, cfg.rglru_width or cfg.d_model)) * 0.5
    a, beta = _gates(p, xb)
    assert bool((a > 0).all()) and bool((a < 1).all())
    assert bool((beta >= 0).all())


def test_ssm_gradients_finite(ssm_cfg):
    cfg = ssm_cfg
    p_px = init_ssm(jax.random.PRNGKey(0), cfg)
    p, _ = split_tree(p_px)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model)) * 0.3

    def loss(pp):
        return (apply_ssm(pp, cfg, x) ** 2).mean()

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf).all())
