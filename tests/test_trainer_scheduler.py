"""ScaleTrainer loop + BatchScheduler serving tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core.distributed import TTHFScaleConfig
from repro.models import build_model
from repro.serving.scheduler import BatchScheduler, Request
from repro.train import ScaleTrainer, TrainerConfig


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_arch("qwen1.5-0.5b").reduced(num_layers=2, d_model=128,
                                            d_ff=256, vocab_size=256)


def test_trainer_runs_and_logs(tmp_path, tiny_cfg):
    scale = TTHFScaleConfig(replicas=4, cluster_size=2, tau=4,
                            consensus_every=2, gamma_d2d=2, lr=0.05)
    tcfg = TrainerConfig(batch_per_replica=2, seq_len=32, intervals=3,
                         eval_every=2, eval_batches=1,
                         log_path=str(tmp_path / "metrics.jsonl"))
    tr = ScaleTrainer(tiny_cfg, scale, tcfg).init()
    tr.run()
    assert tr.interval == 3
    # replicas agree after aggregation
    for leaf in jax.tree.leaves(tr.params):
        np.testing.assert_allclose(np.asarray(leaf[0]),
                                   np.asarray(leaf[-1]), atol=1e-5)
    # metric file has 3 records with the ledger fields
    import json
    recs = [json.loads(l) for l in
            (tmp_path / "metrics.jsonl").read_text().splitlines()]
    assert len(recs) == 3
    assert recs[-1]["uplinks"] == 3 * 2       # N clusters per interval
    assert "eval_loss" in recs[1]


def test_trainer_checkpoint_roundtrip(tmp_path, tiny_cfg):
    scale = TTHFScaleConfig(replicas=2, cluster_size=2, tau=2,
                            consensus_every=2, gamma_d2d=1, lr=0.05)
    tcfg = TrainerConfig(batch_per_replica=2, seq_len=16, intervals=2,
                         eval_every=0, ckpt_dir=str(tmp_path))
    tr = ScaleTrainer(tiny_cfg, scale, tcfg).init()
    tr.run(1)
    path = tr.save()
    loss_before = tr.evaluate()
    tr2 = ScaleTrainer(tiny_cfg, scale, tcfg).restore(path)
    assert tr2.interval == 1
    np.testing.assert_allclose(loss_before, tr2.evaluate(), rtol=1e-5)


def test_scheduler_serves_queue(tiny_cfg):
    model = build_model(tiny_cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = BatchScheduler(model, slots=2, max_prompt=16, max_total=32,
                           temperature=0.0)
    rng = np.random.default_rng(0)
    for rid in range(5):
        sched.submit(Request(rid=rid,
                             prompt=rng.integers(
                                 1, 250, size=rng.integers(4, 12)
                             ).astype(np.int32),
                             max_new=4))
    stats = sched.run(params)
    assert stats.requests_done == 5
    assert stats.tokens_generated >= 5 * 4 - 4   # finished slots may idle
    assert stats.prefills >= 3                   # ceil(5/2) waves


def test_scheduler_greedy_matches_direct_decode(tiny_cfg):
    """Single request, temperature 0: scheduler output == direct
    prefill+decode greedy tokens."""
    model = build_model(tiny_cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.arange(1, 9, dtype=np.int32)
    sched = BatchScheduler(model, slots=1, max_prompt=16, max_total=32)
    req = Request(rid=0, prompt=prompt, max_new=5)
    sched.submit(req)
    sched.run(params)

    lg, cache, pos = model.prefill(params, {"tokens": jnp.asarray(
        prompt[None])}, dtype=jnp.float32, cache_dtype=jnp.float32,
        cache_len=32)
    outs = []
    for _ in range(5):
        tok = jnp.argmax(lg[:, -1], axis=-1)[:, None].astype(jnp.int32)
        outs.append(int(tok[0, 0]))
        lg, cache = model.decode_step(params, tok, cache, pos,
                                      dtype=jnp.float32)
        pos = pos + 1
    assert req.out_tokens == outs
