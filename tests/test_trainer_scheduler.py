"""ScaleTrainer loop + BatchScheduler serving tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core.distributed import TTHFScaleConfig
from repro.models import build_model
from repro.serving import BatchScheduler, Request
from repro.train import ScaleTrainer, TrainerConfig


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_arch("qwen1.5-0.5b").reduced(num_layers=2, d_model=128,
                                            d_ff=256, vocab_size=256)


def test_trainer_runs_and_logs(tmp_path, tiny_cfg):
    scale = TTHFScaleConfig(replicas=4, cluster_size=2, tau=4,
                            consensus_every=2, gamma_d2d=2, lr=0.05)
    tcfg = TrainerConfig(batch_per_replica=2, seq_len=32, intervals=3,
                         eval_every=2, eval_batches=1,
                         log_path=str(tmp_path / "metrics.jsonl"))
    tr = ScaleTrainer(tiny_cfg, scale, tcfg).init()
    tr.run()
    assert tr.interval == 3
    # replicas agree after aggregation
    for leaf in jax.tree.leaves(tr.params):
        np.testing.assert_allclose(np.asarray(leaf[0]),
                                   np.asarray(leaf[-1]), atol=1e-5)
    # metric file has 3 records with the ledger fields
    import json
    recs = [json.loads(l) for l in
            (tmp_path / "metrics.jsonl").read_text().splitlines()]
    assert len(recs) == 3
    assert recs[-1]["uplinks"] == 3 * 2       # N clusters per interval
    assert "eval_loss" in recs[1]


def test_trainer_checkpoint_roundtrip(tmp_path, tiny_cfg):
    scale = TTHFScaleConfig(replicas=2, cluster_size=2, tau=2,
                            consensus_every=2, gamma_d2d=1, lr=0.05)
    tcfg = TrainerConfig(batch_per_replica=2, seq_len=16, intervals=2,
                         eval_every=0, ckpt_dir=str(tmp_path))
    tr = ScaleTrainer(tiny_cfg, scale, tcfg).init()
    tr.run(1)
    path = tr.save()
    loss_before = tr.evaluate()
    tr2 = ScaleTrainer(tiny_cfg, scale, tcfg).restore(path)
    assert tr2.interval == 1
    np.testing.assert_allclose(loss_before, tr2.evaluate(), rtol=1e-5)


def test_scheduler_serves_queue(tiny_cfg):
    model = build_model(tiny_cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = BatchScheduler(model, slots=2, max_prompt=16, max_total=32,
                           temperature=0.0)
    rng = np.random.default_rng(0)
    for rid in range(5):
        sched.submit(Request(rid=rid,
                             prompt=rng.integers(
                                 1, 250, size=rng.integers(4, 12)
                             ).astype(np.int32),
                             max_new=4))
    stats = sched.run(params)
    assert stats.requests_done == 5
    assert stats.tokens_generated >= 5 * 4 - 4   # finished slots may idle
    assert stats.prefills >= 3                   # ceil(5/2) waves


def test_scheduler_greedy_matches_direct_decode(tiny_cfg):
    """Single request, temperature 0: scheduler output == direct
    prefill+decode greedy tokens."""
    model = build_model(tiny_cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.arange(1, 9, dtype=np.int32)
    sched = BatchScheduler(model, slots=1, max_prompt=16, max_total=32)
    req = Request(rid=0, prompt=prompt, max_new=5)
    sched.submit(req)
    sched.run(params)

    lg, cache, pos = model.prefill(params, {"tokens": jnp.asarray(
        prompt[None])}, dtype=jnp.float32, cache_dtype=jnp.float32,
        cache_len=32)
    outs = []
    for _ in range(5):
        tok = jnp.argmax(lg[:, -1], axis=-1)[:, None].astype(jnp.int32)
        outs.append(int(tok[0, 0]))
        lg, cache = model.decode_step(params, tok, cache, pos,
                                      dtype=jnp.float32)
        pos = pos + 1
    assert req.out_tokens == outs


# ---------------------------------------------------------------------------
# mixed-length waves, per-slot retirement, continuous batching
# ---------------------------------------------------------------------------

def _solo_greedy(model, params, prompt, n, max_total=32):
    lg, cache, pos = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, dtype=jnp.float32,
        cache_dtype=jnp.float32, cache_len=max_total)
    outs = []
    for _ in range(n):
        tok = jnp.argmax(lg[:, -1], axis=-1)[:, None].astype(jnp.int32)
        outs.append(int(tok[0, 0]))
        lg, cache = model.decode_step(params, tok, cache, pos,
                                      dtype=jnp.float32)
        pos = pos + 1
    return outs


def test_wave_mixed_lengths_match_solo(tiny_cfg):
    """The wave-prefill padding bugfix: short prompts batched with long
    ones must produce exactly their solo greedy continuations."""
    model = build_model(tiny_cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, 250, size=n).astype(np.int32)
               for n in (4, 9, 13)]
    sched = BatchScheduler(model, slots=3, max_prompt=16, max_total=32)
    reqs = [Request(rid=i, prompt=p, max_new=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        sched.submit(r)
    sched.run(params)
    for r in reqs:
        assert r.out_tokens == _solo_greedy(model, params, r.prompt, 5), \
            f"request {r.rid} diverged from its solo decode"


def test_wave_no_shared_pos_early_retirement(tiny_cfg):
    """The shared-pos bugfix: a short prompt batched with a long one
    gets its full max_new budget (previously it was retired when the
    shared absolute position hit max_total)."""
    model = build_model(tiny_cfg)
    params = model.init(jax.random.PRNGKey(0))
    short = np.arange(1, 4, dtype=np.int32)           # 3 tokens
    long = np.arange(1, 15, dtype=np.int32)           # 14 tokens
    sched = BatchScheduler(model, slots=2, max_prompt=14, max_total=20)
    reqs = [Request(rid=0, prompt=short, max_new=8),
            Request(rid=1, prompt=long, max_new=6)]
    for r in reqs:
        sched.submit(r)
    sched.run(params)
    # short request: 8 tokens (old scheduler stopped at 20 - 14 = 6);
    # long request: min(6, 20 - 14) = 6 tokens
    assert len(reqs[0].out_tokens) == 8
    assert len(reqs[1].out_tokens) == 6
    assert reqs[0].out_tokens == _solo_greedy(model, params, short, 8,
                                              max_total=20)


def test_continuous_matches_wave_and_solo(tiny_cfg):
    """Both schedulers emit identical greedy tokens per request, each
    equal to the request's solo decode."""
    from repro.serving import ContinuousScheduler
    model = build_model(tiny_cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    protos = [(rng.integers(1, 250, size=int(rng.integers(3, 13))
                            ).astype(np.int32), int(rng.integers(3, 7)))
              for _ in range(6)]
    outs = {}
    for cls in (BatchScheduler, ContinuousScheduler):
        sched = cls(model, slots=2, max_prompt=16, max_total=32)
        reqs = [Request(rid=i, prompt=p, max_new=n)
                for i, (p, n) in enumerate(protos)]
        for r in reqs:
            sched.submit(r)
        stats = sched.run(params)
        assert stats.requests_done == len(protos)
        outs[cls.__name__] = {r.rid: r.out_tokens for r in reqs}
    assert outs["BatchScheduler"] == outs["ContinuousScheduler"]
    for (p, n), (rid, toks) in zip(protos,
                                   sorted(outs["BatchScheduler"].items())):
        assert toks == _solo_greedy(model, params, p, n)


def test_continuous_staggered_admission_beats_wave(tiny_cfg):
    """Heterogeneous budgets: the continuous scheduler refills retired
    slots mid-flight (prefills > waves, decode steps strictly fewer,
    higher utilization), still bit-equal to solo decode."""
    from repro.serving import ContinuousScheduler
    model = build_model(tiny_cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    # alternating tiny/large budgets force wave slots to idle
    protos = [(rng.integers(1, 250, size=6).astype(np.int32),
               2 if i % 2 else 10) for i in range(6)]

    def run(cls):
        sched = cls(model, slots=2, max_prompt=8, max_total=32)
        reqs = [Request(rid=i, prompt=p, max_new=n)
                for i, (p, n) in enumerate(protos)]
        for r in reqs:
            sched.submit(r)
        return sched.run(params), reqs

    wave_stats, _ = run(BatchScheduler)
    cont_stats, cont_reqs = run(ContinuousScheduler)
    assert cont_stats.requests_done == len(protos)
    assert cont_stats.prefills == len(protos)      # one per admission
    assert cont_stats.decode_steps < wave_stats.decode_steps
    assert cont_stats.utilization > wave_stats.utilization
    for r in cont_reqs:
        assert r.out_tokens == _solo_greedy(model, params, r.prompt,
                                            r.max_new)


def test_sample_tokens_dtype_stable(tiny_cfg):
    """The shared sampler returns int32 on BOTH paths (the temperature
    path previously leaked categorical's default integer dtype into the
    decode jit signature)."""
    from repro.serving import sample_tokens
    logits = jnp.zeros((2, 1, 16), jnp.float32)
    greedy = sample_tokens(logits)
    temp = sample_tokens(logits, temperature=0.7,
                         key=jax.random.PRNGKey(0))
    assert greedy.dtype == jnp.int32 and greedy.shape == (2, 1)
    assert temp.dtype == jnp.int32 and temp.shape == (2, 1)
    with pytest.raises(ValueError):
        sample_tokens(logits, temperature=0.5)


def test_scheduler_single_jit_signature(tiny_cfg):
    """Mixed prompt lengths across waves reuse ONE prefill/decode trace
    (prompts are padded to max_prompt with a lengths vector)."""
    model = build_model(tiny_cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = BatchScheduler(model, slots=2, max_prompt=16, max_total=32)
    rng = np.random.default_rng(6)
    for rid in range(4):
        sched.submit(Request(rid=rid,
                             prompt=rng.integers(
                                 1, 250, size=rng.integers(2, 16)
                             ).astype(np.int32), max_new=3))
    sched.run(params)
    assert sched.stats.prefills >= 2                # several waves ran
    assert sched._prefill._cache_size() == 1        # one trace
    assert sched._decode._cache_size() == 1


def test_zero_budget_request_emits_nothing(tiny_cfg):
    """A prompt that already fills the cache (budget 0) completes with
    zero tokens instead of leaking one, in both schedulers; run() warns
    instead of silently truncating at max_steps."""
    from repro.serving import ContinuousScheduler
    model = build_model(tiny_cfg)
    params = model.init(jax.random.PRNGKey(0))
    full = np.arange(1, 17, dtype=np.int32)            # 16 == max_total
    short = np.arange(1, 5, dtype=np.int32)
    for cls in (BatchScheduler, ContinuousScheduler):
        sched = cls(model, slots=2, max_prompt=16, max_total=16)
        reqs = [Request(rid=0, prompt=full, max_new=4),
                Request(rid=1, prompt=short, max_new=4)]
        for r in reqs:
            sched.submit(r)
        stats = sched.run(params)
        assert reqs[0].done and reqs[0].out_tokens == []
        assert len(reqs[1].out_tokens) == 4
        assert stats.requests_done == 2

    sched = BatchScheduler(model, slots=1, max_prompt=8, max_total=16)
    sched.submit(Request(rid=0, prompt=short, max_new=8))
    with pytest.warns(RuntimeWarning, match="max_steps"):
        sched.run(params, max_steps=2)
