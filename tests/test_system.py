"""End-to-end behaviour tests for the whole system (Algorithm 1 +
baselines + ledger accounting + the energy/delay model)."""
import dataclasses

import numpy as np
import pytest

from repro.configs import TopologyConfig, TTHFConfig
from repro.core import CommLedger, TTHFTrainer, make_baseline_config
from repro.data import fashion_synth, partition_noniid_labels
from repro.models import make_sim_model


@pytest.fixture(scope="module")
def small_world():
    x, y = fashion_synth(num_points=2000, seed=0)
    data = partition_noniid_labels(x, y, num_devices=20)
    topo = TopologyConfig(num_devices=20, num_clusters=4,
                          graph="geometric", seed=0)
    model = make_sim_model("svm", 784, 10)
    return data, topo, model


def test_algorithm1_end_to_end(small_world):
    data, topo, model = small_world
    algo = TTHFConfig(tau=10, consensus_every=5, gamma_d2d=2,
                      constant_lr=0.002)
    tr = TTHFTrainer(model, data, topo, algo, batch_size=8)
    st, hist = tr.run(steps=50, eval_every=10)
    assert st.t == 50
    assert hist.global_loss[-1] < hist.global_loss[0]
    assert hist.global_acc[-1] > 0.15
    # ledger: 5 aggregations, cluster-sampled -> 4 uplinks each
    assert tr.ledger.uplinks == 5 * 4
    assert tr.ledger.d2d_msgs > 0
    assert tr.ledger.local_steps == 50 * 20


def test_baseline_full_participation_uplinks(small_world):
    data, topo, model = small_world
    algo = dataclasses.replace(make_baseline_config("fedavg", 10),
                               constant_lr=0.002)
    tr = TTHFTrainer(model, data, topo, algo, batch_size=8)
    tr.run(steps=30, eval_every=10)
    assert tr.ledger.uplinks == 20 * 3     # full participation
    assert tr.ledger.d2d_msgs == 0


def test_nn_model_trains(small_world):
    data, topo, _ = small_world
    model = make_sim_model("nn", 784, 10, hidden=32)
    algo = TTHFConfig(tau=10, consensus_every=5, gamma_d2d=2,
                      constant_lr=0.05)
    tr = TTHFTrainer(model, data, topo, algo, batch_size=8)
    _, hist = tr.run(steps=40, eval_every=10)
    assert hist.global_loss[-1] < hist.global_loss[0]


def test_adaptive_gamma_runs(small_world):
    data, topo, model = small_world
    algo = TTHFConfig(tau=10, consensus_every=5, gamma_d2d=-1, phi=0.5,
                      gamma=40.0, alpha=400.0)
    tr = TTHFTrainer(model, data, topo, algo, batch_size=8)
    _, hist = tr.run(steps=30, eval_every=5)
    gammas = np.stack(hist.gamma_used)
    assert gammas.max() > 0
    assert gammas.max() <= 64


def test_energy_delay_tradeoff(small_world):
    """Fig. 6 mechanics: TT-HF wins on energy for small E_D2D/E_Glob and
    the advantage shrinks as the ratio grows."""
    data, topo, model = small_world
    lr = 0.002
    tthf = TTHFConfig(tau=10, consensus_every=5, gamma_d2d=2,
                      constant_lr=lr)
    fed = dataclasses.replace(make_baseline_config("fedavg", 1),
                              constant_lr=lr)
    tr1 = TTHFTrainer(model, data, topo, tthf, batch_size=8)
    tr1.run(steps=30, eval_every=30)
    tr2 = TTHFTrainer(model, data, topo, fed, batch_size=8)
    tr2.run(steps=30, eval_every=30)
    assert tr1.ledger.energy(0.01) < tr2.ledger.energy(0.01)
    gap_cheap = tr2.ledger.energy(0.01) - tr1.ledger.energy(0.01)
    gap_pricey = tr2.ledger.energy(1.0) - tr1.ledger.energy(1.0)
    assert gap_pricey < gap_cheap


def test_checkpointing_roundtrip(tmp_path, small_world):
    data, topo, model = small_world
    algo = TTHFConfig(tau=10, consensus_every=5, gamma_d2d=1,
                      constant_lr=0.002)
    tr = TTHFTrainer(model, data, topo, algo, batch_size=8)
    st, _ = tr.run(steps=10, eval_every=10)
    from repro.checkpoint import restore_pytree, save_pytree
    f = str(tmp_path / "state.npz")
    save_pytree(f, {"params": st.params, "global": st.global_params})
    loaded = restore_pytree(f)
    np.testing.assert_allclose(np.asarray(loaded["global"]["w"]),
                               np.asarray(st.global_params["w"]))


def test_cli_train_sim_smoke(capsys):
    from repro.launch.train import main
    rc = main(["--mode", "sim", "--devices", "10", "--clusters", "2",
               "--points", "1000", "--steps", "20", "--tau", "10",
               "--lr", "0.002", "--eval-every", "10"])
    assert rc == 0
    assert "final_loss" in capsys.readouterr().out


def test_cli_serve_smoke(capsys):
    from repro.launch.serve import main
    rc = main(["--arch", "qwen1.5-0.5b", "--reduced", "--batch", "2",
               "--prompt-len", "16", "--gen", "4"])
    assert rc == 0
    assert "tok/s" in capsys.readouterr().out
