"""Round-program engine parity (DESIGN.md §10).

The unified resolver-driven loops must reproduce the pre-refactor
per-scenario trainers BIT-FOR-BIT. The reference runners below are
line-by-line transcriptions of the deleted loops (``TTHFTrainer.run``
/ ``_run_dynamic`` / ``_run_hierarchical`` and the ``ScaleTrainer``
static/dynamic/hierarchical intervals, at commit 08ac903), driving the
current trainers' unchanged jitted pieces — so any drift in the key
schedule, host RNG seeding, operator order, or ledger arithmetic shows
up as exact-inequality here.

Grid: 2 execution modes x {static, churn, stragglers, fog3,
fog3 + churn}; plus resolver/Billing unit tests (the ledger totals the
engine charges are the historical numbers) and the event-chunked-scan
invariance (chunked == per-iteration dispatch, bitwise).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TopologyConfig, TTHFConfig
from repro.core import TTHFTrainer
from repro.core.energy import CommLedger
from repro.data import fashion_synth, partition_noniid_labels
from repro.hierarchy import build_event, presets
from repro.models import make_sim_model
from repro.netsim import scenarios
from repro.rounds import Billing, RoundProgram, RoundResolver

LEDGER_FIELDS = ("uplinks", "broadcasts", "d2d_msgs", "d2d_rounds",
                 "local_steps", "straggler_uplink_extra",
                 "straggler_round_extra", "uplinks_by_level")


def ledgers_equal(a: CommLedger, b: CommLedger) -> bool:
    return all(getattr(a, f) == getattr(b, f) for f in LEDGER_FIELDS)


def leaves_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ===========================================================================
# simulation mode: legacy per-scenario loops, transcribed
# ===========================================================================

def _legacy_consensus_event_static(tr, st, eta_t):
    from repro.core.schedule import adaptive_gamma, fixed_gamma
    algo = tr.algo
    if algo.gamma_d2d >= 0:
        gamma = fixed_gamma(tr.net.num_clusters, algo.gamma_d2d)
    else:
        ups = tr._upsilon(st.params)
        gamma = adaptive_gamma(eta_t, algo.phi, ups, tr.lambdas,
                               tr.net.cluster_size, tr.model_dim)
    st.params = tr._consensus(st.params, gamma)
    gamma_used = np.asarray(gamma)
    tr.ledger.record_consensus(gamma_used, tr._edges)
    return gamma_used


def _legacy_consensus_event_dynamic(tr, st, snap, eta_t, up):
    from repro.core.schedule import adaptive_gamma, fixed_gamma
    from repro.netsim import faults
    algo = tr.algo
    if algo.gamma_d2d >= 0:
        gamma = fixed_gamma(tr.net.num_clusters, algo.gamma_d2d)
    else:
        ups = tr._upsilon_dyn(st.params, up)
        gamma = adaptive_gamma(
            eta_t, algo.phi, ups, jnp.asarray(snap.lambdas, jnp.float32),
            jnp.asarray(snap.active_per_cluster, jnp.int32), tr.model_dim)
    gamma = jnp.where(jnp.asarray(snap.num_active_edges()) == 0, 0, gamma)
    st.params = tr._consensus_dyn(st.params, jnp.asarray(snap.V), gamma)
    gamma_used = np.asarray(gamma)
    tr.ledger.record_consensus(
        gamma_used, snap.num_active_edges(),
        tail_mult_per_cluster=faults.consensus_tail_mult(
            snap.delay_mult, snap.device_up, snap.adj))
    return gamma_used


def legacy_sim_run(tr, steps, seed=0, eval_every=5):
    """The pre-engine ``run``/``_run_dynamic``/``_run_hierarchical``
    dispatch, verbatim, on a fresh trainer (its resolver untouched —
    only the jitted pieces, tvnet/tree, and the ledger are used)."""
    from repro.netsim import faults

    st = tr.init(seed)
    hist = {"loss": [], "acc": [], "disp": [], "gamma": [], "uplinks": [],
            "active": []}
    algo = tr.algo
    N, s = tr.net.num_clusters, tr.net.cluster_size

    for t in range(st.t + 1, st.t + steps + 1):
        eta_t = tr.eta(t - 1)
        st.key, k_step, k_agg = jax.random.split(st.key, 3)
        snap = tr.tvnet.snapshot(t) if tr.tvnet is not None else None
        if snap is None:
            st.params = tr._local_step(st.params, k_step, eta_t)
            tr.ledger.record_local_step(tr.data.num_devices)
        else:
            up = jnp.asarray(snap.device_up)
            st.params = tr._local_step_dyn(st.params, k_step, eta_t,
                                           up.reshape(-1))
            tr.ledger.record_local_step(int(snap.device_up.sum()))

        gamma_used = np.zeros((N,), np.int32)
        if algo.is_consensus_step(t):
            if snap is None:
                gamma_used = _legacy_consensus_event_static(tr, st, eta_t)
            else:
                gamma_used = _legacy_consensus_event_dynamic(
                    tr, st, snap, eta_t, up)

        if algo.is_aggregation_step(t):
            if tr.tree is not None:
                rng = np.random.default_rng(
                    int(jax.random.randint(k_agg, (), 0, 2**31 - 1)))
                device_up = (snap.device_up if snap is not None
                             else np.ones((N, s), bool))
                ev = build_event(rng, tr.tree, tr.hierarchy, t, device_up,
                                 receive_offline=False)
                if ev is not None and ev.total_uplinks > 0:
                    if ev.global_weights is not None:
                        st.global_params = tr._global_from_weights(
                            st.params, jnp.asarray(ev.global_weights))
                    st.params = tr._apply_event(
                        st.params, jnp.asarray(ev.device_matrix))
                    tr.ledger.record_hierarchy_event(
                        ev.uplinks_by_level,
                        uplink_delay_mults=(faults.uplink_tail_mults(
                            snap.delay_mult, ev.picks, ev.counts)
                            if snap is not None else None))
            elif snap is None:
                full = algo.full_participation or algo.mode != "tthf"
                g, st.params = tr._aggregate(st.params, k_agg, full=full)
                st.global_params = g
                n_up = (tr.data.num_devices if full
                        else N * algo.sample_per_cluster)
                tr.ledger.record_aggregation(n_up)
            else:
                full = algo.full_participation or algo.mode != "tthf"
                if full:
                    weights = faults.full_participation_weights(
                        snap.device_up, np.asarray(tr.net.varrho))
                    n_up = int(snap.device_up.sum())
                    mults = snap.delay_mult[snap.device_up]
                else:
                    rng = np.random.default_rng(
                        int(jax.random.randint(k_agg, (), 0, 2**31 - 1)))
                    picks, counts = faults.availability_sample(
                        rng, snap.device_up, k=algo.sample_per_cluster)
                    weights = faults.aggregation_weights(
                        picks, counts, snap.varrho, s)
                    n_up = int(counts.sum())
                    mults = faults.uplink_tail_mults(
                        snap.delay_mult, picks, counts)
                if n_up > 0:
                    g, st.params = tr._aggregate_dyn(
                        st.params, jnp.asarray(weights, jnp.float32),
                        jnp.asarray(snap.device_up).reshape(-1))
                    st.global_params = g
                    tr.ledger.record_aggregation(
                        n_up, uplink_delay_mults=mults)

        if t % eval_every == 0 or t == st.t + steps:
            loss, acc = tr._eval(st.global_params)
            hist["loss"].append(float(loss))
            hist["acc"].append(float(acc))
            hist["disp"].append(float(tr._dispersion(st.params)))
            hist["gamma"].append(gamma_used.copy())
            hist["uplinks"].append(tr.ledger.uplinks)
            hist["active"].append(int(snap.device_up.sum())
                                  if snap is not None
                                  else tr.data.num_devices)
    st.t += steps
    return st, hist


@pytest.fixture(scope="module")
def sim_data():
    x, y = fashion_synth(num_points=800, seed=0)
    return x, y


def _sim_world(sim_data, devices, clusters):
    x, y = sim_data
    data = partition_noniid_labels(x, y, num_devices=devices)
    topo = TopologyConfig(num_devices=devices, num_clusters=clusters,
                          graph="geometric", seed=0)
    model = make_sim_model("svm", 784, 10)
    return data, topo, model


ALGO10 = TTHFConfig(tau=10, consensus_every=5, gamma_d2d=2,
                    constant_lr=0.002)
ALGO5 = TTHFConfig(tau=5, consensus_every=5, gamma_d2d=2,
                   constant_lr=0.002)

SIM_GRID = {
    "static": dict(algo=ALGO10, world=(20, 4)),
    "churn": dict(algo=ALGO10, world=(20, 4),
                  dyn=("device_churn", 1)),
    "stragglers": dict(algo=ALGO10, world=(20, 4),
                       dyn=("stragglers", 1)),
    "fog3": dict(algo=ALGO5, world=(24, 8), hier="fog3"),
    "fog3_churn": dict(algo=ALGO5, world=(24, 8), hier="fog3",
                       dyn=("device_churn", 2)),
    # the adaptive Remark-1 gamma path must survive the merge too
    "churn_adaptive": dict(
        algo=TTHFConfig(tau=10, consensus_every=5, gamma_d2d=-1,
                        phi=1.0, constant_lr=0.002),
        world=(20, 4), dyn=("markov_links", 1)),
}


def _sim_trainer(sim_data, case):
    data, topo, model = _sim_world(sim_data, *case["world"])
    dyn = (scenarios.get(case["dyn"][0], seed=case["dyn"][1])
           if "dyn" in case else None)
    hier = (presets.get(case["hier"], tau=case["algo"].tau)
            if "hier" in case else None)
    return TTHFTrainer(model, data, topo, case["algo"], batch_size=8,
                       dynamics=dyn, hierarchy=hier)


@pytest.mark.parametrize("name", sorted(SIM_GRID))
def test_sim_parity_bit_for_bit(sim_data, name):
    case = SIM_GRID[name]
    steps = 20

    ref = _sim_trainer(sim_data, case)
    st_ref, h_ref = legacy_sim_run(ref, steps=steps, seed=0)

    new = _sim_trainer(sim_data, case)
    st_new, h_new = new.run(steps=steps, eval_every=5, seed=0)

    assert h_ref["loss"] == h_new.global_loss        # exact float equality
    assert h_ref["acc"] == h_new.global_acc
    assert h_ref["disp"] == h_new.dispersion
    assert h_ref["uplinks"] == h_new.uplinks
    assert h_ref["active"] == h_new.active_devices
    assert all(np.array_equal(a, b)
               for a, b in zip(h_ref["gamma"], h_new.gamma_used))
    assert leaves_equal(st_ref.params, st_new.params)
    assert leaves_equal(st_ref.global_params, st_new.global_params)
    assert ledgers_equal(ref.ledger, new.ledger)


def test_scanned_spans_match_per_iteration_dispatch(sim_data):
    """chunked=True (one lax.scan per inter-event span) and
    chunked=False (one dispatch per iteration — the historical cadence)
    must be bitwise interchangeable."""
    for case in (SIM_GRID["static"], SIM_GRID["churn"]):
        a = _sim_trainer(sim_data, case)
        _, ha = a.run(steps=15, eval_every=5, seed=0)
        b = _sim_trainer(sim_data, case)
        b.chunked = False
        _, hb = b.run(steps=15, eval_every=5, seed=0)
        assert ha.global_loss == hb.global_loss
        assert ha.dispersion == hb.dispersion
        assert ledgers_equal(a.ledger, b.ledger)


# ===========================================================================
# scale mode: legacy interval loops, transcribed
# ===========================================================================

def legacy_scale_run(tr, intervals):
    """The pre-engine ``ScaleTrainer.run`` three-way interval dispatch,
    verbatim, driving the current trainer's step/batch/key plumbing."""
    from repro.core.mixing import refresh_matrices
    from repro.netsim import faults

    def record_interval_comms(snap, events):
        gammas = np.where(snap.num_active_edges() > 0,
                          tr.scale.gamma_d2d, 0)
        tr.ledger.record_consensus(
            list(gammas) * events,
            list(snap.num_active_edges()) * events,
            tail_mult_per_cluster=list(faults.consensus_tail_mult(
                snap.delay_mult, snap.device_up, snap.adj)) * events)
        tr.ledger.record_local_step(
            int(snap.device_up.sum()) * tr.scale.tau)

    if tr.params is None:
        tr.init()
    events = (tr.scale.tau // tr.scale.consensus_every
              if tr.scale.consensus_every else 0)
    for _ in range(intervals):
        batch = tr._interval_batch()
        tr.key, kp = jax.random.split(tr.key)
        if tr.tree is not None:
            snap = refresh = None
            if tr.tvnet is not None:
                snap = tr.tvnet.snapshot(tr.interval + 1)
                refresh = (refresh_matrices(tr._plan, snap.V)
                           if tr._plan is not None else None)
                device_up = snap.device_up
            else:
                device_up = np.ones((tr.scale.num_clusters,
                                     tr.scale.cluster_size), bool)
            rng = np.random.default_rng(
                int(jax.random.randint(kp, (), 0, 2**31 - 1)))
            ev = build_event(rng, tr.tree, tr.hierarchy,
                             (tr.interval + 1) * tr.scale.tau, device_up,
                             receive_offline=True)
            args = (tr.params, batch, jnp.asarray(ev.device_matrix),
                    jnp.asarray(tr.interval))
            if refresh is not None:
                tr.params, _ = tr._step(*args, refresh)
            else:
                tr.params, _ = tr._step(*args)
            if ev.global_weights is not None and ev.total_uplinks:
                tr._global = jax.tree.map(lambda l: l[0], tr.params)
            if ev.total_uplinks:
                tr.ledger.record_hierarchy_event(
                    ev.uplinks_by_level,
                    uplink_delay_mults=(faults.uplink_tail_mults(
                        snap.delay_mult, ev.picks, ev.counts)
                        if snap is not None else None))
            if snap is not None:
                record_interval_comms(snap, events)
            else:
                tr.ledger.record_consensus(
                    [tr.scale.gamma_d2d] * tr.net.num_clusters * events,
                    list(tr.net.num_d2d_edges()) * events)
                tr.ledger.record_local_step(
                    tr.scale.replicas * tr.scale.tau)
        elif tr.tvnet is None:
            picks = jax.random.randint(
                kp, (tr.net.num_clusters,), 0, tr.scale.cluster_size)
            tr.params, _ = tr._step(tr.params, batch, picks,
                                    jnp.asarray(tr.interval))
            tr.ledger.record_aggregation(tr.net.num_clusters)
            tr.ledger.record_consensus(
                [tr.scale.gamma_d2d] * tr.net.num_clusters * events,
                list(tr.net.num_d2d_edges()) * events)
            tr.ledger.record_local_step(tr.scale.replicas * tr.scale.tau)
        else:
            snap = tr.tvnet.snapshot(tr.interval + 1)
            refresh = (refresh_matrices(tr._plan, snap.V)
                       if tr._plan is not None else None)
            rng = np.random.default_rng(
                int(jax.random.randint(kp, (), 0, 2**31 - 1)))
            picks_np, counts = faults.availability_sample(
                rng, snap.device_up, k=tr.scale.sample_per_cluster)
            if refresh is not None:
                agg_w = jnp.asarray(faults.aggregation_weights(
                    picks_np, counts, snap.varrho,
                    tr.scale.cluster_size), jnp.float32)
                tr.params, _ = tr._step(tr.params, batch, agg_w,
                                        jnp.asarray(tr.interval), refresh)
            else:
                picks = jnp.asarray(
                    np.where(counts > 0, picks_np[:, 0], 0), jnp.int32)
                tr.params, _ = tr._step(tr.params, batch, picks,
                                        jnp.asarray(tr.interval))
            tr.ledger.record_aggregation(
                int(counts.sum()),
                uplink_delay_mults=faults.uplink_tail_mults(
                    snap.delay_mult, picks_np, counts))
            record_interval_comms(snap, events)
        tr.interval += 1
    return tr


@pytest.fixture(scope="module")
def scale_world():
    from repro.configs import get_arch
    from repro.core.distributed import TTHFScaleConfig
    from repro.train import TrainerConfig
    cfg = get_arch("qwen1.5-0.5b").reduced(num_layers=2, d_model=64,
                                           d_ff=128, vocab_size=128)
    scale = TTHFScaleConfig(replicas=8, cluster_size=2, tau=2,
                            consensus_every=2, gamma_d2d=2, lr=0.05)
    tcfg = TrainerConfig(batch_per_replica=2, seq_len=16, intervals=3,
                         eval_every=0, eval_batches=1)
    return cfg, scale, tcfg


SCALE_GRID = {
    "static": dict(),
    "churn": dict(dyn=("device_churn", 2)),
    "stragglers": dict(dyn=("stragglers", 1)),
    "fog3": dict(hier="fog3"),
    "fog3_churn": dict(hier="fog3", dyn=("device_churn", 3)),
}


def _scale_trainer(scale_world, case):
    from repro.train import ScaleTrainer
    cfg, scale, tcfg = scale_world
    dyn = (scenarios.get(case["dyn"][0], seed=case["dyn"][1])
           if "dyn" in case else None)
    hier = (presets.get(case["hier"], tau=scale.tau)
            if "hier" in case else None)
    return ScaleTrainer(cfg, scale, tcfg, dynamics=dyn, hierarchy=hier)


@pytest.mark.parametrize("name", sorted(SCALE_GRID))
def test_scale_parity_bit_for_bit(scale_world, name):
    case = SCALE_GRID[name]
    ref = legacy_scale_run(_scale_trainer(scale_world, case).init(), 3)
    new = _scale_trainer(scale_world, case).init()
    new.run(3)
    assert leaves_equal(ref.params, new.params)
    assert leaves_equal(ref._global_params(), new._global_params())
    assert ledgers_equal(ref.ledger, new.ledger)


def test_scale_static_multi_sampling_bills_real_uplinks(scale_world):
    """sample_per_cluster = k > 1 on the STATIC path: all k picks enter
    the aggregate through the (N, s) weight form, the ledger bills
    N * k real uplinks (it used to draw one device and bill N), and the
    broadcast still syncs every replica."""
    import dataclasses as dc
    from repro.train import ScaleTrainer
    cfg, scale, tcfg = scale_world
    k = 2
    tr = ScaleTrainer(cfg, dc.replace(scale, sample_per_cluster=k),
                      tcfg).init()
    tr.run(3)
    assert tr.ledger.uplinks == 3 * scale.num_clusters * k
    assert tr.ledger.uplinks_by_level == {1: 3 * scale.num_clusters * k}
    for leaf in jax.tree.leaves(tr.params):
        arr = np.asarray(leaf)
        assert np.isfinite(arr).all()
        np.testing.assert_allclose(
            arr, np.broadcast_to(arr[0:1], arr.shape), atol=1e-6)


# ===========================================================================
# resolver / Billing unit tests: the charged totals are the ledger's
# historical numbers
# ===========================================================================

def _pricing_state(led):
    """Everything pricing reads: the counters, minus the DESIGN.md §13
    attribution bookkeeping (`events` rows + event cursor), which
    legitimately differs between a direct record_* call and a
    Billing.charge (charge opens its own attribution event; repeats
    replay per repeat). attribution_totals() must still agree."""
    d = dataclasses.asdict(led)
    d.pop("events")
    d.pop("_event_idx")
    return d


def test_billing_flat_aggregation_matches_record_aggregation():
    a, b = CommLedger(), CommLedger()
    a.record_aggregation(7, uplink_delay_mults=[2.0, 1.0])
    Billing(uplinks_by_level={1: 7},
            uplink_delay_mults=np.asarray([2.0, 1.0])).charge(b)
    assert _pricing_state(a) == _pricing_state(b)
    assert a.attribution_totals() == b.attribution_totals()


def test_billing_consensus_repeats_match_interval_lists():
    gammas, edges, tail = [2, 0, 2], [3, 0, 1], [1.5, 1.0, 1.0]
    a, b = CommLedger(), CommLedger()
    a.record_consensus(gammas * 4, edges * 4,
                       tail_mult_per_cluster=tail * 4)
    Billing(consensus_gammas=np.asarray(gammas),
            consensus_edges=np.asarray(edges),
            consensus_tail=np.asarray(tail),
            consensus_repeats=4).charge(b)
    assert _pricing_state(a) == _pricing_state(b)
    totals_a, totals_b = a.attribution_totals(), b.attribution_totals()
    assert totals_a == totals_b
    # the replay's whole point: b keeps real cluster indices {0, 2}
    assert set(b.d2d_by_cluster()) == {0, 2}


def test_billing_runtime_gamma_and_skip_semantics():
    a, b = CommLedger(), CommLedger()
    a.record_consensus([1, 3], [2, 2])
    Billing(consensus_edges=np.asarray([2, 2])).charge(
        b, gamma_used=np.asarray([1, 3]))
    assert _pricing_state(a) == _pricing_state(b)
    assert a.attribution_totals() == b.attribution_totals()
    # nothing transmitted: no uplinks AND no broadcast
    c = CommLedger()
    Billing(uplinks_by_level=None).charge(c, gamma_used=np.zeros(2))
    assert c.uplinks == 0 and c.broadcasts == 0
    # a transmitted-but-empty aggregation (scale all-dark) still
    # broadcasts — the historical record_aggregation(0) semantics
    d = CommLedger()
    Billing(uplinks_by_level={1: 0}).charge(d)
    assert d.uplinks == 0 and d.broadcasts == 1


def test_sim_resolver_static_billing_totals(sim_data):
    """One tau of the static program charges exactly the historical
    ledger: I local device-steps per iteration, N*k uplinks + one
    broadcast per aggregation, Gamma rounds (2 x edges msgs) per
    consensus event."""
    tr = _sim_trainer(sim_data, SIM_GRID["static"])
    _, _ = tr.run(steps=10, eval_every=5, seed=0)
    N = tr.net.num_clusters
    assert tr.ledger.local_steps == 10 * tr.data.num_devices
    assert tr.ledger.uplinks == N * tr.algo.sample_per_cluster
    assert tr.ledger.broadcasts == 1
    assert tr.ledger.d2d_rounds == 2 * N * tr.algo.gamma_d2d
    assert tr.ledger.d2d_msgs == sum(
        2 * tr.algo.gamma_d2d * 2 * int(e) for e in tr.net.num_d2d_edges())


def test_resolver_span_end_knows_the_calendar(sim_data):
    data, topo, model = _sim_world(sim_data, 20, 4)
    tr = TTHFTrainer(model, data, topo, ALGO10, batch_size=8)
    res = tr._resolver
    # consensus every 5, aggregation every 10, eval every 20
    assert res.span_end(1, 100, 20) == 5
    assert res.span_end(6, 100, 20) == 10
    assert res.span_end(11, 100, 20) == 15
    assert res.span_end(16, 100, 20) == 20
    # t_last is always a boundary even off-calendar
    assert res.span_end(21, 23, 100) == 23


def test_round_program_flat_static_is_identity(sim_data):
    """A static-dynamics + flat-hierarchy program IS the bare paper
    setting: no tvnet, no tree, and the trainer takes the historical
    static path bit-for-bit."""
    data, topo, model = _sim_world(sim_data, 20, 4)
    prog = RoundProgram(dynamics=scenarios.get("static"),
                        hierarchy=presets.get("flat", tau=10))
    assert not prog.is_dynamic and not prog.is_hierarchical
    tr0 = TTHFTrainer(model, data, topo, ALGO10, batch_size=8)
    _, h0 = tr0.run(steps=10, eval_every=5, seed=0)
    tr1 = TTHFTrainer(model, data, topo, ALGO10, batch_size=8,
                      program=prog)
    assert tr1.tvnet is None and tr1.tree is None
    _, h1 = tr1.run(steps=10, eval_every=5, seed=0)
    assert h0.global_loss == h1.global_loss
    assert ledgers_equal(tr0.ledger, tr1.ledger)
