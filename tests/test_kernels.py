"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes and
dtypes (interpret mode on CPU — the kernel body itself executes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.topology import metropolis_weights, ring_adjacency, \
    geometric_adjacency
from repro.kernels import ops, ref


def _V(N, s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        np.stack([metropolis_weights(geometric_adjacency(s, 0.9, rng))
                  for _ in range(N)]), jnp.float32)


# ---------------------------------------------------------------------------
# consensus_mix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,s,M", [(1, 2, 8), (3, 5, 100), (4, 8, 700),
                                   (2, 5, 513), (25, 5, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_consensus_mix_shapes(N, s, M, dtype):
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=(N, s, M)), dtype)
    V = _V(N, s)
    gamma = jnp.asarray(rng.integers(0, 6, size=(N,)), jnp.int32)
    out = ops.consensus_mix(z, V, gamma)
    expect = ref.consensus_mix_ref(z, V, gamma)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)


@given(gamma=st.integers(0, 8), blk=st.sampled_from([64, 128, 512]),
       seed=st.integers(0, 20))
@settings(max_examples=12, deadline=None)
def test_consensus_mix_block_size_invariance(gamma, blk, seed):
    rng = np.random.default_rng(seed)
    N, s, M = 2, 5, 200
    z = jnp.asarray(rng.normal(size=(N, s, M)), jnp.float32)
    V = _V(N, s, seed)
    g = jnp.full((N,), gamma, jnp.int32)
    out = ops.consensus_mix(z, V, g, blk_m=blk)
    expect = ref.consensus_mix_ref(z, V, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-4)


def test_consensus_mix_preserves_mean():
    rng = np.random.default_rng(1)
    N, s, M = 3, 5, 96
    z = jnp.asarray(rng.normal(size=(N, s, M)), jnp.float32)
    V = _V(N, s, 1)
    out = ops.consensus_mix(z, V, jnp.full((N,), 7, jnp.int32))
    np.testing.assert_allclose(np.asarray(out.mean(1)),
                               np.asarray(z.mean(1)), atol=1e-4)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("BH,T,P,S,chunk", [
    (1, 64, 16, 16, 16), (2, 256, 64, 128, 128), (3, 512, 64, 128, 256),
    (2, 130, 32, 64, 64),   # ragged T -> padding path in ops
])
def test_ssd_scan_shapes(BH, T, P, S, chunk):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(BH, T, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(BH, T)), jnp.float32)
    loga = -dt * jnp.asarray(rng.uniform(0.5, 2.0, size=(BH, 1)),
                             jnp.float32)
    B = jnp.asarray(rng.normal(size=(BH, T, S)), jnp.float32) * 0.3
    C = jnp.asarray(rng.normal(size=(BH, T, S)), jnp.float32) * 0.3
    yk, hk = ops.ssd_scan(x, dt, loga, B, C, chunk=chunk)
    yr, hr = ref.ssd_scan_ref(x, dt, loga, B, C)
    scale = float(jnp.abs(yr).max()) + 1e-6
    assert float(jnp.abs(yk - yr).max()) / scale < 1e-4
    if T % chunk == 0:   # padded case: final state includes padding steps
        np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                                   rtol=1e-4, atol=1e-4)


def test_ssd_scan_state_carry_across_chunks():
    """Splitting T into chunks must equal one long scan (state carry)."""
    rng = np.random.default_rng(2)
    BH, T, P, S = 2, 256, 32, 64
    x = jnp.asarray(rng.normal(size=(BH, T, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, size=(BH, T)), jnp.float32)
    loga = -dt
    B = jnp.asarray(rng.normal(size=(BH, T, S)), jnp.float32) * 0.3
    C = jnp.asarray(rng.normal(size=(BH, T, S)), jnp.float32) * 0.3
    y64, _ = ops.ssd_scan(x, dt, loga, B, C, chunk=64)
    y256, _ = ops.ssd_scan(x, dt, loga, B, C, chunk=256)
    np.testing.assert_allclose(np.asarray(y64), np.asarray(y256),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused_sgd
# ---------------------------------------------------------------------------

# tiny leaves (n < 128) and odd sizes straddling the lane width pin the
# block-size logic: blocks must stay lane multiples, pad must trim back
@pytest.mark.parametrize("shape", [(8,), (127,), (129,), (1000, 37),
                                   (3, 5, 7, 11)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("wd", [0.0, 0.1])
def test_fused_sgd(shape, dtype, wd):
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=shape), dtype)
    g = jnp.asarray(rng.normal(size=shape), dtype)
    out = ops.fused_sgd(w, g, 0.01, weight_decay=wd)
    expect = ref.fused_sgd_ref(w, g, jnp.asarray(0.01), weight_decay=wd)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)


def test_fused_sgd_block_is_lane_aligned():
    from repro.kernels.fused_sgd import LANE
    # n just under/over the lane width must still produce lane-multiple
    # blocks (the old min(blk, max(n, 8)) could hand Mosaic blk=37)
    for n in (8, 127, 128, 129, 1000 * 37):
        blk = max(LANE, min(65_536, -(-n // LANE) * LANE))
        assert blk % LANE == 0


# ---------------------------------------------------------------------------
# fused_consensus_sgd: last-microstep SGD + W-mixing in one pass
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,s,M", [(2, 4, 64), (4, 2, 937), (1, 8, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("wd", [0.0, 0.1])
def test_fused_consensus_sgd(N, s, M, dtype, wd):
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(N, s, M)), dtype)
    g = jnp.asarray(rng.normal(size=(N, s, M)), dtype)
    V = _V(N, s)
    W = jnp.asarray(np.stack([np.linalg.matrix_power(
        np.asarray(V[c], np.float64), 2) for c in range(N)]), jnp.float32)
    out = ops.fused_consensus_sgd(w, g, W, 0.01, weight_decay=wd)
    expect = ref.fused_consensus_sgd_ref(w, g, W, jnp.asarray(0.01),
                                         weight_decay=wd)
    assert out.shape == (N, s, M) and out.dtype == dtype
    # bf16: the ref rounds to bf16 between the SGD update and the mix,
    # the kernel keeps f32 throughout — up to ~2 bf16 ulp apart, so the
    # bound must scale with magnitude (rtol), not be purely absolute
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_fused_consensus_sgd_matches_jitted_two_pass():
    """vs the jitted unfused two-pass graph (SGD then mix) — the jit-to-
    jit comparison the fused-interval step's bitwise contract rests on."""
    from repro.kernels.fused_consensus_sgd import fused_consensus_sgd
    N, s, M = 2, 4, 384
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.normal(size=(N, s, M)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(N, s, M)), jnp.float32)
    V = _V(N, s)
    W = jnp.asarray(np.stack([np.linalg.matrix_power(
        np.asarray(V[c], np.float64), 3) for c in range(N)]), jnp.float32)

    @jax.jit
    def two_pass(w, g, W):
        wp = w - jnp.float32(0.01) * g
        return jnp.einsum("nij,njm->nim", W, wp,
                          preferred_element_type=jnp.float32)

    fused = fused_consensus_sgd(w, g, W, jnp.float32(0.01))
    assert np.array_equal(np.asarray(fused), np.asarray(two_pass(w, g, W)))


def test_trainer_with_kernel_matches_without():
    """The sim engine with use_kernel=True must train identically."""
    import dataclasses
    from repro.configs import TopologyConfig, TTHFConfig
    from repro.core import TTHFTrainer
    from repro.data import fashion_synth, partition_noniid_labels
    from repro.models import make_sim_model

    x, y = fashion_synth(num_points=800, seed=0)
    data = partition_noniid_labels(x, y, num_devices=10)
    topo = TopologyConfig(num_devices=10, num_clusters=2, graph="ring")
    model = make_sim_model("svm", 784, 10)
    algo = TTHFConfig(tau=5, consensus_every=2, gamma_d2d=2,
                      constant_lr=0.002)
    runs = []
    for uk in (False, True):
        tr = TTHFTrainer(model, data, topo, algo, batch_size=8,
                         use_kernel=uk)
        _, hist = tr.run(steps=10, eval_every=5, seed=0)
        runs.append(hist.global_loss)
    np.testing.assert_allclose(runs[0], runs[1], rtol=1e-4)
