"""Remark-1 adaptive Gamma rule + step-size schedules."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import TTHFConfig
from repro.core import adaptive_gamma, fixed_gamma, lemma1_bound, \
    make_lr_schedule
from repro.optim.schedules import paper_schedule


def test_paper_schedule_decays_as_1_over_t():
    eta = paper_schedule(gamma=2.0, alpha=8.0)
    assert float(eta(0)) == 2.0 / 8.0
    assert abs(float(eta(1000)) - 2.0 / 1008.0) < 1e-9


@given(ups=st.floats(1e-6, 10.0), lam=st.floats(0.3, 0.95),
       t=st.integers(0, 500))
@settings(max_examples=40, deadline=None)
def test_adaptive_gamma_achieves_target(ups, lam, t):
    """Remark 1: the chosen Gamma makes the Lemma-1 bound <= eta_t*phi."""
    phi, s, M = 1.0, 5, 100
    eta = paper_schedule(1.0, 4.0)
    eta_t = float(eta(t))
    g = int(adaptive_gamma(jnp.asarray(eta_t), phi, jnp.asarray([ups]),
                           jnp.asarray([lam]), s, M, max_rounds=4000)[0])
    bound = lemma1_bound(lam, g, s, ups, M)
    target = eta_t * phi
    if g < 4000:   # not clipped
        assert bound <= target * (1 + 1e-5) or g == 0
    if g == 0:     # Gamma=0 must only happen when already within target
        assert s * ups * M <= target


def test_adaptive_gamma_aperiodic():
    """Small divergence -> zero rounds (aperiodicity, Remark 1)."""
    g = adaptive_gamma(jnp.asarray(0.1), 1.0, jnp.asarray([1e-12]),
                       jnp.asarray([0.7]), 5, 100)
    assert int(g[0]) == 0


def test_consensus_calendar():
    cfg = TTHFConfig(tau=20, consensus_every=5)
    agg = [t for t in range(1, 41) if cfg.is_aggregation_step(t)]
    cons = [t for t in range(1, 41) if cfg.is_consensus_step(t)]
    assert agg == [20, 40]
    assert cons == [5, 10, 15, 20, 25, 30, 35, 40]


def test_fixed_gamma():
    assert fixed_gamma(3, 4).tolist() == [4, 4, 4]


def test_lr_schedule_selection():
    eta = make_lr_schedule(TTHFConfig(constant_lr=0.01))
    assert abs(float(eta(500)) - 0.01) < 1e-7
    eta2 = make_lr_schedule(TTHFConfig(gamma=2.0, alpha=10.0))
    assert abs(float(eta2(0)) - 0.2) < 1e-6
