"""The scale-mode raw-speed pass (DESIGN.md §12): fused-interval flat
buffer vs the reference step (bitwise in f32), buffer donation on the
trainer's jitted step, and the prefetch loader's determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.distributed import (
    FlatParamSpec, TTHFScaleConfig, make_tthf_train_step, stack_replicas)
from repro.models import build_model
from repro.train import PrefetchLoader, ScaleTrainer, TrainerConfig

# deliberately NON-lane-aligned (d_model=64, odd leaf sizes): the
# bitwise contract must not depend on shape luck
_CFG = get_arch("qwen1.5-0.5b").reduced(num_layers=2, d_model=64,
                                        d_ff=128, vocab_size=128)
_R, _TAU = 4, 4


def _model():
    return build_model(_CFG)


def _scale(**kw):
    kw.setdefault("replicas", _R)
    kw.setdefault("cluster_size", 2)
    kw.setdefault("tau", _TAU)
    kw.setdefault("consensus_every", 2)
    kw.setdefault("gamma_d2d", 2)
    kw.setdefault("lr", 0.05)
    return TTHFScaleConfig(**kw)


def _batch(seed=1, tau=_TAU, T=16):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (tau, _R, 2, T),
                              0, _CFG.vocab_size)
    return {"tokens": toks, "labels": toks}


def _bitwise(tree_a, tree_b):
    return all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(tree_a),
                               jax.tree.leaves(tree_b)))


# ---------------------------------------------------------------------------
# FlatParamSpec
# ---------------------------------------------------------------------------

def test_flat_spec_roundtrip():
    model = _model()
    spec = FlatParamSpec.for_model(model)
    assert spec.padded % 128 == 0 and spec.padded >= spec.total
    params = stack_replicas(model.init(jax.random.PRNGKey(0)), _R)
    flat = spec.flatten(params)
    assert flat.shape == (_R, spec.padded) and flat.dtype == jnp.float32
    # pad columns zero, roundtrip exact
    assert not np.any(np.asarray(flat[:, spec.total:]))
    assert _bitwise(params, spec.unflatten(flat))
    assert _bitwise(jax.tree.map(lambda l: l[2], params),
                    spec.unflatten_one(flat[2]))


def test_flat_spec_rejects_mixed_dtypes():
    with pytest.raises(AssertionError, match="uniform param dtype"):
        FlatParamSpec.for_tree({"a": jnp.zeros((3,), jnp.float32),
                                "b": jnp.zeros((3,), jnp.bfloat16)})


# ---------------------------------------------------------------------------
# fused interval == reference interval, bitwise in f32
# ---------------------------------------------------------------------------

def _run_pair(sync="tthf", agg=None, scale=None, hierarchy=None,
              refreshable=False, refresh=None, fused_kernel=None,
              intervals=2):
    model = _model()
    scale = scale or _scale()
    kw = dict(dtype=jnp.float32, sync=sync, hierarchy=hierarchy,
              refreshable=refreshable)
    ref_step, net = make_tthf_train_step(model, scale, **kw)
    fus_step, _ = make_tthf_train_step(model, scale, fused_interval=True,
                                       fused_kernel=fused_kernel, **kw)
    spec = fus_step.spec
    params = stack_replicas(model.init(jax.random.PRNGKey(0)), _R)
    flat = spec.flatten(params)
    if agg is None:
        agg = jnp.asarray([1, 0], jnp.int32)
    batch = _batch(tau=scale.tau)
    jref, jfus = jax.jit(ref_step), jax.jit(fus_step)
    losses = []
    for i in range(intervals):
        args = (jnp.asarray(i),) + (() if refresh is None else (refresh,))
        params, l_ref = jref(params, batch, agg, *args)
        flat, l_fus = jfus(flat, batch, agg, *args)
        losses.append((float(l_ref), float(l_fus)))
    return params, spec.unflatten(flat), losses


@pytest.mark.parametrize("sync", ["tthf", "star", "local"])
def test_fused_interval_bitwise_across_sync(sync):
    p_ref, p_fus, losses = _run_pair(sync=sync)
    assert all(a == b for a, b in losses)
    assert _bitwise(p_ref, p_fus)


def test_fused_interval_bitwise_weights_agg():
    # sample_per_cluster > 1 routes through the (N, s) weight-matrix
    # aggregation form
    scale = _scale(sample_per_cluster=2)
    w = jnp.asarray([[0.3, 0.2], [0.0, 0.5]], jnp.float32)
    p_ref, p_fus, losses = _run_pair(agg=w, scale=scale)
    assert all(a == b for a, b in losses)
    assert _bitwise(p_ref, p_fus)


def test_fused_interval_bitwise_matrix_agg():
    # a non-flat hierarchy routes through the composed (R, R) device
    # matrix form
    from repro.configs.base import HierarchyConfig
    h = HierarchyConfig(levels=3, taus=(_TAU, 2 * _TAU), sample=(1, 0))
    rng = np.random.default_rng(0)
    M = rng.random((_R, _R))
    M = jnp.asarray(M / M.sum(1, keepdims=True), jnp.float32)
    p_ref, p_fus, losses = _run_pair(agg=M, hierarchy=h)
    assert all(a == b for a, b in losses)
    assert _bitwise(p_ref, p_fus)


def test_fused_interval_bitwise_refreshable():
    # netsim dynamics: per-interval consensus-matrix refresh feeds the
    # once-traced step
    from repro.core.mixing import build_mixing_plan, refresh_matrices
    scale = _scale()
    net = scale.network()
    plan = build_mixing_plan(net, scale.gamma_d2d, backend="fused")
    refresh = refresh_matrices(plan, np.asarray(net.V))
    w = jnp.asarray([[0.5, 0.0], [0.0, 0.5]], jnp.float32)
    p_ref, p_fus, losses = _run_pair(agg=w, refreshable=True,
                                     refresh=refresh, scale=scale)
    assert all(a == b for a, b in losses)
    assert _bitwise(p_ref, p_fus)


def test_fused_interval_rounds_backend_matches_reference():
    # non-fused_power backends keep exact per-event semantics on the
    # flat buffer (no W to collapse into)
    scale = _scale(consensus_mode="rounds")
    p_ref, p_fus, losses = _run_pair(scale=scale)
    assert all(a == b for a, b in losses)
    assert _bitwise(p_ref, p_fus)


def test_fused_interval_kernel_path_close():
    """fused_kernel=True exercises the Pallas block-end (interpret mode
    on CPU). Its inline last-step grad may re-vectorize, so this path
    carries the kernel tolerance, not the bitwise contract."""
    p_ref, p_fus, losses = _run_pair(fused_kernel=True, intervals=1)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_fus)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)
    for a, b in losses:
        assert abs(a - b) < 1e-6


def test_fused_interval_pad_stays_zero():
    model = _model()
    scale = _scale()
    step, _ = make_tthf_train_step(model, scale, dtype=jnp.float32,
                                   fused_interval=True)
    spec = step.spec
    if spec.padded == spec.total:
        pytest.skip("model packs to an exact lane multiple")
    flat = spec.flatten(stack_replicas(model.init(jax.random.PRNGKey(0)),
                                       _R))
    flat, _ = jax.jit(step)(flat, _batch(), jnp.asarray([1, 0], jnp.int32),
                            jnp.asarray(0))
    assert not np.any(np.asarray(flat[:, spec.total:]))


# ---------------------------------------------------------------------------
# donation
# ---------------------------------------------------------------------------

def _mk_trainer(tmp_path, **kw):
    t = TrainerConfig(batch_per_replica=2, seq_len=16, intervals=2,
                      eval_every=0, ckpt_dir=str(tmp_path), **kw)
    return ScaleTrainer(_CFG, _scale(), t)


def test_trainer_step_donates_param_buffer(tmp_path):
    tr = _mk_trainer(tmp_path).init()
    batch = tr._interval_batch()
    args = (tr.params, batch, jnp.asarray([1, 0], jnp.int32),
            jnp.asarray(0))
    lowered = tr._step.lower(*args)
    # the params buffer is aliased to the output in the lowered module…
    assert "tf.aliasing_output" in lowered.as_text()
    mem = lowered.compile().memory_analysis()
    if mem is not None and hasattr(mem, "alias_size_in_bytes"):
        param_bytes = sum(np.asarray(l).nbytes
                          for l in jax.tree.leaves(tr.params))
        assert mem.alias_size_in_bytes >= param_bytes
    # …and the donated buffer is actually invalidated by execution
    old = tr.params
    tr.run(1)
    with pytest.raises(RuntimeError):
        _ = np.asarray(jax.tree.leaves(old)[0]) + 0


def test_trainer_donate_off_keeps_buffer(tmp_path):
    tr = _mk_trainer(tmp_path, donate=False).init()
    old = tr.params
    tr.run(1)
    _ = [np.asarray(l) for l in jax.tree.leaves(old)]   # still readable


def test_donation_halves_live_param_buffers(tmp_path):
    """The memory claim behind donate=True: an undonated step must keep
    input AND output param buffers live (2x), a donated step aliases
    them (1x). Compare the compiled executables' argument aliasing."""
    tr_d = _mk_trainer(tmp_path).init()
    tr_u = _mk_trainer(tmp_path, donate=False).init()
    batch = tr_d._interval_batch()
    args = (tr_d.params, batch, jnp.asarray([1, 0], jnp.int32),
            jnp.asarray(0))
    txt_d = tr_d._step.lower(*args).as_text()
    txt_u = tr_u._step.lower(*args).as_text()
    assert "tf.aliasing_output" in txt_d
    assert "tf.aliasing_output" not in txt_u


# ---------------------------------------------------------------------------
# prefetch loader
# ---------------------------------------------------------------------------

def test_prefetch_loader_preserves_order_and_end():
    src = iter(range(7))
    with PrefetchLoader(lambda: next(src), depth=2,
                        put=lambda x: x) as loader:
        got = [loader.get() for _ in range(7)]
        assert got == list(range(7))
        with pytest.raises(StopIteration):
            loader.get()


def test_prefetch_loader_surfaces_worker_error():
    def boom():
        raise ValueError("bad batch")
    loader = PrefetchLoader(boom, put=lambda x: x)
    with pytest.raises(ValueError, match="bad batch"):
        loader.get()
    loader.close()


def test_prefetched_run_matches_synchronous(tmp_path):
    sync_tr = _mk_trainer(tmp_path, prefetch=False).run()
    pre_tr = _mk_trainer(tmp_path, prefetch=True).run()
    assert _bitwise(sync_tr.params, pre_tr.params)
    assert sync_tr._train_draws == pre_tr._train_draws


def test_prefetched_batches_identical_to_interval_batch():
    """The loader consumes the SAME build fn in the same order — the
    batch stream is byte-identical to the synchronous path's."""
    t = TrainerConfig(batch_per_replica=2, seq_len=16)
    a = ScaleTrainer(_CFG, _scale(), t)
    b = ScaleTrainer(_CFG, _scale(), t)
    ref = [a._interval_batch() for _ in range(3)]
    with PrefetchLoader(b._build_interval_batch, depth=1) as loader:
        got = [loader.get() for _ in range(3)]
    for r, g in zip(ref, got):
        for k in r:
            assert np.array_equal(np.asarray(r[k]), np.asarray(g[k]))


# ---------------------------------------------------------------------------
# trainer end-to-end: fused carrier
# ---------------------------------------------------------------------------

def test_trainer_fused_interval_matches_straight(tmp_path):
    straight = _mk_trainer(tmp_path, donate=False, prefetch=False).run()
    fused = _mk_trainer(tmp_path, fused_interval=True).run()
    assert fused._spec is not None
    assert _bitwise(straight.params,
                    fused._spec.unflatten(fused.params))
    # eval goes through the same global model
    assert straight.evaluate() == fused.evaluate()


def test_trainer_fused_checkpoint_cross_mode(tmp_path):
    fused = _mk_trainer(tmp_path, fused_interval=True).run()
    p = fused.save(os.path.join(str(tmp_path), "ck.npz"))
    straight = _mk_trainer(tmp_path, donate=False, prefetch=False)
    straight.restore(p)
    assert _bitwise(straight.params,
                    fused._spec.unflatten(fused.params))
    assert straight.interval == fused.interval
    assert straight._train_draws == fused._train_draws
