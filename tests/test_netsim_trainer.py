"""End-to-end netsim wiring: TTHFTrainer and ScaleTrainer under
dynamics; the static scenario must be bit-for-bit the historical
trajectory."""
import jax
import numpy as np
import pytest

from repro.configs import DynamicsConfig, TopologyConfig, TTHFConfig
from repro.core import TTHFTrainer
from repro.data import fashion_synth, partition_noniid_labels
from repro.models import make_sim_model
from repro.netsim import scenarios


@pytest.fixture(scope="module")
def fleet():
    x, y = fashion_synth(num_points=800, seed=0)
    data = partition_noniid_labels(x, y, num_devices=20)
    topo = TopologyConfig(num_devices=20, num_clusters=4,
                          graph="geometric", seed=0)
    model = make_sim_model("svm", 784, 10)
    return data, topo, model


def _run(fleet, algo, dyn=None, steps=20):
    data, topo, model = fleet
    tr = TTHFTrainer(model, data, topo, algo, batch_size=8, dynamics=dyn)
    _, h = tr.run(steps=steps, eval_every=5, seed=0)
    return tr, h


ALGO = TTHFConfig(tau=10, consensus_every=5, gamma_d2d=2,
                  constant_lr=0.002)


def test_static_scenario_reproduces_history_bit_for_bit(fleet):
    tr0, h0 = _run(fleet, ALGO, dyn=None)
    tr1, h1 = _run(fleet, ALGO, dyn=scenarios.get("static"))
    assert h0.global_loss == h1.global_loss      # exact float equality
    assert h0.global_acc == h1.global_acc
    assert h0.dispersion == h1.dispersion
    assert tr0.ledger.uplinks == tr1.ledger.uplinks
    assert tr0.ledger.d2d_msgs == tr1.ledger.d2d_msgs


@pytest.mark.parametrize("name", ["markov_links", "device_churn",
                                  "stragglers", "flash_crowd"])
def test_dynamic_scenarios_run_and_stay_finite(fleet, name):
    tr, h = _run(fleet, ALGO, dyn=scenarios.get(name, seed=1))
    assert all(np.isfinite(h.global_loss))
    assert tr.ledger.uplinks > 0
    if name == "stragglers":
        assert tr.ledger.delay(0.1) > CommDelayBaseline(tr)


def CommDelayBaseline(tr):
    """Delay with the straggler extras stripped."""
    led = tr.ledger
    return (led.uplinks * 0.25 + led.d2d_rounds * 0.1 * 0.25)


def test_total_blackout_freezes_everything(fleet):
    """p_drop=1, p_return=0: from t=1 every device is offline — no SGD,
    no consensus traffic, no uplinks; parameters hold exactly."""
    dyn = DynamicsConfig(name="blackout", p_device_drop=1.0,
                         p_device_return=0.0, seed=0)
    data, topo, model = fleet
    tr = TTHFTrainer(model, data, topo, ALGO, batch_size=8, dynamics=dyn)
    st0 = tr.init(seed=0)
    init_params = jax.tree.map(np.asarray, st0.params)
    st, h = tr.run(steps=12, seed=0, state=st0)
    for a, b in zip(jax.tree.leaves(init_params),
                    jax.tree.leaves(st.params)):
        np.testing.assert_array_equal(np.asarray(b), a)
    assert tr.ledger.uplinks == 0
    assert tr.ledger.d2d_msgs == 0
    assert h.active_devices[-1] == 0


def test_dead_links_bill_no_rounds_under_adaptive_gamma(fleet):
    """All base edges dead from t=1: mixing is the identity, so the
    adaptive Remark-1 rule must neither run nor bill any D2D round
    (lambda=0 clusters used to clip into gamma >= 1)."""
    algo = TTHFConfig(tau=10, consensus_every=5, gamma_d2d=-1, phi=1.0,
                      constant_lr=0.002)
    dyn = DynamicsConfig(name="linkdeath", p_link_fail=1.0,
                         p_link_recover=0.0, seed=0)
    tr, h = _run(fleet, algo, dyn=dyn, steps=15)
    assert tr.ledger.d2d_rounds == 0 and tr.ledger.d2d_msgs == 0
    assert all((np.asarray(g) == 0).all() for g in h.gamma_used)


def test_multi_sampling_ledger_matches_transmissions(fleet):
    algo = TTHFConfig(tau=10, consensus_every=5, gamma_d2d=2,
                      constant_lr=0.002, sample_per_cluster=3)
    tr, _ = _run(fleet, algo, steps=20)
    # 2 aggregations x 4 clusters x 3 sampled devices — now real ones
    assert tr.ledger.uplinks == 2 * 4 * 3


def test_scale_trainer_accepts_w_refresh():
    from repro.configs import get_arch
    from repro.core.distributed import TTHFScaleConfig
    from repro.train import ScaleTrainer, TrainerConfig

    cfg = get_arch("qwen1.5-0.5b").reduced(num_layers=2, d_model=64,
                                           d_ff=128, vocab_size=128)
    scale = TTHFScaleConfig(replicas=4, cluster_size=2, tau=2,
                            consensus_every=2, gamma_d2d=2, lr=0.05)
    tcfg = TrainerConfig(batch_per_replica=2, seq_len=16, intervals=2,
                         eval_every=0, eval_batches=1)
    tr = ScaleTrainer(cfg, scale, tcfg,
                      dynamics=scenarios.get("device_churn", seed=2))
    tr.init().run()
    assert tr.interval == 2
    for leaf in jax.tree.leaves(tr.params):
        assert bool(np.isfinite(np.asarray(leaf)).all())
