"""TT-HF scale mode (core/distributed.py): consensus/aggregation
semantics over the replica axis, fused == rounds, and a tiny end-to-end
training run on a reduced arch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.distributed import (
    TTHFScaleConfig, consensus_event, full_aggregation,
    make_tthf_train_step, sampled_aggregation, stack_replicas,
)
from repro.models import build_model


def _params(R=8, seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(R, 6, 4)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(R, 5)), jnp.float32)}


def test_fused_equals_rounds():
    scale = TTHFScaleConfig(replicas=8, cluster_size=4, gamma_d2d=3)
    net = scale.network()
    p = _params()
    a = consensus_event(p, net, 3, "fused")
    b = consensus_event(p, net, 3, "rounds")
    for k in p:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-5, atol=1e-6)


def test_consensus_preserves_replica_mean():
    scale = TTHFScaleConfig(replicas=8, cluster_size=4, gamma_d2d=5)
    net = scale.network()
    p = _params()
    out = consensus_event(p, net, 5, "fused")
    for k in p:
        np.testing.assert_allclose(
            np.asarray(out[k].reshape(2, 4, -1).mean(1)),
            np.asarray(p[k].reshape(2, 4, -1).mean(1)), atol=1e-5)


def test_sampled_aggregation_broadcasts_weighted_pick():
    scale = TTHFScaleConfig(replicas=4, cluster_size=2)
    net = scale.network()
    p = _params(R=4)
    picks = jnp.asarray([1, 0], jnp.int32)
    out = sampled_aggregation(p, net, picks)
    expect = 0.5 * p["w"][1] + 0.5 * p["w"][2]
    for r in range(4):
        np.testing.assert_allclose(np.asarray(out["w"][r]),
                                   np.asarray(expect), atol=1e-6)


def test_full_aggregation_is_global_mean():
    scale = TTHFScaleConfig(replicas=4, cluster_size=2)
    net = scale.network()
    p = _params(R=4)
    out = full_aggregation(p, net)
    np.testing.assert_allclose(np.asarray(out["w"][0]),
                               np.asarray(p["w"].mean(0)), atol=1e-6)


@pytest.mark.slow
def test_scale_mode_training_decreases_loss():
    cfg = get_arch("qwen1.5-0.5b").reduced(num_layers=2, d_model=128,
                                           d_ff=256, vocab_size=256)
    model = build_model(cfg)
    scale = TTHFScaleConfig(replicas=4, cluster_size=2, tau=4,
                            consensus_every=2, gamma_d2d=2, lr=0.05)
    step, net = make_tthf_train_step(model, scale, dtype=jnp.float32)
    step = jax.jit(step)
    params = stack_replicas(model.init(jax.random.PRNGKey(0)), 4)
    key = jax.random.PRNGKey(1)
    # fixed tiny corpus: loss must drop across intervals
    toks = jax.random.randint(key, (scale.tau, 4, 2, 32), 0, 256)
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for i in range(3):
        key, kp = jax.random.split(key)
        picks = jax.random.randint(kp, (net.num_clusters,), 0, 2)
        params, loss = step(params, batch, picks, jnp.asarray(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    # after aggregation all replicas hold the same model
    for leaf in jax.tree.leaves(params):
        np.testing.assert_allclose(np.asarray(leaf[0]),
                                   np.asarray(leaf[-1]), atol=1e-5)


def test_star_sync_equalizes_replicas():
    cfg = get_arch("qwen1.5-0.5b").reduced(num_layers=2, d_model=128,
                                           d_ff=256, vocab_size=256)
    model = build_model(cfg)
    scale = TTHFScaleConfig(replicas=4, cluster_size=2, tau=2,
                            consensus_every=2, gamma_d2d=0, lr=0.05)
    step, net = make_tthf_train_step(model, scale, dtype=jnp.float32,
                                     sync="star")
    params = stack_replicas(model.init(jax.random.PRNGKey(0)), 4)
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (2, 4, 2, 16), 0, 256)
    batch = {"tokens": toks, "labels": toks}
    params, _ = jax.jit(step)(params, batch,
                              jnp.zeros((2,), jnp.int32), jnp.asarray(0))
    for leaf in jax.tree.leaves(params):
        np.testing.assert_allclose(np.asarray(leaf[0]),
                                   np.asarray(leaf[2]), atol=1e-5)
