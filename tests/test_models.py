"""Per-arch smoke tests: REDUCED variant of each assigned architecture,
one forward + one train (SGD) step on CPU; shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import build_model

ALL_ARCHS = sorted(ARCHS)


def make_batch(cfg, B=2, T=32, key=None):
    key = key or jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.kind == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model)) * 0.1
    if cfg.kind in ("encdec", "audio"):
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_arch_smoke_forward_and_train_step(name):
    cfg = get_arch(name).reduced()
    assert cfg.d_model <= 512 and cfg.num_layers <= 3
    if cfg.moe_num_experts:
        assert cfg.moe_num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 32
    batch = make_batch(cfg, B, T)

    logits, aux = model.forward(params, batch, dtype=jnp.float32)
    t_text = T
    assert logits.shape == (B, t_text, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())

    # one SGD step decreases nothing catastrophic & produces finite params
    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch, dtype=jnp.float32))(params)
    assert np.isfinite(float(loss))
    new_params = jax.tree.map(lambda w, g: w - 1e-3 * g, params, grads)
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.isfinite(leaf).all()), "non-finite params after step"
    loss2 = model.loss(new_params, batch, dtype=jnp.float32)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_arch_abstract_params_match_real(name):
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    shapes, axes = model.abstract_params()
    params = model.init(jax.random.PRNGKey(0))
    flat_s = jax.tree.leaves(shapes)
    flat_p = jax.tree.leaves(params)
    assert len(flat_s) == len(flat_p)
    for s, p in zip(flat_s, flat_p):
        assert s.shape == p.shape and s.dtype == p.dtype
    # axes tree matches params structure and ranks
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    for a, p in zip(flat_a, flat_p):
        assert len(a) == p.ndim, (a, p.shape)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    c = get_arch("gemma-2b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (18, 2048, 8, 1, 16384, 256000)
    c = get_arch("llama4-maverick-400b-a17b")
    assert c.moe_num_experts == 128 and c.moe_top_k == 1
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == \
        (48, 5120, 40, 8)
    c = get_arch("mamba2-370m")
    assert c.ssm_state_dim == 128 and c.num_heads == 0
    c = get_arch("recurrentgemma-9b")
    assert (c.num_layers, c.d_model) == (38, 4096)
    c = get_arch("whisper-small")
    assert c.enc_seq_len == 1500 and c.cross_attention
    c = get_arch("qwen1.5-0.5b")
    assert c.qkv_bias
    c = get_arch("starcoder2-3b")
    assert c.num_kv_heads == 2 and c.rope
    c = get_arch("granite-3-8b")
    assert (c.num_layers, c.num_heads, c.num_kv_heads) == (40, 32, 8)
    c = get_arch("paligemma-3b")
    assert c.vocab_size == 257_216 and c.enc_seq_len == 256
    c = get_arch("llama4-scout-17b-a16e")
    assert c.moe_num_experts == 16


def test_param_counts_plausible():
    assert abs(get_arch("gemma-2b").param_count() / 1e9 - 2.5) < 0.5
    assert abs(get_arch("granite-3-8b").param_count() / 1e9 - 8.2) < 1.0
    mav = get_arch("llama4-maverick-400b-a17b")
    assert 350e9 < mav.param_count() < 450e9
    assert mav.active_param_count() < 20e9
