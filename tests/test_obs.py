"""Observability (repro.obs, DESIGN.md §13).

The §13 contract, enforced:

* span nesting + Chrome-trace export round-trips through the schema
  validator the CI obs-smoke job uses;
* an instrumented run is BITWISE the uninstrumented run (probes are
  separate read-only jitted functions — sim and scale mode);
* the theory gauges in the stream equal direct ``core/theory.py``
  calls (including the general-η Σ_t against the closed form);
* ledger attribution rows re-sum to exactly the counters pricing
  reads, per cluster / per level / per event;
* scheduler request records are complete and internally consistent;
* MetricLogger honours ``window`` and closes its JSONL handle.
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.energy import CommLedger
from repro.obs.manifest import config_hash, write_manifest
from repro.obs.sink import NULL_OBS, make_obs
from repro.obs.trace import Tracer, validate_chrome_trace
from repro.rounds import Billing


# ===========================================================================
# tracer
# ===========================================================================

def test_span_nesting_and_export_roundtrip(tmp_path):
    tr = Tracer()
    with tr.span("run", intervals=2):
        with tr.span("round", interval=0):
            with tr.span("interval", tau=4):
                pass
            tr.instant("consensus_event", repeats=2)
            tr.counter("ledger", uplinks=3, d2d_msgs=12)
    path = tr.export(str(tmp_path / "trace.json"))
    doc = json.loads(Path(path).read_text())
    assert validate_chrome_trace(doc) == []
    evs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    by_name = {e["name"]: e for e in evs}
    assert set(by_name) == {"run", "round", "interval"}
    # nesting: child spans start no earlier and end no later
    for outer, inner in (("run", "round"), ("round", "interval")):
        o, i = by_name[outer], by_name[inner]
        assert o["ts"] <= i["ts"]
        assert o["ts"] + o["dur"] >= i["ts"] + i["dur"]
    kinds = {e["ph"] for e in doc["traceEvents"]}
    assert {"X", "i", "C", "M"} <= kinds
    # args survive the round trip
    assert by_name["run"]["args"]["intervals"] == 2


def test_instant_does_not_deadlock_or_malform():
    tr = Tracer()
    for _ in range(3):
        tr.instant("aggregation", uplinks_by_level={1: 4})
    assert len(tr.events) == 3
    assert all(e["ph"] == "i" for e in tr.events)


def test_validator_flags_malformed():
    assert validate_chrome_trace({}) == ["missing traceEvents"]
    bad = {"traceEvents": [{"ph": "X", "pid": 1, "name": "x", "ts": 0.0,
                            "dur": -1.0},
                           {"ph": "i"}]}
    probs = validate_chrome_trace(bad)
    assert any("negative dur" in p for p in probs)
    assert any("missing 'name'" in p for p in probs)


# ===========================================================================
# manifest + sink lifecycle
# ===========================================================================

def test_manifest_and_sink_artifacts(tmp_path):
    d = tmp_path / "obs"
    obs = make_obs(str(d), run_name="t", config={"a": 1, "b": [2, 3]},
                   extra={"arch": "x"})
    assert obs.enabled
    obs.emit("round", 1, loss=1.5, vec=np.arange(3))
    with obs.span("run"):
        obs.counter("c", v=1)
    obs.close()
    man = json.loads((d / "manifest.json").read_text())
    for key in ("config_hash", "git_sha", "mesh", "unix_ts", "argv"):
        assert key in man, key
    assert man["arch"] == "x"
    assert man["config_hash"] == config_hash({"a": 1, "b": [2, 3]})
    assert config_hash({"b": [2, 3], "a": 1}) == man["config_hash"]
    recs = [json.loads(l) for l in
            (d / "metrics.jsonl").read_text().splitlines()]
    assert recs[0]["kind"] == "round" and recs[0]["vec"] == [0, 1, 2]
    doc = json.loads((d / "trace.json").read_text())
    assert validate_chrome_trace(doc) == []


def test_null_obs_is_free_and_silent(tmp_path):
    assert make_obs(None) is NULL_OBS
    assert not NULL_OBS.enabled
    with NULL_OBS.span("x", a=1) as o:
        o.emit("round", 0, loss=1.0)
        o.instant("e")
        o.counter("c", v=2)
    NULL_OBS.flush()
    NULL_OBS.close()
    assert list(tmp_path.iterdir()) == []


def test_write_manifest_without_config(tmp_path):
    p = write_manifest(str(tmp_path))
    man = json.loads(Path(p).read_text())
    assert man["config_hash"] is None and man["git_sha"]


# ===========================================================================
# MetricLogger fixes
# ===========================================================================

def test_metric_logger_window_respected(tmp_path):
    from repro.train.metrics import MetricLogger
    ml = MetricLogger(str(tmp_path / "m.jsonl"), console_every=0,
                      window=5)
    for i in range(20):
        ml.log(i, loss=float(i))
    assert len(ml._recent["loss"]) == 5               # not 100
    assert ml.smoothed("loss") == np.mean(range(15, 20))
    ml.close()
    assert ml._fh is None
    ml.close()                                        # idempotent


def test_metric_logger_context_manager(tmp_path):
    from repro.train.metrics import MetricLogger
    with MetricLogger(str(tmp_path / "m.jsonl"), console_every=0) as ml:
        ml.log(0, loss=1.0)
        fh = ml._fh
        assert fh is not None
    assert ml._fh is None
    recs = [json.loads(l) for l in
            (tmp_path / "m.jsonl").read_text().splitlines()]
    assert recs == [{"step": 0, "wall_s": recs[0]["wall_s"], "loss": 1.0}]


# ===========================================================================
# ledger attribution
# ===========================================================================

def test_attribution_rows_resum_to_counters():
    led = CommLedger()
    led.next_event()
    led.record_consensus([2, 0, 3], [4, 5, 2])
    led.record_hierarchy_event({1: 6, 2: 2})
    led.next_event()
    led.record_consensus([1, 1, 1], [4, 5, 2])
    led.record_aggregation(5)
    tot = led.attribution_totals()
    assert tot["uplinks"] == led.uplinks == 13
    assert tot["broadcasts"] == led.broadcasts == 2
    assert tot["d2d_msgs"] == led.d2d_msgs
    assert tot["d2d_rounds"] == led.d2d_rounds == 8
    assert tot["uplinks_by_level"] == led.uplinks_by_level == {1: 11, 2: 2}
    by_cl = led.d2d_by_cluster()
    assert sum(d["msgs"] for d in by_cl.values()) == led.d2d_msgs
    assert sum(d["rounds"] for d in by_cl.values()) == led.d2d_rounds
    # cluster 1 had 0 rounds in event 1, 1 round in event 2
    assert by_cl[1] == {"rounds": 1, "msgs": 2 * 5}
    assert sum(led.uplinks_by_event().values()) == led.uplinks


def test_billing_repeats_keep_cluster_index():
    led = CommLedger()
    bill = Billing(consensus_gammas=np.array([2, 1]),
                   consensus_edges=np.array([3, 4]),
                   consensus_repeats=3)
    bill.charge(led)
    # totals: 3 repeats x per-cluster (g * 2 * e)
    assert led.d2d_msgs == 3 * (2 * 2 * 3 + 1 * 2 * 4)
    assert led.d2d_rounds == 3 * 3
    by_cl = led.d2d_by_cluster()
    assert set(by_cl) == {0, 1}                       # never i % (N*repeats)
    assert by_cl[0]["msgs"] == 3 * 2 * 2 * 3
    assert by_cl[1]["msgs"] == 3 * 1 * 2 * 4
    assert all(r["event"] == 1 for r in led.events)


def test_attribution_since_is_a_delta():
    led = CommLedger()
    led.next_event()
    led.record_consensus([1], [2])
    mark = len(led.events)
    led.next_event()
    led.record_aggregation(3)
    delta = led.attribution_since(mark)
    assert {r["kind"] for r in delta} == {"uplink", "broadcast"}
    assert all(r["event"] == 2 for r in delta)


def test_checkpoint_ledger_filter_skips_rows():
    import dataclasses
    led = CommLedger()
    led.next_event()
    led.record_consensus([1], [2])
    persisted = {k: np.asarray(v) for k, v in
                 dataclasses.asdict(led).items()
                 if not isinstance(v, (dict, list))}
    assert "events" not in persisted and "uplinks_by_level" not in persisted
    assert int(persisted["d2d_msgs"]) == led.d2d_msgs


# ===========================================================================
# theory gauges vs direct core/theory.py calls
# ===========================================================================

def test_gauges_match_theory_module():
    from repro.core.theory import (
        ProblemConstants, dispersion_bound, lemma1_bound, sigma_t)
    from repro.obs.telemetry import TheoryGauges, sigma_t_general

    k = ProblemConstants(mu=1.0, beta=2.0, sigma=0.5, delta=0.3,
                         varrho_min=0.2)
    g = TheoryGauges(constants=k, tau=5, model_dim=42, phi=1.5,
                     gamma=0.8, alpha=3.0)
    t, t_prev = 12, 10
    assert g.sigma(t, t_prev) == sigma_t(k, 0.8, 3.0, 5, t, t_prev)
    out = g.round_gauges(t, t_prev)
    eps0 = (0.8 / (t + 3.0)) * 1.5
    assert out["eps0"] == pytest.approx(eps0)
    assert out["dispersion_bound"] == pytest.approx(
        dispersion_bound(k, 0.8, 3.0, 5, t, t_prev, eps0))
    lam, gam, ups = [0.5, 0.7], [2, 3], [1.1, 0.4]
    got = g.lemma1(lam, gam, 4, ups)
    want = [lemma1_bound(lam[c], gam[c], 4, ups[c], 42)
            for c in range(2)]
    np.testing.assert_allclose(got, want)
    # the general-η Σ_t equals the closed form on the decaying schedule
    assert sigma_t_general(k.beta, lambda j: 0.8 / (j + 3.0), t, t_prev) \
        == pytest.approx(sigma_t(k, 0.8, 3.0, 5, t, t_prev), rel=1e-12)


def test_gauges_schedule_xor():
    from repro.core.theory import ProblemConstants
    from repro.obs.telemetry import TheoryGauges
    k = ProblemConstants(1, 1, 1, 1, 0.2)
    with pytest.raises(AssertionError):
        TheoryGauges(constants=k, tau=2, model_dim=3)        # neither
    with pytest.raises(AssertionError):
        TheoryGauges(constants=k, tau=2, model_dim=3,
                     gamma=1.0, alpha=1.0, lr=0.1)           # both


def test_divergence_probe_matches_reference():
    from repro.core.consensus import consensus_error, divergence_upsilon
    from repro.obs.telemetry import make_divergence_probe

    N, s, d = 3, 4, 7
    rng = np.random.default_rng(0)
    w = rng.normal(size=(N * s, d)).astype(np.float32)
    varrho = np.full((N,), 1.0 / N, np.float32)
    probe = make_divergence_probe(N, s, varrho)
    out = {k: np.asarray(v) for k, v in probe(jnp.asarray(w)).items()}
    z = jnp.asarray(w.reshape(N, s, d))
    np.testing.assert_allclose(out["upsilon"],
                               np.asarray(divergence_upsilon(z)),
                               rtol=1e-5)
    np.testing.assert_allclose(out["consensus_err"],
                               np.asarray(consensus_error(z)), rtol=1e-5)
    e = w.reshape(N, s, d) - w.reshape(N, s, d).mean(1, keepdims=True)
    np.testing.assert_allclose(
        out["mix_residual"],
        np.sqrt((e ** 2).sum(-1).max(1)), rtol=1e-5)
    assert out["param_norm"] == pytest.approx(np.linalg.norm(w), rel=1e-5)


# ===========================================================================
# instrumented == uninstrumented, and the stream is complete (sim mode)
# ===========================================================================

@pytest.fixture(scope="module")
def sim_world():
    from repro.configs import TopologyConfig, TTHFConfig
    from repro.data import fashion_synth, partition_noniid_labels
    from repro.models import make_sim_model

    x, y = fashion_synth(num_points=400, seed=0)
    data = partition_noniid_labels(x, y, num_devices=8,
                                   labels_per_device=3, seed=0)
    topo = TopologyConfig(num_devices=8, num_clusters=2,
                          graph="geometric", seed=0)
    svm = make_sim_model("svm", data.feature_dim, data.num_classes)
    algo = TTHFConfig(tau=4, consensus_every=2, gamma_d2d=2,
                      constant_lr=0.01)
    return data, topo, svm, algo


def _sim_run(sim_world, obs=None):
    from repro.core import TTHFTrainer
    data, topo, svm, algo = sim_world
    tr = TTHFTrainer(svm, data, topo, algo, batch_size=8)
    st, _ = tr.run(steps=8, seed=0, eval_every=4, obs=obs)
    return st, tr


def test_sim_bitwise_parity_and_single_stream(sim_world, tmp_path):
    st0, _ = _sim_run(sim_world)
    obs = make_obs(str(tmp_path / "obs"), run_name="sim")
    st1, tr1 = _sim_run(sim_world, obs=obs)
    obs.close()
    for a, b in zip(jax.tree.leaves(st0.params),
                    jax.tree.leaves(st1.params)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()

    recs = [json.loads(l) for l in
            (tmp_path / "obs" / "metrics.jsonl").read_text().splitlines()]
    rounds = {r["step"]: r for r in recs if r.get("kind") == "round"}
    comms = {r["step"]: r for r in recs if r.get("kind") == "comm"}
    # the acceptance-criteria join: ONE stream carries, for the same
    # round, measured per-cluster divergence + Lemma 1 + sigma_t +
    # attributed comms
    joined = [s for s in rounds
              if "lemma1_bound" in rounds[s] and s in comms]
    assert joined, (sorted(rounds), sorted(comms))
    s = joined[0]
    r = rounds[s]
    assert len(r["upsilon"]) == 2                       # per-cluster
    assert len(r["lemma1_bound"]) == 2
    assert "sigma_t" in r and "dispersion_bound" in r
    assert comms[s]["d2d_msgs"] > 0
    assert sum(comms[s]["d2d_msgs_by_cluster"].values()) \
        == comms[s]["d2d_msgs"]
    # comm deltas over the stream re-sum to the ledger totals
    assert sum(c["d2d_msgs"] for c in comms.values()) \
        == tr1.ledger.d2d_msgs
    assert sum(c["uplinks"] for c in comms.values()) == tr1.ledger.uplinks

    doc = json.loads((tmp_path / "obs" / "trace.json").read_text())
    assert validate_chrome_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"run", "round", "interval", "resolve"} <= names


# ===========================================================================
# scale mode: trace_dir through TrainerConfig, parity included
# ===========================================================================

def _scale_trainer(trace_dir=None):
    from repro.configs import get_arch
    from repro.core.distributed import TTHFScaleConfig
    from repro.train import ScaleTrainer, TrainerConfig

    cfg = get_arch("qwen1.5-0.5b").reduced(num_layers=1, d_model=32,
                                           d_ff=64, vocab_size=128)
    scale = TTHFScaleConfig(replicas=4, cluster_size=2, tau=2,
                            consensus_every=1, gamma_d2d=1, lr=0.05)
    tcfg = TrainerConfig(batch_per_replica=2, seq_len=8, intervals=2,
                         eval_every=0, prefetch=False,
                         trace_dir=trace_dir)
    return ScaleTrainer(cfg, scale, tcfg).init()


def test_scale_trainer_obs_smoke_and_parity(tmp_path):
    tr0 = _scale_trainer()
    tr0.run()
    tr1 = _scale_trainer(str(tmp_path / "obs"))
    tr1.run()
    tr1.close()
    for a, b in zip(jax.tree.leaves(tr0.params),
                    jax.tree.leaves(tr1.params)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
    d = tmp_path / "obs"
    assert (d / "manifest.json").exists()
    recs = [json.loads(l) for l in
            (d / "metrics.jsonl").read_text().splitlines()]
    kinds = {r.get("kind") for r in recs}
    assert {"round", "comm"} <= kinds
    doc = json.loads((d / "trace.json").read_text())
    assert validate_chrome_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"run", "round", "interval", "consensus_event"} <= names


# ===========================================================================
# serving: per-request records
# ===========================================================================

def test_scheduler_request_records(tmp_path):
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serving import (
        Request, make_scheduler, run_trace)

    cfg = get_arch("qwen1.5-0.5b").reduced(num_layers=1, d_model=32,
                                           d_ff=64, vocab_size=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    obs = make_obs(str(tmp_path / "obs"), run_name="serve")
    sched = make_scheduler("continuous", model, slots=2, max_prompt=8,
                           max_total=8, temperature=0.0, seed=0, obs=obs)
    rng = np.random.default_rng(0)
    arrivals = [(i, Request(rid=i,
                            prompt=rng.integers(1, 250, size=4).astype(
                                np.int32),
                            max_new=3)) for i in range(3)]
    # a zero-budget request: prompt fills the whole cache -> retires at
    # admission with no tokens
    arrivals.append((0, Request(
        rid=99, prompt=rng.integers(1, 250, size=8).astype(np.int32),
        max_new=3)))
    stats = run_trace(sched, params, arrivals)
    obs.close()

    assert stats.requests_done == 4
    assert len(stats.records) == 4
    by_rid = {r.rid: r for r in stats.records}
    zb = by_rid[99]
    assert zb.decode == 0 and zb.budget == 0
    assert zb.first_token == -1 and zb.ttft == -1
    assert zb.retire == zb.admit
    for r in stats.records:
        if r.rid == 99:
            continue
        assert 0 <= r.submit <= r.admit <= r.first_token <= r.retire
        assert r.decode == min(r.budget, 3)
        assert r.queue_latency == r.admit - r.submit
    assert sum(r.decode for r in stats.records) == stats.tokens_generated
    doc = json.loads((tmp_path / "obs" / "trace.json").read_text())
    assert validate_chrome_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"run", "admission", "prefill", "decode_step"} <= names
