"""Topology & consensus-matrix invariants (Assumption 2)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import TopologyConfig
from repro.core import (
    build_network, check_assumption2, complete_adjacency,
    geometric_adjacency, laplacian_weights, metropolis_weights,
    ring_adjacency, spectral_radius,
)


@given(s=st.integers(2, 24))
@settings(max_examples=20, deadline=None)
def test_ring_metropolis_satisfies_assumption2(s):
    adj = ring_adjacency(s)
    v = metropolis_weights(adj)
    check_assumption2(v, adj)


@given(s=st.integers(2, 16))
@settings(max_examples=15, deadline=None)
def test_complete_laplacian_satisfies_assumption2(s):
    adj = complete_adjacency(s)
    v = laplacian_weights(adj)
    check_assumption2(v, adj)


@given(s=st.integers(3, 12), seed=st.integers(0, 1000),
       radius=st.floats(0.5, 1.4))
@settings(max_examples=25, deadline=None)
def test_geometric_graphs_connected_and_valid(s, seed, radius):
    rng = np.random.default_rng(seed)
    adj = geometric_adjacency(s, radius, rng)
    v = metropolis_weights(adj)
    check_assumption2(v, adj)


def test_consensus_matrix_power_converges_to_mean():
    """V^k -> 11^T/s (the defining property behind Lemma 1)."""
    adj = ring_adjacency(5)
    v = metropolis_weights(adj)
    w = np.linalg.matrix_power(v, 200)
    assert np.allclose(w, np.ones((5, 5)) / 5, atol=1e-8)


def test_spectral_radius_decreases_with_density():
    ring = spectral_radius(metropolis_weights(ring_adjacency(8)))
    comp = spectral_radius(metropolis_weights(complete_adjacency(8)))
    assert comp < ring


def test_build_network_paper_config():
    """Paper Sec. IV-A: 125 devices, 25 clusters of 5, avg rho ~ 0.7."""
    net = build_network(TopologyConfig(num_devices=125, num_clusters=25,
                                       graph="geometric",
                                       target_spectral_radius=0.7, seed=3))
    assert net.V.shape == (25, 5, 5)
    assert net.num_devices == 125
    assert abs(net.lambdas.mean() - 0.7) < 0.12
    assert np.allclose(net.varrho.sum(), 1.0)


def test_build_network_ring():
    net = build_network(TopologyConfig(num_devices=16, num_clusters=4,
                                       graph="ring"))
    assert (net.lambdas < 1.0).all()
    # ring of 4: every node has exactly 2 neighbours
    assert (net.adj.sum(-1) == 2).all()
