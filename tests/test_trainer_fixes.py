"""Regression tests for the scale-mode correctness fixes: multi-sample
aggregation matches its billing, checkpoint paths normalize, resume is
bit-for-bit faithful, and dtype strings are validated."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DynamicsConfig, get_arch
from repro.core.distributed import (TTHFScaleConfig, stack_replicas,
                                    weighted_aggregation)
from repro.netsim import faults
from repro.train import ScaleTrainer, TrainerConfig


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_arch("qwen1.5-0.5b").reduced(num_layers=2, d_model=64,
                                            d_ff=128, vocab_size=128)


# ---------------------------------------------------------------------------
# scale-mode multi-sampling: the aggregate must contain exactly the
# models the ledger bills
# ---------------------------------------------------------------------------

def test_weighted_aggregation_uses_all_sampled_models():
    """With sample_per_cluster = k > 1 the (N, s) weight matrix routes
    ALL k picks into the aggregate — parity with the sim path's
    multi-sample eq. (7)."""
    from repro.core import sampling as smp
    N, s, k = 4, 4, 3
    scale = TTHFScaleConfig(replicas=N * s, cluster_size=s,
                            sample_per_cluster=k)
    net = scale.network()
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(N * s, 6)), jnp.float32)}
    picks = np.asarray(smp.sample_devices_multi(
        jax.random.PRNGKey(1), N, s, k))
    counts = np.full(N, k)
    w = faults.aggregation_weights(picks, counts,
                                   np.asarray(net.varrho), s)
    out = weighted_aggregation(params, net, jnp.asarray(w, jnp.float32))
    expect = smp.sampled_global_pytree(
        params, jnp.asarray(picks),
        jnp.asarray(net.varrho, jnp.float32), N)
    for r in range(N * s):
        np.testing.assert_allclose(np.asarray(out["w"][r]),
                                   np.asarray(expect["w"]), atol=1e-6)
    # billing == models entering the aggregate == nonzero weights
    assert int(counts.sum()) == int((w > 0).sum()) == N * k


def test_weighted_aggregation_all_dark_is_identity():
    scale = TTHFScaleConfig(replicas=4, cluster_size=2)
    net = scale.network()
    params = {"w": jnp.asarray(
        np.random.default_rng(0).normal(size=(4, 3)), jnp.float32)}
    out = weighted_aggregation(params, net,
                               jnp.zeros((2, 2), jnp.float32))
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(params["w"]))


def test_dynamic_multi_sampling_ledger_matches_uplinks(tiny_cfg):
    """Link-flapping dynamics (devices all up) with k = 2: every
    interval bills N * k uplinks and the aggregate is the k-average —
    previously only picks[:, 0] entered while N * k was billed."""
    scale = TTHFScaleConfig(replicas=8, cluster_size=2, tau=2,
                            consensus_every=2, gamma_d2d=1, lr=0.05,
                            sample_per_cluster=2)
    dyn = DynamicsConfig(name="flappy", p_link_fail=0.3,
                         p_link_recover=0.5, seed=1)
    tcfg = TrainerConfig(batch_per_replica=2, seq_len=16, intervals=3,
                         eval_every=0, eval_batches=1)
    tr = ScaleTrainer(tiny_cfg, scale, tcfg, dynamics=dyn).init()
    tr.run()
    assert tr.ledger.uplinks == 3 * scale.num_clusters * 2
    for leaf in jax.tree.leaves(tr.params):
        arr = np.asarray(leaf)
        assert np.isfinite(arr).all()
        # aggregation broadcast: replicas agree after every interval
        np.testing.assert_allclose(arr, np.broadcast_to(arr[0:1],
                                                        arr.shape),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# checkpoint path normalization
# ---------------------------------------------------------------------------

def test_extensionless_ckpt_path_roundtrips(tmp_path):
    from repro.checkpoint import restore_pytree, save_pytree
    tree = {"a": np.arange(6).reshape(2, 3), "b": (np.ones(2),)}
    p = str(tmp_path / "state")            # np.savez appends .npz
    save_pytree(p, tree)
    loaded = restore_pytree(p)             # used to FileNotFoundError
    np.testing.assert_array_equal(loaded["a"], tree["a"])
    # explicit .npz keeps working
    save_pytree(str(tmp_path / "s2.npz"), tree)
    loaded2 = restore_pytree(str(tmp_path / "s2.npz"))
    np.testing.assert_array_equal(loaded2["b"][0], tree["b"][0])


# ---------------------------------------------------------------------------
# resume fidelity
# ---------------------------------------------------------------------------

def test_resume_equals_straight_through_run(tmp_path, tiny_cfg):
    """save -> restore -> run must reproduce the uninterrupted run
    exactly: same params, same ledger, no re-trained batches. The PRNG
    key, ledger counters and data-stream offsets all travel in the
    checkpoint's extra dict."""
    scale = TTHFScaleConfig(replicas=4, cluster_size=2, tau=2,
                            consensus_every=2, gamma_d2d=1, lr=0.05)
    tcfg = TrainerConfig(batch_per_replica=2, seq_len=16, intervals=4,
                         eval_every=2, eval_batches=1,
                         ckpt_dir=str(tmp_path))
    straight = ScaleTrainer(tiny_cfg, scale, tcfg).init()
    straight.run(4)

    first = ScaleTrainer(tiny_cfg, scale, tcfg).init()
    first.run(2)
    path = first.save()
    resumed = ScaleTrainer(tiny_cfg, scale, tcfg).restore(path)
    assert resumed.interval == 2
    resumed.run(2)

    for a, b in zip(jax.tree.leaves(straight.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # everything pricing reads must match exactly; the DESIGN.md §13
    # attribution ROWS deliberately don't travel in checkpoints (only
    # the counters + event cursor do), so the resumed ledger holds just
    # its post-resume rows — tagged with the right event indices
    sd = dataclasses.asdict(straight.ledger)
    rd = dataclasses.asdict(resumed.ledger)
    s_rows, r_rows = sd.pop("events"), rd.pop("events")
    assert sd == rd
    assert r_rows == s_rows[-len(r_rows):]

    # in-process rollback: restoring into a trainer whose generators
    # have already advanced must rebuild the streams, not double-skip
    first.run(1)                    # drift past the checkpoint
    first.restore(path)
    first.run(2)
    for a, b in zip(jax.tree.leaves(straight.params),
                    jax.tree.leaves(first.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# dtype validation
# ---------------------------------------------------------------------------

def test_trainer_config_rejects_unknown_dtype():
    with pytest.raises(ValueError, match="float16"):
        TrainerConfig(dtype="float16")     # typo'd: used to mean bf16
    assert TrainerConfig(dtype="bfloat16").dtype == "bfloat16"
    assert TrainerConfig().dtype == "float32"
