"""Ablation: D2D graph topology / consensus-weight scheme vs
convergence (ties Lemma 1's lambda_c to end-to-end behaviour).

Denser graphs (smaller spectral radius rho(V - 11^T/s)) mix faster, so
fewer D2D rounds are needed for the same consensus error — the knob the
paper's Remark 1 turns. Expectation: at fixed Gamma, loss(complete)
<= loss(geometric) <= loss(ring); metropolis ~ laplacian.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import Row, sim_world


def run(scale: str = "ci", seed: int = 0) -> list[Row]:
    from repro.configs import TopologyConfig, TTHFConfig
    from repro.core import TTHFTrainer, build_network

    data, topo_base, model, steps = sim_world(scale, seed)
    steps = min(steps, 150)
    algo = TTHFConfig(tau=20, consensus_every=5, gamma_d2d=1,
                      constant_lr=0.002)
    rows, finals, lambdas = [], {}, {}
    for graph, weights in (("ring", "metropolis"),
                           ("geometric", "metropolis"),
                           ("geometric", "laplacian"),
                           ("complete", "metropolis")):
        topo = dataclasses.replace(topo_base, graph=graph, weights=weights)
        net = build_network(topo)
        tr = TTHFTrainer(model, data, topo, algo, batch_size=16)
        _, hist = tr.run(steps=steps, eval_every=steps, seed=seed)
        name = f"{graph}_{weights}"
        finals[name] = hist.global_loss[-1]
        lambdas[name] = float(net.lambdas.mean())
        rows.append(Row(f"topology/{name}", 0.0,
                        f"lambda={lambdas[name]:.3f};"
                        f"loss={finals[name]:.4f};"
                        f"consensus_err={hist.consensus_err[-1]:.2e}"))

    # NOTE (measured): a 5-node ring mixes BETTER (lambda~0.54) than
    # geometric graphs *tuned to the paper's rho=0.7 target* — the
    # tuning target, not density, is binding at s=5. Claims reflect
    # that: complete < ring in lambda; geometric ~ 0.7 by construction;
    # smaller lambda never hurts the loss.
    lam_ordered = (lambdas["complete_metropolis"]
                   < lambdas["ring_metropolis"] < 1.0)
    target_hit = abs(lambdas["geometric_metropolis"] - 0.7) < 0.1
    ordered = (finals["complete_metropolis"]
               <= min(finals["geometric_metropolis"],
                      finals["ring_metropolis"]) + 5e-3)
    rows.append(Row("topology/claims", 0.0,
                    f"complete_mixes_fastest={lam_ordered};"
                    f"geometric_tuned_to_paper_target={target_hit};"
                    f"smaller_lambda_not_worse_loss={ordered}"))
    return rows
