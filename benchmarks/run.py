"""Benchmark harness entrypoint — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Default scale "ci" fits
this CPU box; ``--scale paper`` runs the Sec.-IV configuration
(125 devices / 25 clusters / Fashion-synth 70k).

  PYTHONPATH=src python -m benchmarks.run [--scale ci] [--only fig4,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = ("fig4_gamma", "fig5_tau", "fig6_energy", "theory_bound",
          "kernel_bench", "scale_sync", "topology_ablation", "roofline",
          "dynamics_bench", "hierarchy_bench", "rounds_bench",
          "serving_bench")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["ci", "paper"], default="ci")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    chosen = (args.only.split(",") if args.only else SUITES)
    print("name,us_per_call,derived")
    rc = 0
    for suite in chosen:
        mod_name = suite if suite in SUITES else f"{suite}"
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            t0 = time.time()
            rows = mod.run(scale=args.scale, seed=args.seed)
            for row in rows:
                print(row.csv())
            print(f"_suite/{suite},{(time.time()-t0)*1e6:.0f},ok",
                  flush=True)
        except Exception as e:  # noqa: BLE001 — report, keep sweeping
            rc = 1
            print(f"_suite/{suite},0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    return rc


if __name__ == "__main__":
    sys.exit(main())
