"""Benchmark harness entrypoint — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Default scale "ci" fits
this CPU box; ``--scale paper`` runs the Sec.-IV configuration
(125 devices / 25 clusters / Fashion-synth 70k).

  PYTHONPATH=src python -m benchmarks.run [--scale ci] [--only fig4,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = ("fig4_gamma", "fig5_tau", "fig6_energy", "theory_bound",
          "kernel_bench", "scale_sync", "topology_ablation", "roofline",
          "dynamics_bench", "hierarchy_bench", "rounds_bench",
          "serving_bench", "obs_overhead")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["ci", "paper"], default="ci")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-dir", default=None,
                    help="write a run manifest (config hash, git SHA, "
                         "mesh) into this dir before sweeping")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the sweep in jax.profiler.trace "
                         "(requires --trace-dir)")
    args = ap.parse_args(argv)

    chosen = (args.only.split(",") if args.only else SUITES)
    if args.trace_dir:
        from repro.obs.manifest import write_manifest
        write_manifest(args.trace_dir,
                       config={"scale": args.scale, "seed": args.seed,
                               "suites": list(chosen)},
                       extra={"run": "benchmarks"})
    if args.profile and args.trace_dir:
        from repro.obs.trace import profiler_trace
        prof = profiler_trace(args.trace_dir)
    else:
        from contextlib import nullcontext
        prof = nullcontext()
    print("name,us_per_call,derived")
    rc = 0
    with prof:
        for suite in chosen:
            mod_name = suite if suite in SUITES else f"{suite}"
            try:
                mod = __import__(f"benchmarks.{mod_name}",
                                 fromlist=["run"])
                t0 = time.time()
                rows = mod.run(scale=args.scale, seed=args.seed)
                for row in rows:
                    print(row.csv())
                print(f"_suite/{suite},{(time.time()-t0)*1e6:.0f},ok",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — report, keep sweeping
                rc = 1
                print(f"_suite/{suite},0,ERROR:{type(e).__name__}:{e}")
                traceback.print_exc(file=sys.stderr)
    return rc


if __name__ == "__main__":
    sys.exit(main())
