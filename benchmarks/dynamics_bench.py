"""Netsim scenario sweep: convergence + energy/delay per scenario.

Runs the Sec.-IV simulation under every registry scenario
(``repro.netsim.scenarios``) with identical model/data/topology/
schedule, and records the full trajectories — loss/accuracy at each
eval point plus the priced communication energy and straggler-aware
delay — to ``BENCH_dynamics.json``. The ``static`` row doubles as the
regression anchor: it must match the historical (pre-netsim)
trajectory exactly.

Row ``derived`` format (CSV-safe, '|' separated trajectories):
  final_loss=..;final_acc=..;energy_J=..;delay_s=..;
  ts=t1|t2|..;loss=l1|l2|..;uplinks=u1|u2|..
"""
from __future__ import annotations

import time

from benchmarks.common import Row, append_trajectory, sim_world

LR = 0.002
E_RATIO = 0.1   # E_D2D / E_Glob (the 5G-ish operating point [17])
D_RATIO = 0.1


def _traj(vals, fmt="{:.4f}") -> str:
    return "|".join(fmt.format(v) for v in vals)


def run(scale: str = "ci", seed: int = 0) -> list[Row]:
    from repro.configs import TTHFConfig
    from repro.core import TTHFTrainer
    from repro.netsim import scenarios

    data, topo, model, steps = sim_world(scale, seed)
    steps = steps if scale == "paper" else 100
    algo = TTHFConfig(tau=20, consensus_every=5, gamma_d2d=2,
                      constant_lr=LR)

    rows = []
    for name in scenarios.names():
        dyn = scenarios.get(name, seed=seed)
        tr = TTHFTrainer(model, data, topo, algo, batch_size=16,
                         dynamics=dyn)
        # single timed run (no warmup repeat: the ledger must count ONE
        # trajectory's communication, and this is a convergence bench)
        t0 = time.perf_counter()
        _, hist = tr.run(steps=steps, eval_every=5, seed=seed)
        us = (time.perf_counter() - t0) * 1e6
        e = tr.ledger.energy(E_RATIO)
        d = tr.ledger.delay(D_RATIO)
        rows.append(Row(
            f"dynamics/{name}", us,
            f"final_loss={hist.global_loss[-1]:.4f};"
            f"final_acc={hist.global_acc[-1]:.4f};"
            f"energy_J={e:.3f};delay_s={d:.2f};"
            f"uplinks={tr.ledger.uplinks};"
            f"d2d_msgs={tr.ledger.d2d_msgs};"
            f"straggler_extra_s="
            f"{tr.ledger.straggler_uplink_extra:.2f}up+"
            f"{tr.ledger.straggler_round_extra:.2f}rd;"
            f"ts={_traj(hist.ts, '{:d}')};"
            f"loss={_traj(hist.global_loss)};"
            f"acc={_traj(hist.global_acc)};"
            f"active={_traj(hist.active_devices, '{:d}')}"))

    # claim rows: dynamics should cost, static should anchor
    by = {r.name.split("/")[1]: r for r in rows}
    static_loss = float(by["static"].derived.split(";")[0].split("=")[1])
    churn_loss = float(by["device_churn"].derived.split(";")[0]
                       .split("=")[1])
    rows.append(Row("dynamics/claims", 0.0,
                    f"static_final={static_loss:.4f};"
                    f"churn_degrades={churn_loss >= static_loss - 0.02}"))
    append_trajectory("dynamics", rows, scale)
    return rows
