"""Shared benchmark harness utilities.

Each benchmark module exposes ``run(scale: str) -> list[Row]`` where
scale is "ci" (fits this 1-core CPU box in ~minutes) or "paper" (the
Sec.-IV configuration: 125 devices, 25 clusters). Rows are printed by
run.py as ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from dataclasses import dataclass


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def append_trajectory(name: str, rows: list, scale: str,
                      out_dir: str = "benchmarks/results") -> str:
    """Append one record to the ``BENCH_<name>.json`` trajectory.

    The trajectory is a JSON list, one record per benchmark run
    ({unix_ts, scale, rows}) — the machine-readable history that lets a
    PR show whether its hot path got faster. Returns the file path.
    """
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    os.makedirs(out_dir, exist_ok=True)
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (OSError, json.JSONDecodeError):
            # keep the unreadable file aside instead of clobbering it
            os.replace(path, path + ".corrupt")
            history = []
    history.append({
        "unix_ts": int(time.time()),
        "scale": scale,
        "rows": [dataclasses.asdict(r) for r in rows],
    })
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(history, f, indent=1)
    os.replace(tmp, path)   # atomic: a killed run cannot truncate history
    return path


def timed(fn, *args, repeats: int = 3, **kw):
    """(result, us_per_call) with one warmup."""
    fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def sim_world(scale: str, seed: int = 0):
    """The Sec.-IV experimental setup (or a CI-sized version of it)."""
    from repro.configs import TopologyConfig
    from repro.data import fashion_synth, partition_noniid_labels
    from repro.models import make_sim_model

    if scale == "paper":
        devices, clusters, points, steps = 125, 25, 70_000, 600
    else:
        devices, clusters, points, steps = 25, 5, 6_000, 150
    x, y = fashion_synth(num_points=points, seed=seed)
    data = partition_noniid_labels(x, y, num_devices=devices,
                                   labels_per_device=3, seed=seed)
    topo = TopologyConfig(num_devices=devices, num_clusters=clusters,
                          graph="geometric",
                          target_spectral_radius=0.7, seed=seed)
    svm = make_sim_model("svm", data.feature_dim, data.num_classes)
    return data, topo, svm, steps
