"""Fig. 5 reproduction: increasing the local interval tau (fewer
uplinks) counteracted by more D2D rounds Gamma.

Claim (C2): TT-HF with larger tau + larger Gamma still outperforms FL
tau=20 while using a LOWER frequency of global aggregations.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import Row, sim_world

LR = 0.002
# (tau, Gamma) pairs per the paper: Gamma grows with tau
SWEEP = ((20, 2), (40, 4), (60, 6))


def run(scale: str = "ci", seed: int = 0) -> list[Row]:
    from repro.configs import TTHFConfig
    from repro.core import TTHFTrainer, make_baseline_config

    data, topo, model, steps = sim_world(scale, seed)
    steps = max(steps, 120)
    rows, results = [], {}

    def train(name, algo):
        tr = TTHFTrainer(model, data, topo, algo, batch_size=16)
        t0 = time.perf_counter()
        _, hist = tr.run(steps=steps, eval_every=max(steps // 10, 1),
                         seed=seed)
        us = (time.perf_counter() - t0) / steps * 1e6
        results[name] = (hist, tr.ledger)
        rows.append(Row(
            f"fig5/{name}", us,
            f"loss={hist.global_loss[-1]:.4f};acc={hist.global_acc[-1]:.4f};"
            f"uplinks={tr.ledger.uplinks}"))

    train("fl_tau20", dataclasses.replace(
        make_baseline_config("fedavg", 20), constant_lr=LR))
    for tau, g in SWEEP:
        train(f"tthf_tau{tau}_g{g}", TTHFConfig(
            tau=tau, consensus_every=5, gamma_d2d=g, constant_lr=LR))

    l = {k: v[0].global_loss[-1] for k, v in results.items()}
    u = {k: v[1].uplinks for k, v in results.items()}
    beats = all(l[f"tthf_tau{t}_g{g}"] < l["fl_tau20"] + 5e-3
                for t, g in SWEEP)
    fewer = all(u[f"tthf_tau{t}_g{g}"] < u["fl_tau20"] for t, g in SWEEP)
    rows.append(Row("fig5/claims", 0.0,
                    f"larger_tau_counteracted_by_gamma={beats};"
                    f"fewer_uplinks={fewer}"))
    return rows
