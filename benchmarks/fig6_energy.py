"""Fig. 6 reproduction: total energy / delay to reach a target accuracy
under varying E_D2D/E_Glob and Delta_D2D/Delta_Glob ratios.

Claims (C3): TT-HF (tau=40, aperiodic Remark-1 consensus) reaches the
accuracy target with less energy/delay than (i) FL tau=1 full
participation and (ii) FL tau=20 one-device-per-cluster sampling, for
small ratios; the advantage narrows as the ratio grows; the crossover
sits well above the ~0.1 observed in 5G systems [17].
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import Row, sim_world

LR = 0.002
RATIOS = (0.01, 0.1, 0.5, 1.0)
TARGET_FRAC = 0.6   # "60% of peak accuracy" per the paper


def _steps_to_target(hist, target):
    for t, acc in zip(hist.ts, hist.global_acc):
        if acc >= target:
            return t
    return None


def run(scale: str = "ci", seed: int = 0) -> list[Row]:
    from repro.configs import TTHFConfig
    from repro.core import TTHFTrainer, make_baseline_config

    data, topo, model, steps = sim_world(scale, seed)
    # NN in the paper; SVM here at CI scale for speed (same mechanics —
    # the paper notes "similar results for SVM"); paper scale uses NN.
    if scale == "paper":
        from repro.models import make_sim_model
        model = make_sim_model("nn", data.feature_dim, data.num_classes,
                               hidden=7840)

    rows = []
    runs = {}

    def train(name, algo):
        tr = TTHFTrainer(model, data, topo, algo, batch_size=16)
        _, hist = tr.run(steps=steps, eval_every=5, seed=seed)
        runs[name] = (hist, tr)

    # NOTE: with a constant step size the Remark-1 rule never relaxes
    # (Upsilon stays O(1) -> Gamma pinned at the cap), which buries the
    # energy win under D2D cost; the paper's regime is few, cheap
    # rounds — fixed Gamma=2 here (tau=20; the paper's tau=40 + decaying
    # eta behaves the same directionally but needs ~4x the steps to hit
    # the accuracy target at CI scale).
    train("tthf_tau40", TTHFConfig(tau=20, consensus_every=5,
                                   gamma_d2d=2, constant_lr=LR))
    train("fl_tau1_full", dataclasses.replace(
        make_baseline_config("centralized", 1), constant_lr=LR))
    # FL with cluster sampling, tau=20, no D2D
    train("fl_tau20_sampled", TTHFConfig(
        tau=20, consensus_every=0, gamma_d2d=0, constant_lr=LR,
        mode="tthf", full_participation=False))

    peak = max(max(h.global_acc) for h, _ in runs.values())
    target = TARGET_FRAC * peak
    wins_e, wins_d = [], []
    for name, (hist, tr) in runs.items():
        t_hit = _steps_to_target(hist, target)
        # ledger counts at the end of the full run are proportional to
        # per-step costs; rescale to the target-hit step
        frac = (t_hit / hist.ts[-1]) if t_hit else np.nan
        for r in RATIOS:
            e = tr.ledger.energy(r) * frac
            d = tr.ledger.delay(r) * frac
            rows.append(Row(f"fig6/{name}/ratio{r}", 0.0,
                            f"steps_to_{TARGET_FRAC:.0%}={t_hit};"
                            f"energy_J={e:.2f};delay_s={d:.1f}"))
            if name == "tthf_tau40" and t_hit:
                wins_e.append((r, e))
                wins_d.append((r, d))

    # claim: at small ratios TT-HF cheaper than fl_tau1_full
    def cost(name, r, kind):
        hist, tr = runs[name]
        t_hit = _steps_to_target(hist, target)
        if not t_hit:
            return np.inf
        frac = t_hit / hist.ts[-1]
        return (tr.ledger.energy(r) if kind == "e"
                else tr.ledger.delay(r)) * frac

    cheap_win = cost("tthf_tau40", 0.01, "e") < cost("fl_tau1_full", 0.01, "e")
    gap_small = cost("fl_tau1_full", 0.01, "e") - cost("tthf_tau40", 0.01, "e")
    gap_big = cost("fl_tau1_full", 1.0, "e") - cost("tthf_tau40", 1.0, "e")
    rows.append(Row("fig6/claims", 0.0,
                    f"tthf_cheaper_at_small_ratio={cheap_win};"
                    f"advantage_narrows={gap_big < gap_small}"))
    return rows
