"""Scale-mode sync-strategy comparison: TT-HF vs star (FedAvg) vs
local-only, on a reduced model-zoo arch — validates that the paper's
technique transfers to the transformer training path, and compares the
consensus backends of the unified engine (``core/mixing.py``): the
paper-faithful ``rounds`` (-> reference) sequential exchanges, the
``masked_loop`` bounded loop, and the beyond-paper ``fused``
(-> fused_power) build-time V^Gamma variant (identical losses, fewer
collectives).

Raw-speed rows (DESIGN.md §12): ``tthf_fused_interval`` times the flat
(R, P) carrier step with donated buffers, and the ``trainer_straight``
vs ``trainer_fast`` pair times the full ScaleTrainer loop with every
speed knob off vs on (donation + fused interval + prefetch) — the
trajectories are bitwise identical, only the clock moves.

Timing discipline: every row runs ONE excluded warmup interval (jit
compilation used to land in interval 0 and dominate the mean) and
fences with ``block_until_ready`` on both sides of the timed loop.
Per-row timings are appended to the
``benchmarks/results/BENCH_scale_sync.json`` trajectory.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import Row, append_trajectory


def _prev_tthf_fused_us(out_dir: str = "benchmarks/results"):
    """us/interval of the last PRE-§12 ``tthf_fused`` row (a record
    with no ``tthf_fused_interval`` row). Those records had no warmup
    exclusion, so interval 0 includes jit compile time — that row is
    what this run's warmup-excluded fast path is compared against in
    the claims; later §12-era records would only measure run-to-run
    noise."""
    path = os.path.join(out_dir, "BENCH_scale_sync.json")
    try:
        with open(path) as f:
            hist = json.load(f)
    except (OSError, ValueError):
        return None
    for rec in reversed(hist):
        names = {row.get("name") for row in rec.get("rows", [])}
        if "scale_sync/tthf_fused_interval" in names:
            continue
        for row in rec.get("rows", []):
            if row.get("name") == "scale_sync/tthf_fused":
                return float(row["us_per_call"])
    return None


def run(scale: str = "ci", seed: int = 0) -> list[Row]:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.core.distributed import (
        TTHFScaleConfig, make_tthf_train_step, stack_replicas)
    from repro.models import build_model
    from repro.train import ScaleTrainer, TrainerConfig

    cfg = get_arch("qwen1.5-0.5b").reduced(num_layers=2, d_model=128,
                                           d_ff=256, vocab_size=512)
    model = build_model(cfg)
    R, s, tau = 4, 2, 4
    intervals = 4 if scale == "ci" else 12
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (tau, R, 2, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    # the same pick sequence for every mode (drawn once, outside timing)
    kk = jax.random.PRNGKey(seed + 1)
    picks_per_interval = []
    for _ in range(intervals):
        kk, kp = jax.random.split(kk)
        picks_per_interval.append(kp)

    def timed_intervals(step, params0, num_clusters):
        """(losses, us/interval): one EXCLUDED warmup interval (compile
        + first execute, on copies so a donating step cannot invalidate
        params0), then the timed loop fenced with block_until_ready."""
        picks = [jax.random.randint(k, (num_clusters,), 0, s)
                 for k in picks_per_interval]
        warm = step(jax.tree.map(jnp.copy, params0), batch, picks[0],
                    jnp.asarray(0))
        jax.block_until_ready(warm)
        p = params0
        jax.block_until_ready((p, batch))
        losses = []
        t0 = time.perf_counter()
        for i in range(intervals):
            p, loss = step(p, batch, picks[i], jnp.asarray(i))
            losses.append(loss)
        jax.block_until_ready((p, losses))
        us = (time.perf_counter() - t0) / intervals * 1e6
        return [float(x) for x in losses], us

    rows = []
    losses_by_mode = {}
    us_by_mode = {}
    for sync, cmode in (("tthf", "fused"), ("tthf", "rounds"),
                        ("tthf", "masked_loop"),
                        ("star", "fused"), ("local", "fused")):
        scale_cfg = TTHFScaleConfig(replicas=R, cluster_size=s, tau=tau,
                                    consensus_every=2, gamma_d2d=2,
                                    lr=0.05, consensus_mode=cmode)
        step, net = make_tthf_train_step(model, scale_cfg,
                                         dtype=jnp.float32, sync=sync)
        params = stack_replicas(model.init(jax.random.PRNGKey(0)), R)
        losses, us = timed_intervals(jax.jit(step), params,
                                     net.num_clusters)
        name = f"{sync}_{cmode}" if sync == "tthf" else sync
        losses_by_mode[name] = losses
        us_by_mode[name] = us
        rows.append(Row(f"scale_sync/{name}", us,
                        f"loss0={losses[0]:.4f};lossN={losses[-1]:.4f}"))

    # the §12 fast path: flat (R, P) carrier + donated param buffer
    # (bitwise the tthf_fused trajectory — asserted in claims below)
    scale_cfg = TTHFScaleConfig(replicas=R, cluster_size=s, tau=tau,
                                consensus_every=2, gamma_d2d=2, lr=0.05,
                                consensus_mode="fused")
    step, net = make_tthf_train_step(model, scale_cfg, dtype=jnp.float32,
                                     sync="tthf", fused_interval=True)
    flat0 = step.spec.flatten(
        stack_replicas(model.init(jax.random.PRNGKey(0)), R))
    losses, us = timed_intervals(jax.jit(step, donate_argnums=(0,)),
                                 flat0, net.num_clusters)
    losses_by_mode["tthf_fused_interval"] = losses
    us_by_mode["tthf_fused_interval"] = us
    rows.append(Row("scale_sync/tthf_fused_interval", us,
                    f"loss0={losses[0]:.4f};lossN={losses[-1]:.4f}"))

    # full trainer loop, speed knobs off vs on (donate + fused interval
    # + prefetch). Same seeds -> the two runs must land on bitwise-
    # identical params; only the wall clock may differ.
    def make_trainer(fast: bool) -> ScaleTrainer:
        return ScaleTrainer(
            cfg,
            TTHFScaleConfig(replicas=R, cluster_size=s, tau=tau,
                            consensus_every=2, gamma_d2d=2, lr=0.05,
                            consensus_mode="fused"),
            TrainerConfig(batch_per_replica=2, seq_len=64, eval_every=0,
                          dtype="float32", seed=seed, donate=fast,
                          fused_interval=fast, prefetch=fast))

    t_us, final = {}, {}
    for label, fast in (("trainer_straight", False), ("trainer_fast",
                                                      True)):
        tr = make_trainer(fast).init()
        tr.run(1)                          # warmup interval (excluded)
        jax.block_until_ready(tr.params)
        t0 = time.perf_counter()
        tr.run(intervals)
        jax.block_until_ready(tr.params)
        t_us[label] = (time.perf_counter() - t0) / intervals * 1e6
        final[label] = (tr._spec.unflatten(tr.params)
                        if tr._spec is not None else tr.params)
        rows.append(Row(f"scale_sync/{label}", t_us[label],
                        f"intervals={intervals};"
                        f"donate={fast};fused={fast};prefetch={fast}"))

    fast_bitwise = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(final["trainer_straight"]),
                        jax.tree.leaves(final["trainer_fast"])))
    fast_speedup = t_us["trainer_straight"] / t_us["trainer_fast"]

    # fused == rounds (same math)
    d = max(abs(a - b) for a, b in zip(losses_by_mode["tthf_fused"],
                                       losses_by_mode["tthf_rounds"]))
    d_loop = max(abs(a - b)
                 for a, b in zip(losses_by_mode["tthf_fused"],
                                 losses_by_mode["tthf_masked_loop"]))
    d_flat = max(abs(a - b)
                 for a, b in zip(losses_by_mode["tthf_fused"],
                                 losses_by_mode["tthf_fused_interval"]))
    prev = _prev_tthf_fused_us()
    vs_prev = (prev / us_by_mode["tthf_fused_interval"]
               if prev else float("nan"))
    rows.append(Row("scale_sync/claims", 0.0,
                    f"fused_equals_rounds={d < 1e-4};"
                    f"fused_equals_masked_loop={d_loop < 1e-4};"
                    f"fused_interval_bitwise={d_flat == 0.0};"
                    f"fast_params_bitwise={fast_bitwise};"
                    f"fast_trainer_speedup={fast_speedup:.2f}x;"
                    f"fused_interval_vs_prev_fused_row={vs_prev:.2f}x;"
                    f"tthf_trains={losses_by_mode['tthf_fused'][-1] < losses_by_mode['tthf_fused'][0]}"))
    append_trajectory("scale_sync", rows, scale)
    return rows
