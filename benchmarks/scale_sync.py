"""Scale-mode sync-strategy comparison: TT-HF vs star (FedAvg) vs
local-only, on a reduced model-zoo arch — validates that the paper's
technique transfers to the transformer training path, and compares the
consensus backends of the unified engine (``core/mixing.py``): the
paper-faithful ``rounds`` (-> reference) sequential exchanges, the
``masked_loop`` bounded loop, and the beyond-paper ``fused``
(-> fused_power) build-time V^Gamma variant (identical losses, fewer
collectives).  Per-backend interval timings are appended to the
``benchmarks/results/BENCH_scale_sync.json`` trajectory.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, append_trajectory


def run(scale: str = "ci", seed: int = 0) -> list[Row]:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.core.distributed import (
        TTHFScaleConfig, make_tthf_train_step, stack_replicas)
    from repro.models import build_model

    cfg = get_arch("qwen1.5-0.5b").reduced(num_layers=2, d_model=128,
                                           d_ff=256, vocab_size=512)
    model = build_model(cfg)
    R, s, tau = 4, 2, 4
    intervals = 4 if scale == "ci" else 12
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (tau, R, 2, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    rows = []
    losses_by_mode = {}
    for sync, cmode in (("tthf", "fused"), ("tthf", "rounds"),
                        ("tthf", "masked_loop"),
                        ("star", "fused"), ("local", "fused")):
        scale_cfg = TTHFScaleConfig(replicas=R, cluster_size=s, tau=tau,
                                    consensus_every=2, gamma_d2d=2,
                                    lr=0.05, consensus_mode=cmode)
        step, net = make_tthf_train_step(model, scale_cfg,
                                         dtype=jnp.float32, sync=sync)
        step = jax.jit(step)
        params = stack_replicas(model.init(jax.random.PRNGKey(0)), R)
        kk = jax.random.PRNGKey(seed + 1)
        losses = []
        t0 = time.perf_counter()
        for i in range(intervals):
            kk, kp = jax.random.split(kk)
            picks = jax.random.randint(kp, (net.num_clusters,), 0, s)
            params, loss = step(params, batch, picks, jnp.asarray(i))
            losses.append(float(loss))
        us = (time.perf_counter() - t0) / intervals * 1e6
        name = f"{sync}_{cmode}" if sync == "tthf" else sync
        losses_by_mode[name] = losses
        rows.append(Row(f"scale_sync/{name}", us,
                        f"loss0={losses[0]:.4f};lossN={losses[-1]:.4f}"))

    # fused == rounds (same math)
    d = max(abs(a - b) for a, b in zip(losses_by_mode["tthf_fused"],
                                       losses_by_mode["tthf_rounds"]))
    d_loop = max(abs(a - b)
                 for a, b in zip(losses_by_mode["tthf_fused"],
                                 losses_by_mode["tthf_masked_loop"]))
    rows.append(Row("scale_sync/claims", 0.0,
                    f"fused_equals_rounds={d < 1e-4};"
                    f"fused_equals_masked_loop={d_loop < 1e-4};"
                    f"tthf_trains={losses_by_mode['tthf_fused'][-1] < losses_by_mode['tthf_fused'][0]}"))
    append_trajectory("scale_sync", rows, scale)
    return rows
