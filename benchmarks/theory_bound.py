"""Theorem-2 bound vs measured loss gap (O(1/t) validation).

Runs TT-HF with the prescribed schedules (eta_t = gamma/(t+alpha),
adaptive Remark-1 consensus targeting eps^(t) = eta_t * phi) on the
strongly-convex SVM and reports the measured gap alongside the
nu/(t+alpha) envelope.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, sim_world


def run(scale: str = "ci", seed: int = 0) -> list[Row]:
    from repro.configs import TopologyConfig, TTHFConfig
    from repro.core import TTHFTrainer, bound_curve
    from repro.data import fashion_synth, partition_noniid_labels
    from repro.models import make_sim_model

    # unit-norm features -> beta = O(1): Theorem-2 conditions
    # (gamma > 1/mu, alpha ~ gamma beta^2/mu) are exactly satisfiable.
    if scale == "paper":
        devices, clusters, points, steps = 125, 25, 70_000, 1200
    else:
        devices, clusters, points, steps = 25, 5, 2_500, 600
    x, y = fashion_synth(num_points=points, seed=seed, unit_norm=True)
    data = partition_noniid_labels(x, y, num_devices=devices, seed=seed)
    topo = TopologyConfig(num_devices=devices, num_clusters=clusters,
                          graph="geometric", seed=seed)
    model = make_sim_model("svm", data.feature_dim, data.num_classes)
    algo = TTHFConfig(tau=10, consensus_every=5, gamma_d2d=-1, phi=0.05,
                      gamma=20.0, alpha=1000.0)
    tr = TTHFTrainer(model, data, topo, algo, batch_size=16)
    _, hist = tr.run(steps=steps, eval_every=max(steps // 10, 1),
                     seed=seed)
    ts = np.asarray(hist.ts, float)
    loss = np.asarray(hist.global_loss)
    f_star = loss.min() - 1e-3
    gap = loss - f_star
    nu_fit = gap[0] * (ts[0] + algo.alpha)
    env = bound_curve(1.5 * nu_fit, algo.alpha, ts)
    inside = bool((gap[1:] <= env[1:]).all())
    # rate check: gap roughly halves when (t+alpha) doubles
    i0 = 0
    t2 = 2 * (ts[i0] + algo.alpha) - algo.alpha
    i2 = int(np.argmin(np.abs(ts - t2)))
    ratio = gap[i2] / gap[i0] if gap[i0] > 0 else np.nan
    rows = [Row("theory/o1_over_t", 0.0,
                f"envelope_holds={inside};gap_ratio_at_2x_t={ratio:.2f};"
                f"nu_fit={nu_fit:.1f};alpha={algo.alpha}")]
    for t, g_, e_ in zip(ts[::2], gap[::2], env[::2]):
        rows.append(Row(f"theory/gap_t{int(t)}", 0.0,
                        f"measured={g_:.4f};bound={e_:.4f}"))
    return rows
