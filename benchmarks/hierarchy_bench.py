"""Fog-hierarchy depth sweep: convergence + level-tagged comms per L.

Runs the Sec.-IV simulation at every hierarchy depth L in {2, 3, 4}
over the same model/data/topology/schedule (flat L = 2 is today's
TT-HF and doubles as the regression anchor), and records the full
trajectories — loss/accuracy at each eval point plus the priced
communication energy, straggler-aware delay, and the per-level uplink
split — to ``BENCH_hierarchy.json``. A second sweep repeats L = 3
under device churn to show dark-subtree renormalization costing fewer
uplinks rather than correctness.

Row ``derived`` format (CSV-safe, '|' separated trajectories):
  final_loss=..;final_acc=..;energy_J=..;delay_s=..;
  uplinks=..;uplinks_L<l>=..;ts=..|..;loss=..|..
"""
from __future__ import annotations

import time

from benchmarks.common import Row, append_trajectory

LR = 0.002
E_RATIO = 0.1   # E_D2D / E_Glob (the 5G-ish operating point [17])
D_RATIO = 0.1

PRESETS = {2: "flat", 3: "fog3", 4: "fog4"}


def _traj(vals, fmt="{:.4f}") -> str:
    return "|".join(fmt.format(v) for v in vals)


def _world(scale: str, seed: int):
    """A hierarchy-friendly fleet: the cluster count must factor into
    every swept depth (8 = 2*2*2 serves L in {2, 3, 4})."""
    from repro.configs import TopologyConfig
    from repro.data import fashion_synth, partition_noniid_labels
    from repro.models import make_sim_model

    if scale == "paper":
        devices, clusters, points, steps = 120, 24, 60_000, 600
    else:
        devices, clusters, points, steps = 24, 8, 4_800, 100
    x, y = fashion_synth(num_points=points, seed=seed)
    data = partition_noniid_labels(x, y, num_devices=devices,
                                   labels_per_device=3, seed=seed)
    topo = TopologyConfig(num_devices=devices, num_clusters=clusters,
                          graph="geometric",
                          target_spectral_radius=0.7, seed=seed)
    svm = make_sim_model("svm", data.feature_dim, data.num_classes)
    return data, topo, svm, steps


def _one(name, data, topo, model, algo, steps, seed, hierarchy, dynamics):
    from repro.core import TTHFTrainer

    tr = TTHFTrainer(model, data, topo, algo, batch_size=16,
                     dynamics=dynamics, hierarchy=hierarchy)
    t0 = time.perf_counter()
    _, hist = tr.run(steps=steps, eval_every=5, seed=seed)
    us = (time.perf_counter() - t0) * 1e6
    led = tr.ledger
    by_level = "".join(f";uplinks_L{l}={n}" for l, n in
                       sorted(led.uplinks_by_level.items()))
    return Row(
        f"hierarchy/{name}", us,
        f"final_loss={hist.global_loss[-1]:.4f};"
        f"final_acc={hist.global_acc[-1]:.4f};"
        f"energy_J={led.energy(E_RATIO):.3f};"
        f"delay_s={led.delay(D_RATIO):.2f};"
        f"uplinks={led.uplinks}{by_level};"
        f"d2d_msgs={led.d2d_msgs};"
        f"ts={_traj(hist.ts, '{:d}')};"
        f"loss={_traj(hist.global_loss)};"
        f"acc={_traj(hist.global_acc)}")


def run(scale: str = "ci", seed: int = 0) -> list[Row]:
    from repro.configs import TTHFConfig
    from repro.hierarchy import presets
    from repro.netsim import scenarios

    data, topo, model, steps = _world(scale, seed)
    algo = TTHFConfig(tau=20, consensus_every=5, gamma_d2d=2,
                      constant_lr=LR)

    rows = []
    for levels, preset in PRESETS.items():
        hier = presets.get(preset, tau=algo.tau)
        rows.append(_one(f"L{levels}", data, topo, model, algo, steps,
                         seed, hier, None))
    # depth under weather: dark subtrees renormalize, uplinks shrink
    rows.append(_one("L3_churn", data, topo, model, algo, steps, seed,
                     presets.get("fog3", tau=algo.tau),
                     scenarios.get("device_churn", seed=seed)))

    # claim rows: the root tier gets rarer with depth, so total uplink
    # traffic must not grow; churn must not inflate it either
    def _uplinks(row):
        return int(dict(kv.split("=") for kv in
                        row.derived.split(";") if "=" in kv)["uplinks"])
    by = {r.name.split("/")[1]: r for r in rows}
    rows.append(Row(
        "hierarchy/claims", 0.0,
        f"flat_uplinks={_uplinks(by['L2'])};"
        f"depth_saves_root_traffic="
        f"{_uplinks(by['L3']) <= 2 * _uplinks(by['L2'])};"
        f"churn_cheaper={_uplinks(by['L3_churn']) <= _uplinks(by['L3'])}"))
    append_trajectory("hierarchy", rows, scale)
    return rows
