"""Fig. 4 reproduction: TT-HF vs FL baselines, sweeping the number of
D2D consensus rounds Gamma.

Paper claims validated here (EXPERIMENTS.md C1):
  * TT-HF (tau=20, Gamma>0) beats FL tau=20 despite 5x fewer uplinks;
  * increasing Gamma improves accuracy/loss with diminishing returns,
    approaching the FL tau=1 (centralized-like) upper bound.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import Row, sim_world

LR = 0.002
TAU = 20
GAMMAS = (0, 1, 2, 4, 8)


def run(scale: str = "ci", seed: int = 0) -> list[Row]:
    from repro.configs import TTHFConfig
    from repro.core import TTHFTrainer, make_baseline_config

    data, topo, model, steps = sim_world(scale, seed)
    rows = []
    results = {}

    def train(name, algo):
        tr = TTHFTrainer(model, data, topo, algo, batch_size=16)
        t0 = time.perf_counter()
        _, hist = tr.run(steps=steps, eval_every=max(steps // 10, 1),
                         seed=seed)
        us = (time.perf_counter() - t0) / steps * 1e6
        results[name] = (hist, tr.ledger)
        rows.append(Row(
            f"fig4/{name}", us,
            f"loss={hist.global_loss[-1]:.4f};acc={hist.global_acc[-1]:.4f};"
            f"uplinks={tr.ledger.uplinks};d2d={tr.ledger.d2d_msgs}"))

    train("fl_tau1", dataclasses.replace(
        make_baseline_config("centralized", 1), constant_lr=LR))
    train("fl_tau20", dataclasses.replace(
        make_baseline_config("fedavg", TAU), constant_lr=LR))
    for g in GAMMAS:
        train(f"tthf_gamma{g}", TTHFConfig(
            tau=TAU, consensus_every=5, gamma_d2d=g, constant_lr=LR))

    # -- claim checks --------------------------------------------------
    l = {k: v[0].global_loss[-1] for k, v in results.items()}
    c1a = l["tthf_gamma2"] < l["fl_tau20"]
    mono = l["tthf_gamma4"] <= l["tthf_gamma1"] + 1e-3
    gain_12 = l["tthf_gamma1"] - l["tthf_gamma2"]
    gain_48 = l["tthf_gamma4"] - l["tthf_gamma8"]
    dimin = gain_48 <= max(gain_12, 0) + 5e-3
    approach = abs(l["tthf_gamma8"] - l["fl_tau1"]) \
        < abs(l["tthf_gamma0"] - l["fl_tau1"])
    rows.append(Row("fig4/claims", 0.0,
                    f"tthf_beats_fl_tau20={c1a};gamma_monotone={mono};"
                    f"diminishing_returns={dimin};approaches_tau1={approach}"))
    return rows
