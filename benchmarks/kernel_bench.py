"""Pallas kernel micro-benchmarks (interpret mode on CPU — wall times
are NOT TPU times; the derived column reports the analytic HBM-traffic
saving of the fused kernel, which is hardware-independent)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timed


def run(scale: str = "ci", seed: int = 0) -> list[Row]:
    from repro.core.topology import metropolis_weights, ring_adjacency
    from repro.kernels import ops, ref

    rng = np.random.default_rng(seed)
    rows = []

    # consensus_mix: paper config N=25 clusters of s=5, SVM-sized M
    N, s, M = (25, 5, 7850) if scale == "paper" else (5, 5, 1024)
    z = jnp.asarray(rng.normal(size=(N, s, M)), jnp.float32)
    V = jnp.asarray(np.stack([metropolis_weights(ring_adjacency(s))
                              for _ in range(N)]), jnp.float32)
    for gamma in (2, 8, 16):
        g = jnp.full((N,), gamma, jnp.int32)
        out_k, us_k = timed(lambda: np.asarray(ops.consensus_mix(z, V, g)))
        out_r, us_r = timed(lambda: np.asarray(
            ref.consensus_mix_ref(z, V, g)))
        err = float(np.abs(out_k - out_r).max())
        # fused kernel: 2sM HBM words; per-round ref: 2*Gamma*sM
        saving = gamma
        rows.append(Row(f"kernel/consensus_mix/g{gamma}", us_k,
                        f"ref_us={us_r:.0f};max_err={err:.1e};"
                        f"hbm_traffic_saving={saving}x"))

    # ssd_scan: mamba2 head shapes
    BH, T, P, S = (8, 2048, 64, 128) if scale == "paper" else (4, 512, 64, 128)
    x = jnp.asarray(rng.normal(size=(BH, T, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(BH, T)), jnp.float32)
    loga = -dt
    B = jnp.asarray(rng.normal(size=(BH, T, S)), jnp.float32) * 0.3
    C = jnp.asarray(rng.normal(size=(BH, T, S)), jnp.float32) * 0.3
    (yk, _), us_k = timed(lambda: ops.ssd_scan(x, dt, loga, B, C, chunk=256))
    (yr, _), us_r = timed(lambda: ref.ssd_scan_ref(x, dt, loga, B, C))
    err = float(jnp.abs(yk - yr).max() / (jnp.abs(yr).max() + 1e-9))
    # chunked SSD: O(T*Q) flops vs O(T*S) sequential steps; report the
    # matmul fraction that hits the MXU
    rows.append(Row("kernel/ssd_scan", us_k,
                    f"seq_ref_us={us_r:.0f};rel_err={err:.1e};"
                    f"chunk=256;mxu_matmul_form=True"))

    # fused_sgd
    n = 7850 * 125 if scale == "paper" else 100_000
    w = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    _, us_k = timed(lambda: np.asarray(ops.fused_sgd(w, g, 0.01)))
    rows.append(Row("kernel/fused_sgd", us_k,
                    f"elements={n};hbm_passes=3_vs_4"))
    return rows
