"""Pallas kernel micro-benchmarks (interpret mode on CPU — wall times
are NOT TPU times; the derived column reports the analytic HBM-traffic
saving of the fused kernel, which is hardware-independent).

Consensus mixing sweeps EVERY backend of the unified engine
(``repro.core.mixing``) and appends per-backend timings to the
``benchmarks/results/BENCH_mixing.json`` trajectory."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, append_trajectory, timed


def _bench_mixing(scale: str, seed: int) -> list[Row]:
    from repro.core import mixing
    from repro.core.topology import metropolis_weights, ring_adjacency

    rng = np.random.default_rng(seed)
    # paper config: N=25 clusters of s=5, SVM-sized M
    N, s, M = (25, 5, 7850) if scale == "paper" else (5, 5, 1024)
    z = jnp.asarray(rng.normal(size=(N, s, M)), jnp.float32)
    V = jnp.asarray(np.stack([metropolis_weights(ring_adjacency(s))
                              for _ in range(N)]), jnp.float32)
    rows = []
    for gamma in (2, 8, 16):
        # heterogeneous Remark-1 round counts averaging ~gamma
        g = jnp.asarray(rng.integers(max(gamma - 1, 0), gamma + 2,
                                     size=(N,)), jnp.int32)
        ref_out = np.asarray(mixing.mix(z, V, g, backend="reference"))
        for backend in mixing.BACKENDS:
            plan = mixing.build_mixing_plan(V, np.asarray(g),
                                            backend=backend)
            if backend == "reference":
                fn = lambda: np.asarray(plan.apply(z))          # noqa: E731
            else:
                jfn = jax.jit(plan.apply)
                fn = lambda: np.asarray(jfn(z))                 # noqa: E731
            out, us = timed(fn)
            err = float(np.abs(out - ref_out).max())
            # fused paths: 2sM HBM words; per-round: 2*Gamma*sM
            saving = "1x" if backend in ("reference", "masked_loop") \
                else f"{gamma}x"
            rows.append(Row(f"mixing/{backend}/g{gamma}", us,
                            f"max_err={err:.1e};"
                            f"hbm_traffic_saving={saving}"))
    return rows


def run(scale: str = "ci", seed: int = 0) -> list[Row]:
    from repro.kernels import ops, ref

    rng = np.random.default_rng(seed)
    rows = _bench_mixing(scale, seed)
    append_trajectory("mixing", rows, scale)

    # ssd_scan: mamba2 head shapes
    BH, T, P, S = (8, 2048, 64, 128) if scale == "paper" else (4, 512, 64, 128)
    x = jnp.asarray(rng.normal(size=(BH, T, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(BH, T)), jnp.float32)
    loga = -dt
    B = jnp.asarray(rng.normal(size=(BH, T, S)), jnp.float32) * 0.3
    C = jnp.asarray(rng.normal(size=(BH, T, S)), jnp.float32) * 0.3
    (yk, _), us_k = timed(lambda: ops.ssd_scan(x, dt, loga, B, C, chunk=256))
    (yr, _), us_r = timed(lambda: ref.ssd_scan_ref(x, dt, loga, B, C))
    err = float(jnp.abs(yk - yr).max() / (jnp.abs(yr).max() + 1e-9))
    # chunked SSD: O(T*Q) flops vs O(T*S) sequential steps; report the
    # matmul fraction that hits the MXU
    rows.append(Row("kernel/ssd_scan", us_k,
                    f"seq_ref_us={us_r:.0f};rel_err={err:.1e};"
                    f"chunk=256;mxu_matmul_form=True"))

    # fused_sgd
    n = 7850 * 125 if scale == "paper" else 100_000
    w = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    _, us_k = timed(lambda: np.asarray(ops.fused_sgd(w, g, 0.01)))
    rows.append(Row("kernel/fused_sgd", us_k,
                    f"elements={n};hbm_passes=3_vs_4"))
    return rows
