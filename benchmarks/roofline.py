"""Roofline table from the dry-run sweeps (§Roofline deliverable).

Reads benchmarks/results/dryrun_{pod,multipod}.json (produced by
``python -m repro.launch.dryrun --all --mesh ... --subprocess``), emits
the per-(arch x shape) three-term roofline with the dominant bottleneck
and the MODEL_FLOPS / HLO_FLOPs usefulness ratio.
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import Row

RESULTS = pathlib.Path(__file__).parent / "results"


def load(mesh: str):
    f = RESULTS / f"dryrun_{mesh}.json"
    if not f.exists():
        return None
    return json.load(open(f))


def run(scale: str = "ci", seed: int = 0) -> list[Row]:
    rows = []
    for mesh in ("pod", "multipod"):
        recs = load(mesh)
        if recs is None:
            rows.append(Row(f"roofline/{mesh}", 0.0, "missing=no dryrun json"))
            continue
        n_ok = sum(r["status"] == "ok" for r in recs)
        n_skip = sum(r["status"] == "skipped" for r in recs)
        n_err = len(recs) - n_ok - n_skip
        rows.append(Row(f"roofline/{mesh}/summary", 0.0,
                        f"ok={n_ok};skipped={n_skip};error={n_err}"))
        for r in recs:
            if r["status"] != "ok":
                continue
            # roofline step time = max of the three terms (us)
            step_us = max(r["compute_s"], r["memory_s"],
                          r["collective_s"]) * 1e6
            rows.append(Row(
                f"roofline/{mesh}/{r['arch']}/{r['shape']}", step_us,
                f"compute_ms={r['compute_s']*1e3:.2f};"
                f"memory_ms={r['memory_s']*1e3:.2f};"
                f"collective_ms={r['collective_s']*1e3:.2f};"
                f"dominant={r['dominant']};"
                f"useful_flops={r['useful_flops_frac']:.2f};"
                f"hbm_gb={(r['temp_bytes']+r['arg_bytes'])/2**30:.1f}"))
    return rows
