"""Wave vs continuous batching under a Poisson arrival trace.

One reduced arch per family (dense / moe / ssm / hybrid) serves the
same seeded trace through both schedulers; the derived column records
decode steps, generated tokens, slot utilization, and wall-clock tok/s.
Continuous batching should finish the trace in fewer decode steps —
freed slots are re-prefilled while the rest of the batch keeps
decoding, instead of idling until the wave drains.

  PYTHONPATH=src python -m benchmarks.serving_bench
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import Row, append_trajectory

ARCH_BY_KIND = {
    "dense": "qwen1.5-0.5b",
    "moe": "llama4-scout-17b-a16e",
    "ssm": "mamba2-370m",
    "hybrid": "recurrentgemma-9b",
}


def _reduced_cfg(name):
    from repro.configs import get_arch
    cfg = get_arch(name).reduced(num_layers=2, d_model=128, d_ff=256,
                                 vocab_size=256)
    if cfg.kind == "hybrid":
        cfg = dataclasses.replace(cfg, attention_window=16)
    if cfg.moe_num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    return cfg


def _trace(rng, n_req, max_prompt, gap):
    """Poisson arrivals with mixed prompt lengths and budgets."""
    from repro.serving.scheduler import Request
    arrivals, step = [], 0
    for rid in range(n_req):
        plen = int(rng.integers(max(2, max_prompt // 4), max_prompt + 1))
        prompt = rng.integers(1, 250, size=plen).astype(np.int32)
        arrivals.append((step, Request(rid=rid, prompt=prompt,
                                       max_new=int(rng.integers(4, 13)))))
        step += int(rng.poisson(gap))
    return arrivals


def run(scale: str = "ci", seed: int = 0):
    import jax
    from repro.models import build_model
    from repro.serving.scheduler import make_scheduler, run_trace

    n_req = 12 if scale == "ci" else 48
    slots, max_prompt, max_total = 4, 16, 48
    rows = []
    for kind, name in ARCH_BY_KIND.items():
        cfg = _reduced_cfg(name)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed))
        per_sched = {}
        for sname in ("wave", "continuous"):
            rng = np.random.default_rng(seed)     # identical trace
            arrivals = _trace(rng, n_req, max_prompt, gap=1.0)
            sched = make_scheduler(sname, model, slots=slots,
                                   max_prompt=max_prompt,
                                   max_total=max_total, temperature=0.0,
                                   seed=seed)
            t0 = time.time()
            stats = run_trace(sched, params, arrivals)
            dt = time.time() - t0
            assert stats.requests_done == n_req, (kind, sname, stats)
            per_sched[sname] = stats
            # per-request latency percentiles (step-clock ticks) from
            # the retirement records the scheduler now keeps
            ql = np.array([r.queue_latency for r in stats.records])
            tt = np.array([r.ttft for r in stats.records if r.ttft >= 0])
            rows.append(Row(
                f"serving/{kind}/{sname}", dt * 1e6 / max(
                    stats.decode_steps, 1),
                f"decode_steps={stats.decode_steps};"
                f"toks={stats.tokens_generated};"
                f"util={stats.utilization:.3f};"
                f"tok_per_step={stats.tokens_generated / max(stats.decode_steps, 1):.2f};"
                f"tok_s={stats.tokens_generated / max(dt, 1e-9):.1f};"
                f"queue_p50={np.percentile(ql, 50):.0f};"
                f"queue_p95={np.percentile(ql, 95):.0f};"
                f"ttft_p50={np.percentile(tt, 50):.0f};"
                f"ttft_p95={np.percentile(tt, 95):.0f}"))
        w, c = per_sched["wave"], per_sched["continuous"]
        rows.append(Row(
            f"serving/{kind}/speedup", 0.0,
            f"steps_wave={w.decode_steps};steps_cont={c.decode_steps};"
            f"step_ratio={w.decode_steps / max(c.decode_steps, 1):.2f}"))
    append_trajectory("serving", rows, scale)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row.csv())
