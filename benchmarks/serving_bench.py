"""Wave vs continuous batching under a Poisson arrival trace.

One reduced arch per family (dense / moe / ssm / hybrid) serves the
same seeded trace through both schedulers; the derived column records
decode steps, generated tokens, slot utilization, and wall-clock tok/s.
Continuous batching should finish the trace in fewer decode steps —
freed slots are re-prefilled while the rest of the batch keeps
decoding, instead of idling until the wave drains.

  PYTHONPATH=src python -m benchmarks.serving_bench
  PYTHONPATH=src python -m benchmarks.serving_bench --sharded
  PYTHONPATH=src python -m benchmarks.serving_bench --memory-ceiling

``--sharded`` additionally times the continuous scheduler on a
(data=2, model=4) mesh of 8 simulated host devices against the same
single-device trace (DESIGN.md §14). It runs in a subprocess because
the forced device count must be set before jax initializes.

``--memory-ceiling`` (DESIGN.md §15) serves the same shared-prefix
Poisson trace under a CAPPED cache byte budget through the ring
(continuous) and paged schedulers, recording requests-served-per-GB
within a fixed step horizon plus the paged prefix-hit-rate; a second
uncapped pass compares TTFT on the templated trace, attributing it to
queueing vs chunked prefill.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import Row, append_trajectory

ARCH_BY_KIND = {
    "dense": "qwen1.5-0.5b",
    "moe": "llama4-scout-17b-a16e",
    "ssm": "mamba2-370m",
    "hybrid": "recurrentgemma-9b",
}


def _reduced_cfg(name):
    from repro.configs import get_arch
    cfg = get_arch(name).reduced(num_layers=2, d_model=128, d_ff=256,
                                 vocab_size=256)
    if cfg.kind == "hybrid":
        cfg = dataclasses.replace(cfg, attention_window=16)
    if cfg.moe_num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    return cfg


def _trace(rng, n_req, max_prompt, gap):
    """Poisson arrivals with mixed prompt lengths and budgets."""
    from repro.serving import Request
    arrivals, step = [], 0
    for rid in range(n_req):
        plen = int(rng.integers(max(2, max_prompt // 4), max_prompt + 1))
        prompt = rng.integers(1, 250, size=plen).astype(np.int32)
        arrivals.append((step, Request(rid=rid, prompt=prompt,
                                       max_new=int(rng.integers(4, 13)))))
        step += int(rng.poisson(gap))
    return arrivals


def run(scale: str = "ci", seed: int = 0):
    import jax
    from repro.models import build_model
    from repro.serving import make_scheduler, run_trace

    n_req = 12 if scale == "ci" else 48
    slots, max_prompt, max_total = 4, 16, 48
    rows = []
    for kind, name in ARCH_BY_KIND.items():
        cfg = _reduced_cfg(name)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed))
        per_sched = {}
        for sname in ("wave", "continuous"):
            rng = np.random.default_rng(seed)     # identical trace
            arrivals = _trace(rng, n_req, max_prompt, gap=1.0)
            sched = make_scheduler(sname, model, slots=slots,
                                   max_prompt=max_prompt,
                                   max_total=max_total, temperature=0.0,
                                   seed=seed)
            t0 = time.time()
            stats = run_trace(sched, params, arrivals)
            dt = time.time() - t0
            assert stats.requests_done == n_req, (kind, sname, stats)
            per_sched[sname] = stats
            # per-request latency percentiles (step-clock ticks) from
            # the retirement records the scheduler now keeps
            ql = np.array([r.queue_latency for r in stats.records])
            tt = np.array([r.ttft for r in stats.records if r.ttft >= 0])
            pf = np.array([r.prefill_latency for r in stats.records
                           if r.ttft >= 0])
            rows.append(Row(
                f"serving/{kind}/{sname}", dt * 1e6 / max(
                    stats.decode_steps, 1),
                f"decode_steps={stats.decode_steps};"
                f"toks={stats.tokens_generated};"
                f"util={stats.utilization:.3f};"
                f"tok_per_step={stats.tokens_generated / max(stats.decode_steps, 1):.2f};"
                f"tok_s={stats.tokens_generated / max(dt, 1e-9):.1f};"
                f"queue_p50={np.percentile(ql, 50):.0f};"
                f"queue_p95={np.percentile(ql, 95):.0f};"
                f"prefill_p50={np.percentile(pf, 50):.0f};"
                f"ttft_p50={np.percentile(tt, 50):.0f};"
                f"ttft_p95={np.percentile(tt, 95):.0f}"))
        w, c = per_sched["wave"], per_sched["continuous"]
        rows.append(Row(
            f"serving/{kind}/speedup", 0.0,
            f"steps_wave={w.decode_steps};steps_cont={c.decode_steps};"
            f"step_ratio={w.decode_steps / max(c.decode_steps, 1):.2f}"))
    append_trajectory("serving", rows, scale)
    return rows


def _shared_prefix_trace(rng, n_req, template, max_prompt, gap):
    """Poisson arrivals whose prompts all start with one fixed
    ``template``-token prefix (the prefix-sharing regime: after the
    first admission the trie serves the template pages to everyone)."""
    from repro.serving import Request
    tmpl = rng.integers(1, 250, size=template).astype(np.int32)
    arrivals, step = [], 0
    for rid in range(n_req):
        tail = rng.integers(
            1, 250, size=int(rng.integers(4, max_prompt - template + 1)))
        prompt = np.concatenate([tmpl, tail]).astype(np.int32)
        arrivals.append((step, Request(rid=rid, prompt=prompt,
                                       max_new=int(rng.integers(4, 13)))))
        step += int(rng.poisson(gap))
    return arrivals


def _lat(stats):
    """(queue_p50, prefill_p50, ttft_p50, mean_chunks) from records —
    TTFT = queue_latency + prefill_latency, so the pair attributes it
    to queueing vs (chunked) prefill."""
    recs = [r for r in stats.records if r.ttft >= 0]
    if not recs:
        return -1.0, -1.0, -1.0, 0.0
    q = float(np.percentile([r.queue_latency for r in recs], 50))
    p = float(np.percentile([r.prefill_latency for r in recs], 50))
    t = float(np.percentile([r.ttft for r in recs], 50))
    ch = float(np.mean([r.prefill_chunks for r in recs]))
    return q, p, t, ch


def run_memory_ceiling(scale: str = "ci", seed: int = 0):
    """Ring vs paged under one capped cache byte budget (DESIGN.md §15).

    Both schedulers get the SAME cache bytes: the ring spends them on
    ``ring_slots`` fixed (max_total)-token lanes; the paged pool spends
    them on pages that prefix sharing and per-request page counts keep
    mostly full. Within a fixed step horizon the paged scheduler must
    serve strictly more requests per GB on the shared-prefix trace.
    """
    import warnings

    import jax
    from repro.models import build_model
    from repro.serving import make_scheduler, run_trace

    n_req = 16 if scale == "ci" else 64
    horizon = 60 if scale == "ci" else 240
    page_size, template = 8, 8
    slots, max_prompt, max_total = 4, 16, 48
    ring_slots = 2
    cfg = _reduced_cfg(ARCH_BY_KIND["dense"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    # the capped budget: bytes for ring_slots full-length ring lanes
    # (f32 cache: layers * K/V * kv_heads * head_dim * 4B per token)
    tok_bytes = cfg.num_layers * 2 * cfg.num_kv_heads * cfg.head_dim * 4
    budget_tokens = ring_slots * max_total
    budget_gb = budget_tokens * tok_bytes / 1e9
    cache_pages = budget_tokens // page_size + 1    # same bytes, paged

    rows, done = [], {}
    for sname in ("continuous", "paged"):
        rng = np.random.default_rng(seed)           # identical trace
        arrivals = _shared_prefix_trace(rng, n_req, template,
                                        max_prompt, gap=1.0)
        kw = dict(max_prompt=max_prompt, max_total=max_total,
                  temperature=0.0, seed=seed)
        if sname == "paged":
            sched = make_scheduler("paged", model, slots=slots,
                                   page_size=page_size,
                                   cache_pages=cache_pages, **kw)
        else:
            sched = make_scheduler("continuous", model,
                                   slots=ring_slots, **kw)
        t0 = time.time()
        with warnings.catch_warnings():
            # the horizon intentionally truncates the trace
            warnings.simplefilter("ignore", RuntimeWarning)
            stats = run_trace(sched, params, arrivals, max_steps=horizon)
        dt = time.time() - t0
        done[sname] = stats.requests_done
        q50, p50, t50, chunks = _lat(stats)
        extra = ""
        if sname == "paged":
            reused = sum(r.prefix_pages_reused for r in stats.records)
            extra = (f";prefix_hit_rate={sched.prefix_hit_rate:.2f};"
                     f"pages_reused={reused};"
                     f"deferrals={sched.page_deferrals};"
                     f"mean_chunks={chunks:.1f}")
        rows.append(Row(
            f"serving/memceil/{sname}",
            dt * 1e6 / max(stats.decode_steps, 1),
            f"budget_mb={budget_gb * 1e3:.2f};"
            f"done_at_h{horizon}={stats.requests_done};"
            f"requests_per_gb={stats.requests_done / budget_gb:.0f};"
            f"toks={stats.tokens_generated};"
            f"queue_p50={q50:.0f};prefill_p50={p50:.0f};"
            f"ttft_p50={t50:.0f}" + extra))
    assert done["paged"] > done["continuous"], (
        "paged must serve strictly more requests per GB than ring "
        f"under the capped budget: {done}")
    rows.append(Row(
        "serving/memceil/gain", 0.0,
        f"ring_done={done['continuous']};paged_done={done['paged']};"
        f"ratio={done['paged'] / max(done['continuous'], 1):.2f}"))

    # --- uncapped templated-prefix pass: TTFT must not regress --------
    ttft = {}
    for sname in ("continuous", "paged"):
        rng = np.random.default_rng(seed)
        arrivals = _shared_prefix_trace(rng, n_req, template,
                                        max_prompt, gap=1.0)
        kw = dict(slots=slots, max_prompt=max_prompt,
                  max_total=max_total, temperature=0.0, seed=seed)
        if sname == "paged":
            sched = make_scheduler("paged", model, page_size=page_size,
                                   **kw)
        else:
            sched = make_scheduler("continuous", model, **kw)
        stats = run_trace(sched, params, arrivals)
        assert stats.requests_done == n_req
        q50, p50, t50, chunks = _lat(stats)
        ttft[sname] = t50
        extra = ""
        if sname == "paged":
            reused = sum(r.prefix_pages_reused for r in stats.records)
            assert reused > 0, "templated trace must reuse prefix pages"
            extra = (f";pages_reused={reused};"
                     f"prefix_hit_rate={sched.prefix_hit_rate:.2f};"
                     f"mean_chunks={chunks:.1f}")
        rows.append(Row(
            f"serving/ttft_template/{sname}", 0.0,
            f"queue_p50={q50:.0f};prefill_p50={p50:.0f};"
            f"ttft_p50={t50:.0f}" + extra))
    assert ttft["paged"] <= ttft["continuous"], (
        "paged TTFT regressed vs ring on short templated prompts", ttft)
    append_trajectory("serving", rows, scale)
    return rows


SHARDED_KINDS = ("dense", "ssm")
SHARDED_MESH = "2x4"        # data=2, model=4 over 8 forced host devices
SHARDED_NDEV = 8


def _run_sharded_child(scale: str, seed: int):
    """Child entry: runs under XLA_FLAGS forcing 8 host devices. Times
    the same continuous-batching trace single-device and on the
    (data, model) mesh, printing one JSON line the parent parses."""
    import jax
    from repro.launch.mesh import make_serve_mesh
    from repro.models import build_model
    from repro.serving import make_scheduler, run_trace, shard_params

    n_req = 12 if scale == "ci" else 48
    slots, max_prompt, max_total = 4, 16, 48
    out = []
    for kind in SHARDED_KINDS:
        cfg = _reduced_cfg(ARCH_BY_KIND[kind])
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed))
        for spec in (None, SHARDED_MESH):
            mesh = make_serve_mesh(spec) if spec else None
            p = shard_params(params, model, mesh) if mesh else params
            rng = np.random.default_rng(seed)     # identical trace
            arrivals = _trace(rng, n_req, max_prompt, gap=1.0)
            sched = make_scheduler("continuous", model, slots=slots,
                                   max_prompt=max_prompt,
                                   max_total=max_total, temperature=0.0,
                                   seed=seed, mesh=mesh)
            t0 = time.time()
            stats = run_trace(sched, p, arrivals)
            dt = time.time() - t0
            assert stats.requests_done == n_req, (kind, spec, stats)
            out.append({
                "kind": kind, "mesh": spec or "single",
                "devices": 1 if mesh is None else int(mesh.devices.size),
                "wall_s": dt, "decode_steps": stats.decode_steps,
                "tokens": stats.tokens_generated,
                "util": stats.utilization})
    print(json.dumps(out))


def run_sharded(scale: str = "ci", seed: int = 0):
    """Parent entry for ``--sharded``: fork a child with the forced
    host device count, parse its JSON, append rows to BENCH_serving."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count="
                        + str(SHARDED_NDEV))
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.serving_bench",
         "--child-sharded", "--scale", scale, "--seed", str(seed)],
        capture_output=True, text=True, timeout=1800, env=env)
    if out.returncode != 0:
        raise RuntimeError(f"sharded child failed:\n{out.stderr[-2000:]}")
    recs = json.loads(out.stdout.splitlines()[-1])
    rows = []
    for r in recs:
        rows.append(Row(
            f"serving/sharded/{r['kind']}/{r['mesh']}",
            r["wall_s"] * 1e6 / max(r["decode_steps"], 1),
            f"devices={r['devices']};decode_steps={r['decode_steps']};"
            f"toks={r['tokens']};util={r['util']:.3f};"
            f"tok_s={r['tokens'] / max(r['wall_s'], 1e-9):.1f}"))
    append_trajectory("serving", rows, scale)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--sharded", action="store_true",
                    help="also bench the continuous scheduler on a "
                         f"{SHARDED_MESH} mesh of {SHARDED_NDEV} forced "
                         "host devices (subprocess)")
    ap.add_argument("--child-sharded", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--memory-ceiling", action="store_true",
                    help="ring vs paged under one capped cache byte "
                         "budget on a shared-prefix trace (requests/GB, "
                         "prefix hit rate, TTFT attribution)")
    ap.add_argument("--scale", default="ci", choices=["ci", "full"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.child_sharded:
        _run_sharded_child(args.scale, args.seed)
    elif args.sharded:
        for row in run_sharded(args.scale, args.seed):
            print(row.csv())
    elif args.memory_ceiling:
        for row in run_memory_ceiling(args.scale, args.seed):
            print(row.csv())
    else:
        for row in run(args.scale, args.seed):
            print(row.csv())
