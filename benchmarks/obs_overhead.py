"""Observability overhead benchmark (DESIGN.md §13).

A/B of the scale-mode trainer — the workload the <3% budget is about:
one jitted TT-HF interval is ~1s of real compute, against which the
per-interval drain (one ``block_until_ready`` fence + one read-only
probe dispatch + a JSONL write + trace export, ~15 ms on a 1-core CPU
box, far less on a real accelerator) must be noise. The
tiny-SVM simulation is deliberately NOT the budget workload: its whole
step costs ~2 ms, comparable to a single jit dispatch, so any
per-round host work reads as tens of percent there (the sim's bitwise
and stream guarantees are covered by ``tests/test_obs.py``).

Rows:
* ``obs/bare`` / ``obs/instrumented`` — µs per interval, post-warmup.
* ``obs/overhead_pct`` — steps/sec cost; budget < 3%. Also asserts the
  instrumented params are BITWISE the bare params after identical
  interval counts.
* ``obs/stream`` — the single metrics.jsonl stream carries, for the
  same interval, measured per-cluster divergence, the Lemma-1 /
  Proposition-1 gauges, and the attributed comms delta.
"""
from __future__ import annotations

import json
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import Row, append_trajectory


def _trainer(scale_name: str, trace_dir=None):
    from repro.configs import get_arch
    from repro.core.distributed import TTHFScaleConfig
    from repro.train import ScaleTrainer, TrainerConfig

    if scale_name == "paper":
        layers, d_model, d_ff, replicas, tau = 2, 256, 512, 8, 20
    else:
        layers, d_model, d_ff, replicas, tau = 2, 128, 256, 4, 16
    cfg = get_arch("qwen1.5-0.5b").reduced(
        num_layers=layers, d_model=d_model, d_ff=d_ff, vocab_size=128)
    scale = TTHFScaleConfig(replicas=replicas, cluster_size=2, tau=tau,
                            consensus_every=2, gamma_d2d=1, lr=0.05)
    tcfg = TrainerConfig(batch_per_replica=2, seq_len=128, intervals=1,
                         eval_every=0, prefetch=False,
                         trace_dir=trace_dir)
    return ScaleTrainer(cfg, scale, tcfg).init()


def _leaves(tr):
    import jax
    return [np.asarray(l) for l in jax.tree.leaves(tr.params)]


def run(scale: str = "ci", seed: int = 0) -> list:
    intervals = 6 if scale == "ci" else 8

    # One warmup interval each pays the jit compile (the instrumented
    # warmup also compiles the read-only probes). The timed intervals
    # then ALTERNATE bare/instrumented so slow machine drift (thermal,
    # cache, noisy-neighbour) hits both sides equally, and each side's
    # best interval is compared: the drain is deterministic work that
    # shows up in the minimum, scheduler noise does not — sequential
    # mean/median A/B on a busy 1-core box drifts by more than the
    # effect being measured.
    tr0 = _trainer(scale)
    td = tempfile.mkdtemp(prefix="obs_bench_")
    tr1 = _trainer(scale, trace_dir=td)
    tr0.run(1)
    tr1.run(1)
    per_bare, per_obs = [], []
    for _ in range(intervals):
        t0 = time.perf_counter()
        tr0.run(1)
        per_bare.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        tr1.run(1)
        per_obs.append(time.perf_counter() - t0)
    tr1.close()
    dt_bare = float(np.min(per_bare)) * intervals
    dt_obs = float(np.min(per_obs)) * intervals

    # bitwise trajectory parity after identical interval counts
    bitwise = all(a.tobytes() == b.tobytes()
                  for a, b in zip(_leaves(tr0), _leaves(tr1)))
    assert bitwise, "observability perturbed the training trajectory"

    overhead = (dt_obs - dt_bare) / max(dt_bare, 1e-9) * 100.0

    # one-stream completeness: a single interval carries bound +
    # actual + attributed comms
    recs = [json.loads(l) for l in
            (Path(td) / "metrics.jsonl").read_text().splitlines()]
    rounds = [r for r in recs if r.get("kind") == "round"
              and "lemma1_bound" in r and "upsilon" in r]
    comms = {r["step"] for r in recs if r.get("kind") == "comm"}
    joined = [r for r in rounds if r["step"] in comms]
    assert rounds and joined, \
        "telemetry stream missing bound-vs-actual / comm join"

    rows = [
        Row("obs/bare", dt_bare / intervals * 1e6,
            f"intervals={intervals}"),
        Row("obs/instrumented", dt_obs / intervals * 1e6,
            f"intervals={intervals}"),
        Row("obs/overhead_pct", overhead,
            f"budget<3% bitwise={bitwise}"),
        Row("obs/stream", float(len(recs)),
            f"rounds_with_bounds={len(rounds)} joined={len(joined)}"),
    ]
    append_trajectory("obs", rows, scale)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
