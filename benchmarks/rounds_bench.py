"""Round-program hot-loop benchmark (DESIGN.md §10).

The unified sim loop dispatches per *event*, not per iteration: the
resolver knows the calendar ahead of time, so every local-SGD span
between two communication/eval events runs as ONE jitted ``lax.scan``.
This sweep measures steps/sec of the per-iteration dispatch cadence
(``chunked=False`` — exactly the pre-engine loops' dispatch pattern)
against the event-chunked scan (``chunked=True``) on the same worlds,
asserts the trajectories are bitwise identical (the scan is a pure
execution-strategy change), and appends the speedups to
``BENCH_rounds.json``.

Cases: a dense event calendar (consensus every 5 — spans of 5), a
sparse one (consensus every tau — spans of 20, the large-tau regime
the paper's Fig. 5 sweeps), and device churn (per-iteration host
snapshots still tick inside the span; only the SGD dispatch is
batched).

Row ``derived``: steps_per_sec=..;speedup=..;bitwise_equal=..
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, append_trajectory, sim_world

LR = 0.002


def _trainer(data, topo, model, algo, dyn, chunked):
    from repro.core import TTHFTrainer
    return TTHFTrainer(model, data, topo, algo, batch_size=16,
                       dynamics=dyn, chunked=chunked)


def _run(tr, steps, eval_every):
    t0 = time.perf_counter()
    _, hist = tr.run(steps=steps, eval_every=eval_every, seed=0,
                     record_dispersion=False)
    return time.perf_counter() - t0, hist


def run(scale: str = "ci", seed: int = 0) -> list[Row]:
    from repro.configs import TTHFConfig
    from repro.netsim import scenarios

    data, topo, model, _ = sim_world(scale, seed)
    steps = 400 if scale == "paper" else 120

    cases = {
        "dense_events": (TTHFConfig(tau=20, consensus_every=5,
                                    gamma_d2d=2, constant_lr=LR), None),
        "sparse_events": (TTHFConfig(tau=20, consensus_every=20,
                                     gamma_d2d=2, constant_lr=LR), None),
        "churn": (TTHFConfig(tau=20, consensus_every=5, gamma_d2d=2,
                             constant_lr=LR),
                  scenarios.get("device_churn", seed=seed)),
    }

    rows = []
    for name, (algo, dyn) in cases.items():
        eval_every = algo.tau
        results = {}
        for mode, chunked in (("stepwise", False), ("scanned", True)):
            tr = _trainer(data, topo, model, algo, dyn, chunked)
            _run(tr, eval_every, eval_every)       # warmup: compile
            wall, hist = _run(tr, steps, eval_every)
            results[mode] = (wall, hist, tr.ledger)
            rows.append(Row(f"rounds/{name}_{mode}", wall * 1e6,
                            f"steps_per_sec={steps / wall:.1f}"))
        (w0, h0, l0), (w1, h1, l1) = results["stepwise"], results["scanned"]
        same = (h0.global_loss == h1.global_loss
                and h0.global_acc == h1.global_acc
                and l0.uplinks == l1.uplinks
                and l0.d2d_msgs == l1.d2d_msgs
                and all(np.array_equal(a, b)
                        for a, b in zip(h0.gamma_used, h1.gamma_used)))
        rows.append(Row(f"rounds/{name}_speedup", 0.0,
                        f"speedup={w0 / w1:.2f}x;"
                        f"bitwise_equal={same};"
                        f"final_loss={h1.global_loss[-1]:.4f}"))
    append_trajectory("rounds", rows, scale)
    return rows
