"""Regenerate the §Roofline table in EXPERIMENTS.md from the dry-run
JSONs (run after a sweep): replaces the <!-- ROOFLINE_TABLE --> marker
block."""
from __future__ import annotations

import json
import pathlib
import re

ROOT = pathlib.Path(__file__).parent.parent
RESULTS = pathlib.Path(__file__).parent / "results"

HEADER = (
    "| arch | shape | compute | memory | collective | dominant | "
    "useful | HBM GB |\n"
    "|---|---|---|---|---|---|---|---|\n")


def fmt_s(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f} s"
    if s >= 1e-3:
        return f"{s*1e3:.1f} ms"
    return f"{s*1e6:.0f} us"


def table(mesh: str) -> str:
    recs = json.load(open(RESULTS / f"dryrun_{mesh}.json"))
    out = [HEADER]
    for r in recs:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"*skipped* | — | — |\n")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |\n")
            continue
        hbm = (r["temp_bytes"] + r["arg_bytes"]) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {r['useful_flops_frac']:.2f} | "
            f"{hbm:.1f} |\n")
    return "".join(out)


def main():
    md = (ROOT / "EXPERIMENTS.md").read_text()
    block = ("### Single-pod (16x16 = 256 chips) — all 39 runnable combos\n\n"
             + table("pod")
             + "\nMulti-pod (2x16x16 = 512 chips) numbers live in "
               "`benchmarks/results/dryrun_multipod.json`; every combo "
               "also lowers + compiles there (the `pod` axis shards "
               "batch/replicas), with per-chip footprints at or below "
               "the single-pod values.\n")
    md = re.sub(r"<!-- ROOFLINE_TABLE -->", block, md, count=1)
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md roofline table updated")


if __name__ == "__main__":
    main()
